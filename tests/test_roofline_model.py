"""Validate the analytic executed-FLOPs model against UNROLLED HLO counts.

The roofline compute term relies on `benchmarks.roofline.executed_flops`
because `cost_analysis()` counts scan bodies once. Here we build a tiny
config whose layer loop is fully unrolled (a python loop — no lax.scan),
lower it, and check the analytic model against XLA's own count.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_analytic_flops_within_30pct_of_unrolled_hlo():
    code = r"""
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ParallelSpec, ShapeSpec
from repro.distributed.sharding import Policy
from repro.models import build, input_specs
from repro.models import transformer as TF
from repro import optim
from repro.launch.train import make_train_step
from benchmarks.roofline import executed_flops

# tiny dense config; remat OFF so factor=6 (no recompute ambiguity)
cfg = get_config("qwen2-7b")
cfg = dataclasses.replace(cfg, num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=2, d_ff=512, vocab_size=1024, head_dim=64, qkv_bias=False,
    parallel=ParallelSpec(remat=False))
shape = ShapeSpec("t", 256, 4, "train")

# monkeypatch the segment scan into a python loop => fully unrolled HLO
orig = TF._seg_apply
def unrolled(cfg_, unit, seg_p, x, positions, policy, remat):
    import jax
    aux = jnp.zeros((), jnp.float32)
    n = jax.tree.leaves(seg_p)[0].shape[0]
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], seg_p)
        for j, sig in enumerate(unit):
            x, a = TF.apply_block(cfg_, sig, lp[f"u{j}"], x, positions, policy)
            aux = aux + a
    return x, aux
TF._seg_apply = unrolled

model = build(cfg)
params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
opt_cfg = optim.AdamWConfig()
opt = jax.eval_shape(lambda p: optim.init(opt_cfg, p), params)
step = make_train_step(model, opt_cfg, Policy())
c = jax.jit(step).lower(params, opt, input_specs(cfg, shape)).compile()
ca = c.cost_analysis()
if isinstance(ca, (list, tuple)):   # older jax: one dict per device
    ca = ca[0]
hlo = ca["flops"]
analytic = executed_flops(cfg, shape)
ratio = analytic / hlo
print(f"analytic={analytic:.3e} hlo={hlo:.3e} ratio={ratio:.2f}")
assert 0.7 < ratio < 1.4, ratio
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + REPO)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ratio=" in out.stdout


def test_model_flops_formulas():
    from benchmarks.roofline import model_flops
    from repro.configs import SHAPES, get_config
    cfg = get_config("qwen2-7b")
    t = SHAPES["train_4k"]
    assert model_flops(cfg, t) == pytest.approx(
        6.0 * cfg.num_params() * t.global_batch * t.seq_len)
    moe = get_config("deepseek-v3-671b")
    assert model_flops(moe, t) == pytest.approx(
        6.0 * moe.num_active_params() * t.global_batch * t.seq_len)
    d = SHAPES["decode_32k"]
    assert model_flops(cfg, d) == pytest.approx(
        2.0 * cfg.num_params() * d.global_batch)
