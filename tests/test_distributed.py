"""Distributed-correctness tests. These need >1 device, so they run in a
subprocess with forced host devices (the main pytest process keeps the
default single-device config, per the dry-run isolation rule)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """One train step on a (2,4) mesh == single-device step (same math)."""
    run_sub(r"""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.distributed.sharding import Policy, make_policy, param_specs, shardings_of
from repro.launch.mesh import make_mesh
from repro.launch.train import make_train_step, batch_shardings
from repro.launch.mesh import use_mesh
from repro.models import build, make_batch
from repro import optim

cfg = get_config("qwen2-7b-smoke")
shape = ShapeSpec("t", 64, 4, "train")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
opt_cfg = optim.AdamWConfig(lr=1e-3)
opt = optim.init(opt_cfg, params)
batch = make_batch(cfg, shape, jax.random.PRNGKey(1))

# single device
step1 = jax.jit(make_train_step(model, opt_cfg, Policy()))
p1, o1, m1 = step1(params, opt, batch)

# sharded
mesh = make_mesh((2, 4), ("data", "model"))
policy = make_policy(mesh, cfg)
stepN = jax.jit(make_train_step(model, opt_cfg, policy),
                in_shardings=(shardings_of(param_specs(params, policy), mesh),
                              None, batch_shardings(batch, policy)))
with use_mesh(mesh):
    pN, oN, mN = stepN(params, opt, batch)

np.testing.assert_allclose(float(m1["loss"]), float(mN["loss"]), rtol=1e-5)
jax.tree.map(lambda a, b: np.testing.assert_allclose(
    np.asarray(a, np.float32), np.asarray(b, np.float32),
    rtol=5e-3, atol=5e-3), p1, pN)
print("OK sharded == single-device")
""")


def test_moe_ep_sharded_matches_local():
    """EP-sharded deepseek MoE step == local path (generous capacity)."""
    run_sub(r"""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.distributed.sharding import Policy, make_policy, param_specs, shardings_of
from repro.launch.mesh import make_mesh, use_mesh
from repro.models import build, make_batch

cfg = get_config("deepseek-v3-671b-smoke")
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
shape = ShapeSpec("t", 32, 4, "train")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = make_batch(cfg, shape, jax.random.PRNGKey(1))

l1, _ = jax.jit(lambda p, b: model.loss(p, b, Policy()))(params, batch)
mesh = make_mesh((2, 2), ("data", "model"))
policy = make_policy(mesh, cfg)
with use_mesh(mesh):
    lN, _ = jax.jit(lambda p, b: model.loss(p, b, policy))(params, batch)
np.testing.assert_allclose(float(l1), float(lN), rtol=2e-4)
print("OK moe ep == local")
""")


def test_production_mesh_shapes():
    run_sub(r"""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.shape == {"data": 16, "model": 16}, m1.shape
m2 = make_production_mesh(multi_pod=True)
assert m2.shape == {"pod": 2, "data": 16, "model": 16}, m2.shape
print("OK meshes")
""", devices=512)


@pytest.mark.slow
def test_dryrun_single_cell_end_to_end(tmp_path):
    """The dry-run entry point works end-to-end for one small cell."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "decode_32k", "--report-dir", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-2000:]
    import json
    rep = json.load(open(os.path.join(
        str(tmp_path), "smollm-135m__decode_32k__pod16x16.json")))
    assert rep["status"] == "ok"
    assert rep["memory"]["peak_bytes"] < 16 * 2**30     # fits HBM
