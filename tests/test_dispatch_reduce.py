"""Oracle coverage for ``dispatch.ordered_segment_reduce`` beyond the
``add`` path: ``max`` / ``min`` flavours and empty bins, cross-checked
against the retry-based native-scatter oracle (``lrsc_scatter_add`` and
its max/min analogues built from ``.at[].max/.min``).

Deliberately hypothesis-free so the reduce paths stay exercised on
minimal installs (the property suites in ``test_dispatch.py`` skip when
hypothesis is absent).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch as D


def _native_scatter(keys, vals, bins, op):
    """The XLA duplicate-combining scatter — the SPMD analogue of the SC
    retry loop that ordered_segment_reduce replaces."""
    ident = {"max": -jnp.inf, "min": jnp.inf}[op]
    init = jnp.full((bins,), ident, jnp.float32)
    upd = getattr(init.at[keys], op)(vals)       # .at[].max / .at[].min
    return upd


def _cases():
    rng = np.random.RandomState(42)
    for n, bins in [(1, 1), (7, 3), (50, 8), (500, 40), (300, 17)]:
        keys = rng.randint(0, bins, size=n).astype(np.int32)
        vals = rng.uniform(-100, 100, size=n).astype(np.float32)
        yield keys, vals, bins
    # guaranteed-empty bins: keys restricted to the lower half of the range
    keys = rng.randint(0, 5, size=200).astype(np.int32)
    vals = rng.uniform(-50, 50, size=200).astype(np.float32)
    yield keys, vals, 16
    # single hot bin amid many empties
    yield np.full(64, 9, np.int32), np.arange(64, dtype=np.float32), 32


@pytest.mark.parametrize("op", ["max", "min"])
def test_segment_reduce_matches_native_scatter(op):
    for keys, vals, bins in _cases():
        out = D.ordered_segment_reduce(jnp.array(keys), jnp.array(vals),
                                       bins, op=op)
        ref = _native_scatter(jnp.array(keys), jnp.array(vals), bins, op)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6)


@pytest.mark.parametrize("op,ident", [("max", -np.inf), ("min", np.inf)])
def test_segment_reduce_empty_bins_get_identity(op, ident):
    keys = jnp.array([0, 0, 3], jnp.int32)
    vals = jnp.array([2.0, 7.0, -1.0], jnp.float32)
    out = np.asarray(D.ordered_segment_reduce(keys, vals, 6, op=op))
    occupied = {0: 7.0 if op == "max" else 2.0, 3: -1.0}
    for b in range(6):
        if b in occupied:
            assert out[b] == occupied[b]
        else:
            assert out[b] == ident                # identity, not garbage


def test_segment_reduce_add_matches_lrsc_oracle():
    for keys, vals, bins in _cases():
        out = D.ordered_segment_reduce(jnp.array(keys), jnp.array(vals),
                                       bins, op="add")
        ref = D.lrsc_scatter_add(jnp.array(keys), jnp.array(vals), bins)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3)


def test_segment_reduce_all_bins_empty_variantless():
    """Zero requests: every bin reports the identity."""
    keys = jnp.zeros((0,), jnp.int32)
    vals = jnp.zeros((0,), jnp.float32)
    out_max = np.asarray(D.ordered_segment_reduce(keys, vals, 4, op="max"))
    out_min = np.asarray(D.ordered_segment_reduce(keys, vals, 4, op="min"))
    assert (out_max == -np.inf).all()
    assert (out_min == np.inf).all()
