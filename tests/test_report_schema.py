"""Schema check for generated benchmark reports: every summary row must
carry the paper's full metric triple (jain_fairness / lat_p95 /
energy_pj_per_op), the trend flags must hold, and every report written
by ``benchmarks/run.py`` (plus the pinned ``baselines.json``) must
carry the provenance block (``benchmarks/_common.provenance``) that
makes its numbers attributable to a git sha / jax version / device.

CI regenerates ``reports/benchmarks.summary.json`` (``run.py --only
summary`` under ``REPRO_BENCH_QUICK=1``) and then runs this module, so
the committed full-resolution report and the CI smoke report are held
to the same schema.  Skips when no summary report has been generated.
"""
import glob
import json
import math
import os

import pytest

from repro.core.metrics import METRIC_TRIPLE

REPORTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "reports")
REPORT = os.path.join(REPORTS_DIR, "benchmarks.summary.json")


@pytest.fixture(scope="module")
def summary():
    if not os.path.exists(REPORT):
        pytest.skip(f"no summary report at {REPORT}; generate with "
                    "`benchmarks/run.py --only summary`")
    with open(REPORT) as f:
        return json.load(f)["summary"]


def test_every_summary_row_carries_metric_triple(summary):
    rows = summary["rows"]
    assert rows, "summary report has no rows"
    for row in rows:
        for k in METRIC_TRIPLE:
            assert k in row, (row.get("workload"), row.get("protocol"), k)
            assert isinstance(row[k], (int, float)), (k, row[k])
            assert math.isfinite(row[k]) and row[k] >= 0.0, (k, row[k])
        assert 0.0 <= row["jain_fairness"] <= 1.0 + 1e-9
        # fairness_span is the one legitimately-absent value (None once
        # a core starves — never an epsilon-divided pseudo-number); 0.0
        # marks the nothing-completed degenerate case
        assert "fairness_span" in row
        assert (row["fairness_span"] is None or row["fairness_span"] == 0.0
                or row["fairness_span"] >= 1.0)


def test_summary_trend_flags_hold(summary):
    head = summary["headline"]
    assert head["pollfree_energy_wins_256"] == 1.0
    assert head["colibri_fair_and_fast_256"] == 1.0
    assert head["min_lrsc_over_colibri_energy_256"] > 1.0


TOPOLOGY_REPORT = os.path.join(REPORTS_DIR, "benchmarks.topology.json")


@pytest.fixture(scope="module")
def topology():
    if not os.path.exists(TOPOLOGY_REPORT):
        pytest.skip(f"no topology report at {TOPOLOGY_REPORT}; generate "
                    "with `benchmarks/run.py --only topology`")
    with open(TOPOLOGY_REPORT) as f:
        return json.load(f)["topology"]


def test_topology_rows_carry_topology_column(topology):
    """Every topology-benchmark row names its NoC (the ``topology``
    column every ``Result.to_row`` now emits), carries the metric
    triple, and bills hops only on hierarchical rows."""
    from repro.core.topologies import names as topo_names
    rows = topology["rows"]
    assert rows, "topology report has no rows"
    assert {r["topology"] for r in rows} >= {"flat", "cluster2"}
    for row in rows:
        assert row["topology"] in topo_names(), row["row"]
        for k in METRIC_TRIPLE:
            assert k in row and math.isfinite(row[k]), (row["row"], k)
        assert row["hops_per_op"] >= 0.0
        if row["topology"] == "flat":
            assert row["hops_per_op"] == 0.0
        elif row["throughput"] > 0:
            assert row["hops_per_op"] > 0.0, row["row"]


def test_topology_headline_contrast(topology):
    """The headline the README quotes: on the cluster2 NoC the
    polling-free waiters beat lrsc, whose retry storm crosses clusters
    on every poll."""
    head = topology["headline"]
    assert head["hier_over_lrsc_cluster2"] > 1.0
    assert head["colibri_over_lrsc_cluster2"] > 1.0
    assert head["lrsc_hops_per_op_cluster2"] > \
        head["hier_hops_per_op_cluster2"]
    assert head["ladder_monotone"] == 1.0


FAULTS_REPORT = os.path.join(REPORTS_DIR, "benchmarks.faults.json")


@pytest.fixture(scope="module")
def faults():
    if not os.path.exists(FAULTS_REPORT):
        pytest.skip(f"no faults report at {FAULTS_REPORT}; generate with "
                    "`benchmarks/run.py --only faults`")
    with open(FAULTS_REPORT) as f:
        return json.load(f)["faults"]


def test_fault_rows_carry_degradation_columns(faults):
    """Every faults-benchmark row reports the graceful-degradation
    metric set, and the liveness-contrast rows additionally carry the
    retention ratio vs their healthy twin."""
    rows = faults["rows"]
    assert rows, "faults report has no rows"
    for row in rows:
        for k in ("progress_ok", "faults_injected", "recoveries",
                  "stalled_cores", "survivor_throughput", "survivor_jain",
                  "halt_cyc", "watchdog_cyc"):
            assert k in row, (row["row"], k)
        assert isinstance(row["progress_ok"], bool)
        assert row["faults_injected"] >= 0 and row["recoveries"] >= 0
        assert math.isfinite(row["survivor_throughput"])
        assert 0.0 <= row["survivor_jain"] <= 1.0 + 1e-9
        if row["row"].startswith("kill_"):
            assert "throughput_retention" in row
            assert math.isfinite(row["throughput_retention"])


def test_fault_headline_liveness_contrast(faults):
    """The headline invariant the README quotes: with the watchdog
    every benchmarked protocol stays live under the owner kill; with it
    off, every deadlockable protocol's halt is detected."""
    head = faults["headline"]
    assert (head["protocols_live_with_watchdog"]
            == head["protocols_total"])
    assert (head["deadlocks_detected_without_watchdog"]
            == head["deadlockable_protocols"])
    for k, v in head.items():
        if k.endswith("_retention_lrscwait") or k.startswith("kill_wd_"):
            assert v > 0.0, (k, v)


# ---------------------------------------------------------------------------
# provenance: every generated report is attributable
# ---------------------------------------------------------------------------

def _report_paths():
    return sorted(glob.glob(os.path.join(REPORTS_DIR, "benchmarks*.json"))
                  + glob.glob(os.path.join(REPORTS_DIR, "baselines.json")))


@pytest.mark.parametrize("path", _report_paths() or ["<none>"])
def test_reports_carry_provenance(path):
    if path == "<none>":
        pytest.skip("no reports generated yet")
    with open(path) as f:
        doc = json.load(f)
    assert "provenance" in doc, f"{os.path.basename(path)} lacks provenance"
    prov = doc["provenance"]
    for key in ("git_sha", "jax", "jaxlib", "device", "backend",
                "timestamp"):
        assert isinstance(prov.get(key), str) and prov[key], (path, key)
    assert isinstance(prov.get("n_devices"), int) and prov["n_devices"] >= 1
    assert isinstance(prov.get("quick"), bool)
    # ISO-8601 UTC, second resolution — "2026-08-08T12:34:56+00:00"
    assert "T" in prov["timestamp"] and prov["timestamp"].endswith("+00:00")


def test_run_reports_have_sweep_instrumentation():
    """Reports produced by the instrumented driver carry the per-chunk
    compile/execute RunReport block for each benchmark section."""
    checked = 0
    for path in _report_paths():
        with open(path) as f:
            doc = json.load(f)
        for name, section in doc.items():
            if not isinstance(section, dict) or "run_report" not in section:
                continue
            rep = section["run_report"]
            assert {"backend", "n_chunks", "n_points", "compile_s",
                    "execute_s", "chunks"} <= set(rep), (path, name)
            assert rep["n_chunks"] == len(rep["chunks"])
            for ch in rep["chunks"]:
                assert ch["points"] >= 1 and ch["compile_s"] >= 0
            checked += 1
    if not checked:
        pytest.skip("no instrumented reports generated yet")
