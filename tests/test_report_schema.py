"""Schema check for generated benchmark reports: every summary row must
carry the paper's full metric triple (jain_fairness / lat_p95 /
energy_pj_per_op) and the trend flags must hold.

CI regenerates ``reports/benchmarks.summary.json`` (``run.py --only
summary`` under ``REPRO_BENCH_QUICK=1``) and then runs this module, so
the committed full-resolution report and the CI smoke report are held
to the same schema.  Skips when no summary report has been generated.
"""
import json
import math
import os

import pytest

from repro.core.metrics import METRIC_TRIPLE

REPORT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "reports", "benchmarks.summary.json")


@pytest.fixture(scope="module")
def summary():
    if not os.path.exists(REPORT):
        pytest.skip(f"no summary report at {REPORT}; generate with "
                    "`benchmarks/run.py --only summary`")
    with open(REPORT) as f:
        return json.load(f)["summary"]


def test_every_summary_row_carries_metric_triple(summary):
    rows = summary["rows"]
    assert rows, "summary report has no rows"
    for row in rows:
        for k in METRIC_TRIPLE:
            assert k in row, (row.get("workload"), row.get("protocol"), k)
            assert isinstance(row[k], (int, float)), (k, row[k])
            assert math.isfinite(row[k]) and row[k] >= 0.0, (k, row[k])
        assert 0.0 <= row["jain_fairness"] <= 1.0 + 1e-9
        # fairness_span is the one legitimately-absent value (None once
        # a core starves — never an epsilon-divided pseudo-number); 0.0
        # marks the nothing-completed degenerate case
        assert "fairness_span" in row
        assert (row["fairness_span"] is None or row["fairness_span"] == 0.0
                or row["fairness_span"] >= 1.0)


def test_summary_trend_flags_hold(summary):
    head = summary["headline"]
    assert head["pollfree_energy_wins_256"] == 1.0
    assert head["colibri_fair_and_fast_256"] == 1.0
    assert head["min_lrsc_over_colibri_energy_256"] > 1.0
