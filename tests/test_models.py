"""Per-arch smoke tests + prefill/decode equivalence.

Each assigned architecture instantiates a REDUCED same-family config and runs
one forward/train step on CPU asserting output shapes and finiteness; the
serving tests prove decode-with-cache matches the full forward teacher-forced
logits (the core serving invariant).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import ShapeSpec
from repro.distributed.sharding import Policy
from repro.models import build, make_batch

POL = Policy()
SMOKE = ShapeSpec("smoke", 32, 2, "train")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step(name, rng):
    cfg = get_config(name + "-smoke")
    m = build(cfg)
    params = m.init(rng)
    batch = make_batch(cfg, SMOKE, jax.random.PRNGKey(1))

    def loss_fn(p):
        return m.loss(p, batch, POL)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), name
    flat = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in flat), f"{name}: non-finite grads"
    # one SGD step must change the loss
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                              params, grads)
    loss2 = jax.jit(loss_fn)(new_params)
    assert loss2 < loss, f"{name}: SGD step did not reduce loss"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_output_shapes(name, rng):
    cfg = get_config(name + "-smoke")
    m = build(cfg)
    params = m.init(rng)
    batch = make_batch(cfg, SMOKE, jax.random.PRNGKey(1))
    lg = jax.jit(lambda p, b: m.logits(p, b, POL))(params, batch)
    assert lg.shape == (SMOKE.global_batch, SMOKE.seq_len, cfg.vocab_size)
    assert jnp.isfinite(lg).all()


DECODE_ARCHS = ["smollm-135m", "qwen2-7b", "deepseek-v3-671b",
                "recurrentgemma-2b", "rwkv6-1.6b", "whisper-large-v3",
                "kimi-k2-1t-a32b"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_prefill_decode_matches_forward(name, rng):
    """Teacher-forced forward logits == prefill(prompt) + stepwise decode."""
    cfg = get_config(name + "-smoke")
    if cfg.moe is not None:
        # avoid capacity-drop mismatches between T=prompt and T=1 dispatch
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    m = build(cfg)
    params = m.init(rng)
    s_total, s_prompt = 12, 8
    batch = make_batch(cfg, ShapeSpec("t", s_total, 2, "train"),
                       jax.random.PRNGKey(1))
    full_batch = dict(batch)
    full_logits = jax.jit(lambda p, b: m.logits(p, b, POL))(params, full_batch)

    # prefill prompt, then decode the remaining tokens one by one
    pre_batch = {k: (v[:, :s_prompt] if k in ("tokens", "labels") else v)
                 for k, v in batch.items()}
    hidden, cache = jax.jit(
        lambda p, b: m.prefill(p, b, s_total, POL))(params, pre_batch)

    step = jax.jit(lambda p, c, t, pos: m.decode_step(p, c, t, pos, POL))
    for t in range(s_prompt, s_total):
        tok = batch["tokens"][:, t: t + 1]
        pos = jnp.full((2,), t, jnp.int32)
        lg, cache = step(params, cache, tok, pos)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-2,
        )


def test_local_attention_ring_buffer():
    """Sliding-window decode with a ring buffer matches full-seq local attn."""
    cfg = get_config("recurrentgemma-2b-smoke")
    cfg = dataclasses.replace(cfg, local_window=8)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    s_total, s_prompt = 24, 4            # decode well past the window
    batch = make_batch(cfg, ShapeSpec("t", s_total, 2, "train"),
                       jax.random.PRNGKey(1))
    full_logits = jax.jit(lambda p, b: m.logits(p, b, POL))(params, batch)
    pre = {"tokens": batch["tokens"][:, :s_prompt]}
    _, cache = jax.jit(lambda p, b: m.prefill(p, b, s_total, POL))(params, pre)
    step = jax.jit(lambda p, c, t, pos: m.decode_step(p, c, t, pos, POL))
    for t in range(s_prompt, s_total):
        tok = batch["tokens"][:, t: t + 1]
        pos = jnp.full((2,), t, jnp.int32)
        lg, cache = step(params, cache, tok, pos)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)
