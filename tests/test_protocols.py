"""Protocol-plugin engine regression + new-protocol invariants.

The golden values below were captured from the seed monolithic
``sim.py`` (pre-refactor, commit 5dacbd5) — every seed protocol must
produce *identical* ``ops``/``msgs``/``polls``/... through the plugin
engine.  The new registry-only protocols (``ticket_lock``,
``colibri_hier``) are checked against their defining invariants:
FIFO/fairness for the ticket dispenser, polling-freedom + cluster
round-robin fairness for hierarchical Colibri.
"""
import numpy as np
import pytest

from repro.core import protocols
from repro.core.protocols.base import Protocol
from repro.core.sim import PROTOCOLS, SimParams, run

# the three capture configurations (seed=7..9 style stamps varied per cfg)
GOLDEN_CONFIGS = (
    dict(n_cores=64, n_addrs=1, cycles=3000, seed=1),
    dict(n_cores=64, n_addrs=16, cycles=3000, seed=2),
    dict(n_cores=128, n_addrs=4, cycles=2000, lat=3, work=6, modify=2,
         net_bw=32, seed=3),
)

# ops/msgs/polls/... of the SEED simulator for protocol/config-index
GOLDEN = {
 "amo/0": {"ops": 2990, "msgs": 5990, "polls": 0, "sleep_cyc": 0,
           "backoff_cyc": 0, "bank_ops": 2995, "net_stall": 0,
           "ops_min": 46, "ops_max": 47},
 "amo/1": {"ops": 9596, "msgs": 19200, "polls": 0, "sleep_cyc": 0,
           "backoff_cyc": 0, "bank_ops": 9600, "net_stall": 0,
           "ops_min": 149, "ops_max": 150},
 "amo/2": {"ops": 7976, "msgs": 15976, "polls": 0, "sleep_cyc": 0,
           "backoff_cyc": 0, "bank_ops": 7988, "net_stall": 5,
           "ops_min": 62, "ops_max": 63},
 "lrsc/0": {"ops": 164, "msgs": 3004, "polls": 585, "sleep_cyc": 0,
            "backoff_cyc": 163358, "bank_ops": 1502, "net_stall": 0,
            "ops_min": 0, "ops_max": 16},
 "lrsc/1": {"ops": 1537, "msgs": 8384, "polls": 550, "sleep_cyc": 0,
            "backoff_cyc": 125958, "bank_ops": 4192, "net_stall": 0,
            "ops_min": 5, "ops_max": 41},
 "lrsc/2": {"ops": 531, "msgs": 5614, "polls": 868, "sleep_cyc": 0,
            "backoff_cyc": 224991, "bank_ops": 2807, "net_stall": 5,
            "ops_min": 0, "ops_max": 18},
 "lrscwait/0": {"ops": 226, "msgs": 1028, "polls": 0, "sleep_cyc": 183068,
                "backoff_cyc": 0, "bank_ops": 514, "net_stall": 0,
                "ops_min": 3, "ops_max": 4},
 "lrscwait/1": {"ops": 3621, "msgs": 14594, "polls": 0, "sleep_cyc": 85759,
                "backoff_cyc": 0, "bank_ops": 7297, "net_stall": 0,
                "ops_min": 55, "ops_max": 58},
 "lrscwait/2": {"ops": 1124, "msgs": 4736, "polls": 0, "sleep_cyc": 234432,
                "backoff_cyc": 0, "bank_ops": 2368, "net_stall": 5,
                "ops_min": 8, "ops_max": 9},
 "colibri/0": {"ops": 196, "msgs": 1818, "polls": 0, "sleep_cyc": 183939,
               "backoff_cyc": 0, "bank_ops": 455, "net_stall": 0,
               "ops_min": 3, "ops_max": 4},
 "colibri/1": {"ops": 3161, "msgs": 24720, "polls": 0, "sleep_cyc": 98536,
               "backoff_cyc": 0, "bank_ops": 6374, "net_stall": 0,
               "ops_min": 48, "ops_max": 51},
 "colibri/2": {"ops": 874, "msgs": 7488, "polls": 0, "sleep_cyc": 238668,
               "backoff_cyc": 0, "bank_ops": 1874, "net_stall": 19,
               "ops_min": 6, "ops_max": 7},
 "amo_lock/0": {"ops": 174, "msgs": 2732, "polls": 1017, "sleep_cyc": 0,
                "backoff_cyc": 172636, "bank_ops": 1366, "net_stall": 0,
                "ops_min": 0, "ops_max": 9},
 "amo_lock/1": {"ops": 1632, "msgs": 8076, "polls": 764, "sleep_cyc": 0,
                "backoff_cyc": 128388, "bank_ops": 4038, "net_stall": 0,
                "ops_min": 9, "ops_max": 56},
 "amo_lock/2": {"ops": 580, "msgs": 5062, "polls": 1367, "sleep_cyc": 0,
                "backoff_cyc": 233012, "bank_ops": 2531, "net_stall": 5,
                "ops_min": 0, "ops_max": 18},
 "lrsc_lock/0": {"ops": 121, "msgs": 4734, "polls": 1001, "sleep_cyc": 0,
                 "backoff_cyc": 169020, "bank_ops": 1244, "net_stall": 0,
                 "ops_min": 0, "ops_max": 9},
 "lrsc_lock/1": {"ops": 1239, "msgs": 10592, "polls": 780, "sleep_cyc": 0,
                 "backoff_cyc": 131471, "bank_ops": 3269, "net_stall": 0,
                 "ops_min": 3, "ops_max": 37},
 "lrsc_lock/2": {"ops": 451, "msgs": 8186, "polls": 1368, "sleep_cyc": 0,
                 "backoff_cyc": 230369, "bank_ops": 2272, "net_stall": 39,
                 "ops_min": 0, "ops_max": 17},
 "mwait_lock/0": {"ops": 196, "msgs": 1426, "polls": 0, "sleep_cyc": 183939,
                  "backoff_cyc": 0, "bank_ops": 455, "net_stall": 0,
                  "ops_min": 3, "ops_max": 4},
 "mwait_lock/1": {"ops": 3161, "msgs": 18760, "polls": 0,
                  "sleep_cyc": 98536, "backoff_cyc": 0, "bank_ops": 6374,
                  "net_stall": 0, "ops_min": 48, "ops_max": 51},
 "mwait_lock/2": {"ops": 874, "msgs": 5736, "polls": 0, "sleep_cyc": 238668,
                  "backoff_cyc": 0, "bank_ops": 1874, "net_stall": 19,
                  "ops_min": 6, "ops_max": 7},
}

# finite-queue rejection path and congested-link worker configs
GOLDEN_EXTRA = {
 "lrscwait_q8": (dict(n_cores=64, n_addrs=1, q_slots=8, cycles=3000, seed=4),
                 {"ops": 222, "msgs": 2024, "polls": 560, "sleep_cyc": 20630,
                  "backoff_cyc": 156604, "bank_ops": 1012, "net_stall": 0,
                  "ops_min": 0, "ops_max": 10}),
 "lrsc_workers": (dict(protocol="lrsc", n_cores=64, n_addrs=1, n_workers=8,
                       net_bw=13, hol_block=16, cycles=3000, backoff=128,
                       backoff_exp=1, seed=5),
                  {"ops": 169, "msgs": 4452, "polls": 940, "sleep_cyc": 0,
                   "backoff_cyc": 131007, "bank_ops": 2226, "net_stall": 177,
                   "w_served": 11998, "ops_min": 0, "ops_max": 8}),
 "colibri_workers": (dict(protocol="colibri", n_cores=64, n_addrs=1,
                          n_workers=8, net_bw=13, hol_block=16, cycles=3000,
                          backoff=128, backoff_exp=1, seed=5),
                     {"ops": 196, "msgs": 1790, "polls": 0,
                      "sleep_cyc": 160443, "backoff_cyc": 0, "bank_ops": 448,
                      "net_stall": 354, "w_served": 11993,
                      "ops_min": 0, "ops_max": 4}),
}


def _observe(r):
    obs = {"ops": int(r["ops"].sum()), "msgs": int(r["msgs"]),
           "polls": int(r["polls"]), "sleep_cyc": int(r["sleep_cyc"]),
           "backoff_cyc": int(r["backoff_cyc"]),
           "bank_ops": int(r["bank_ops"]), "net_stall": int(r["net_stall"]),
           "ops_min": int(r["ops"].min()), "ops_max": int(r["ops"].max())}
    if "w_served" in r:
        obs["w_served"] = int(np.asarray(r["w_served"]).sum())
    return obs


@pytest.mark.parametrize("proto", PROTOCOLS)
def test_plugin_engine_matches_seed_golden(proto):
    """All seven seed protocols are numerically identical through the
    registry-based engine."""
    for i, cfg in enumerate(GOLDEN_CONFIGS):
        r = run(SimParams(protocol=proto, **cfg))
        obs = _observe(r)
        want = GOLDEN[f"{proto}/{i}"]
        assert {k: obs[k] for k in want} == want, (proto, i)


@pytest.mark.parametrize("unroll", (2, 8))
@pytest.mark.parametrize("proto", PROTOCOLS)
def test_golden_invariant_under_unroll(proto, unroll):
    """The scan unroll factor is a pure compilation knob: every seed
    protocol reproduces its golden values at unroll=2 and unroll=8
    exactly (the default unroll=1 path is covered by the test above).
    Both golden configs share one static fingerprint, so each
    (protocol, unroll) pair costs a single 2-point vmapped compile."""
    from repro.core.sweep import sweep
    cfgs = [SimParams(protocol=proto, unroll=unroll, **cfg)
            for cfg in GOLDEN_CONFIGS[:2]]
    for i, r in enumerate(sweep(cfgs)):
        obs = _observe(r)
        want = GOLDEN[f"{proto}/{i}"]
        assert {k: obs[k] for k in want} == want, (proto, unroll, i)


@pytest.mark.parametrize("name", sorted(GOLDEN_EXTRA))
def test_plugin_engine_matches_seed_golden_extra(name):
    cfg, want = GOLDEN_EXTRA[name]
    cfg = dict(cfg)
    proto = cfg.pop("protocol", "lrscwait")
    r = run(SimParams(protocol=proto, **cfg))
    obs = _observe(r)
    assert {k: obs[k] for k in want} == want, name


def test_registry_contents_and_errors():
    names = protocols.names()
    for p in PROTOCOLS + ("ticket_lock", "colibri_hier"):
        assert p in names
    with pytest.raises(KeyError):
        protocols.get("no_such_protocol")
    with pytest.raises(ValueError):           # duplicate name rejected
        @protocols.register
        class Dup(Protocol):
            name = "colibri"
    with pytest.raises(ValueError):           # anonymous plugin rejected
        protocols.register(Protocol)


def test_ticket_lock_fifo_fairness():
    """Ticket dispenser grants strictly in draw order: per-core completed
    ops stay within one ticket round of each other, unlike the random
    test&set winner of amo_lock."""
    kw = dict(n_addrs=1, n_cores=64, cycles=8000, backoff=128, backoff_exp=1)
    tkt = run(SimParams(protocol="ticket_lock", **kw))
    amo = run(SimParams(protocol="amo_lock", **kw))
    assert int(tkt["ops"].sum()) > 0
    assert int(tkt["polls"]) > 0                        # still a spin lock
    t_span = int(tkt["ops"].max()) - int(tkt["ops"].min())
    a_span = int(amo["ops"].max()) - int(amo["ops"].min())
    assert t_span <= 2                                  # FIFO service
    assert t_span < a_span                              # fairer than t&s


def test_colibri_hier_polling_free_and_fair():
    """Hierarchical Colibri keeps the paper's headline properties: no
    retries/polls ever, contenders sleep, and the turn budget bounds
    cross-group unfairness."""
    r = run(SimParams(protocol="colibri_hier", n_cores=64, n_addrs=1,
                      cycles=8000))
    assert int(r["polls"]) == 0
    assert int(r["sleep_cyc"]) > 0
    span = int(r["ops"].max()) - int(r["ops"].min())
    assert span <= 3, span                             # round-robin groups
    # conservation: bank ops == acquire+release traffic of completed ops
    assert int(r["ops"].sum()) > 0


def test_colibri_hier_tracks_flat_colibri():
    """Cluster-local wakes should not lose throughput against flat
    Colibri; at high contention they win (cheaper handoffs)."""
    for bins in (1, 16):
        hier = run(SimParams(protocol="colibri_hier", n_cores=64,
                             n_addrs=bins, cycles=8000))
        flat = run(SimParams(protocol="colibri", n_cores=64, n_addrs=bins,
                             cycles=8000))
        assert hier["throughput"] >= 0.8 * flat["throughput"]
    assert int(hier["polls"]) == 0


def test_colibri_hier_group_count_axis():
    """More groups = more (smaller) local queues; all group counts stay
    polling-free and make progress."""
    for g in (1, 2, 8):
        r = run(SimParams(protocol="colibri_hier", n_groups=g, n_cores=64,
                          n_addrs=2, cycles=5000))
        assert int(r["polls"]) == 0
        assert int(r["ops"].sum()) > 0


def test_degenerate_worker_configs_report_zero():
    """n_workers == n_cores leaves no atomic cores: metrics are 0.0, not a
    crash on empty slices."""
    r = run(SimParams(protocol="colibri", n_cores=8, n_workers=8, n_addrs=1,
                      cycles=500))
    assert r["throughput"] == 0.0
    assert r["fairness_min"] == 0.0 and r["fairness_max"] == 0.0
    assert r["worker_rate"] > 0.0
