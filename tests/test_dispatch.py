"""Property tests for the colibri ordered-commit primitive (core.dispatch).

The invariants are the paper's protocol guarantees mapped to SPMD:
FIFO queue positions (starvation freedom), exactly-once commit, and
equivalence with the retry-based (scatter-add) baseline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import dispatch as D


@st.composite
def keys_values(draw):
    n = draw(st.integers(1, 300))
    bins = draw(st.integers(1, 40))
    keys = draw(st.lists(st.integers(0, bins - 1), min_size=n, max_size=n))
    vals = draw(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                         min_size=n, max_size=n))
    return np.array(keys, np.int32), np.array(vals, np.float32), bins


@settings(max_examples=50, deadline=None)
@given(kv=keys_values())
def test_ordered_segment_sum_matches_scatter_add(kv):
    keys, vals, bins = kv
    out = D.ordered_segment_sum(jnp.array(keys), jnp.array(vals), bins)
    ref = np.zeros(bins, np.float64)
    np.add.at(ref, keys, vals.astype(np.float64))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-3)


@settings(max_examples=50, deadline=None)
@given(kv=keys_values())
def test_queue_positions_fifo(kv):
    keys, _, bins = kv
    qp, counts = D.queue_positions(jnp.array(keys), bins)
    qp, counts = np.asarray(qp), np.asarray(counts)
    for b in range(bins):
        idx = np.where(keys == b)[0]
        # arrival (program) order = queue order: starvation freedom
        assert (qp[idx] == np.arange(len(idx))).all()
        assert counts[b] == len(idx)


@settings(max_examples=50, deadline=None)
@given(kv=keys_values(), cap=st.integers(1, 16))
def test_capacity_keeps_oldest(kv, cap):
    """LRSCwait_q semantics: under capacity pressure the OLDEST q requests
    win (FIFO), never a random subset."""
    keys, _, bins = kv
    d = D.dispatch(jnp.array(keys), bins, capacity=cap)
    keep = np.asarray(d.keep)
    for b in range(bins):
        idx = np.where(keys == b)[0]
        expected = np.zeros(len(idx), bool)
        expected[:cap] = True
        assert (keep[idx] == expected).all()


@settings(max_examples=30, deadline=None)
@given(kv=keys_values(), cap=st.integers(1, 8))
def test_dispatch_indices_exactly_once(kv, cap):
    """Each slot is committed at most once; each kept request appears in
    exactly one slot (the 'commit exactly once' property)."""
    keys, _, bins = kv
    src, valid, d = D.dispatch_indices(jnp.array(keys), bins, cap)
    src, valid = np.asarray(src), np.asarray(valid)
    occupants = src[valid]
    assert len(np.unique(occupants)) == len(occupants)
    assert len(occupants) == int(np.asarray(d.keep).sum())
    # every occupant's key matches its row
    for b in range(bins):
        occ = src[b][valid[b]]
        assert (keys[occ] == b).all()


@settings(max_examples=30, deadline=None)
@given(kv=keys_values())
def test_roundtrip_combine(kv):
    """dispatch → buffer → combine_from_slots reconstructs each request's
    value exactly (gather inverse of the ordered scatter)."""
    keys, vals, bins = kv
    cap = len(keys)  # no drops
    src, valid, d = D.dispatch_indices(jnp.array(keys), bins, cap)
    payload = jnp.where(valid[..., None],
                        jnp.array(vals)[jnp.minimum(src, len(vals) - 1)][..., None],
                        0.0)
    back = D.combine_from_slots(payload, jnp.array(keys), d.queue_pos, d.keep)
    np.testing.assert_allclose(np.asarray(back)[:, 0], vals, rtol=1e-6)


def test_segment_reduce_ops():
    keys = jnp.array([0, 1, 0, 2, 1, 0])
    vals = jnp.array([1.0, 5.0, -2.0, 7.0, 3.0, 4.0])
    out_max = D.ordered_segment_reduce(keys, vals, 4, op="max")
    np.testing.assert_allclose(np.asarray(out_max)[:3], [4.0, 5.0, 7.0])
    out_min = D.ordered_segment_reduce(keys, vals, 4, op="min")
    np.testing.assert_allclose(np.asarray(out_min)[:3], [-2.0, 3.0, 7.0])


def test_histogram_matches_bincount():
    keys = jnp.array(np.random.RandomState(0).randint(0, 64, size=5000))
    h = D.histogram(keys, 64)
    np.testing.assert_array_equal(np.asarray(h),
                                  np.bincount(np.asarray(keys), minlength=64))
