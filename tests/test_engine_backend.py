"""Backend equivalence: the Pallas fused-step path vs the scan oracle.

The engine's ``backend`` knob is a pure execution choice — the fused
Pallas kernel (``repro.kernels.engine_step``) must reproduce the XLA
``lax.scan`` path BIT-identically, not approximately: every state array,
every counter, every histogram bucket.  ``pallas_interpret`` runs the
exact kernel dataflow on CPU, so these tests pin the kernel on hosts
with no accelerator (on TPU/GPU the same pallas_call lowers natively).

Also here: the backend knob's construction-time validation (unknown
names, missing devices) and the sweep/Study fingerprint behaviour
(backend is a static field — mixed-backend studies must chunk into
per-backend compilation groups, never share one trace).
"""
import numpy as np
import pytest

from repro.core import protocols, sweep, workloads
from repro.core.sim import (SimParams, _run, available_backends,
                            resolve_backend)
from repro.sync import Spec, Study

SMALL = dict(n_cores=16, cycles=1200)


def _assert_runs_equal(r0, r1):
    assert set(r0) == set(r1)
    for k in sorted(r0):
        np.testing.assert_array_equal(np.asarray(r0[k]), np.asarray(r1[k]),
                                      err_msg=f"field {k!r} diverged")


def _pair(**kw):
    r0 = _run(SimParams(backend="xla_cpu", **kw))
    r1 = _run(SimParams(backend="pallas_interpret", **kw))
    _assert_runs_equal(r0, r1)


# ---------------------------------------------------------------------------
# bit-identity across the full protocol × workload grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload", workloads.names())
@pytest.mark.parametrize("protocol", protocols.names())
def test_backend_bit_identical(protocol, workload):
    wl = workloads.get(workload)
    _pair(protocol=protocol, workload=workload,
          n_addrs=max(4, wl.min_addrs), **SMALL)


def test_backend_bit_identical_traced():
    """record_trace shapes the scan carry differently — cover it too."""
    _pair(protocol="colibri", n_addrs=4, record_trace=True, **SMALL)


@pytest.mark.parametrize("protocol", ["colibri", "colibri_hier",
                                      "ticket_lock"])
def test_backend_bit_identical_tiled(protocol):
    """Multi-tile launch: 512 banks -> 2 bank tiles of 256, 2048 cores
    -> 2 in-kernel core chunks of 1024.  Exercises the block-local
    protocol restatement (global vs block-local bank/queue ids)."""
    _pair(protocol=protocol, n_cores=2048, n_addrs=512, cycles=240)


def test_backend_bit_identical_under_sweep():
    """The vmapped sweep path (traced lat axis) agrees across backends."""
    pts = [SimParams(protocol="lrscwait", n_addrs=4, backend=b, lat=lat,
                     n_cores=16, cycles=800)
           for b in ("xla_cpu", "pallas_interpret") for lat in (3, 5)]
    res = {i: r for i, r in sweep.sweep_iter(pts)}
    for i in (0, 1):
        _assert_runs_equal(res[i], res[2 + i])


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

def test_unknown_backend_raises_naming_available():
    with pytest.raises(ValueError, match="available backends.*xla_cpu"):
        SimParams(backend="cuda")
    with pytest.raises(ValueError, match="available backends"):
        Spec(backend="cuda")


def test_missing_device_backend_fails_fast():
    """pallas_gpu/pallas_tpu without the device fail at construction,
    never deep inside a jit trace."""
    for b in ("pallas_gpu", "pallas_tpu"):
        if b in available_backends():
            continue                     # accelerator host: legal there
        with pytest.raises(ValueError, match="requires a"):
            SimParams(backend=b)
        with pytest.raises(ValueError, match="requires a"):
            Spec(backend=b)


def test_auto_backend_resolves_to_available():
    assert "auto" in available_backends()
    assert resolve_backend("auto") in ("xla_cpu", "pallas_gpu",
                                       "pallas_tpu")
    assert resolve_backend("xla_cpu") == "xla_cpu"


def test_spec_backend_roundtrip():
    s = Spec(protocol="lrsc", backend="pallas_interpret")
    assert s.costs.backend == "pallas_interpret"
    assert s.to_params().backend == "pallas_interpret"
    assert Spec.from_json(s.to_json()) == s
    assert s.replace(backend="xla_cpu").to_params().backend == "xla_cpu"


# ---------------------------------------------------------------------------
# sweep fingerprint / Study grouping
# ---------------------------------------------------------------------------

def test_backend_joins_sweep_fingerprint():
    assert "backend" in sweep.STATIC_FIELDS
    base = dict(protocol="lrscwait", n_cores=16, n_addrs=4, cycles=800)
    k_x3 = sweep._static_key(SimParams(backend="xla_cpu", lat=3, **base))
    k_x5 = sweep._static_key(SimParams(backend="xla_cpu", lat=5, **base))
    k_p3 = sweep._static_key(SimParams(backend="pallas_interpret", lat=3,
                                       **base))
    assert k_x3 == k_x5                  # lat is a dyn axis: same group
    assert k_x3 != k_p3                  # backends never share one trace


def test_study_mixed_backend_grouping():
    """A mixed-backend Study chunks into per-backend groups and the
    paired points still agree bit-for-bit on the raw stats."""
    st = Study(protocol="lrscwait", n_cores=16, n_addrs=4, cycles=800) \
        .grid(backend=["xla_cpu", "pallas_interpret"], lat=[3, 5])
    results = st.run()
    assert len(results) == 4
    by = {(r.spec.costs.backend, r.spec.costs.lat): r for r in results}
    for lat in (3, 5):
        a = by[("xla_cpu", lat)].stats
        b = by[("pallas_interpret", lat)].stats
        _assert_runs_equal(a, b)
