"""Cycle-level simulator sanity + paper-claim calibration tests.

These encode the paper's quantitative claims as regression bounds so the
reproduction cannot silently drift (EXPERIMENTS.md reports exact numbers).
"""
import numpy as np
import pytest

from repro.core.costmodel import (PAPER_AREA, PAPER_ENERGY, energy_per_op,
                                  fit_area, fit_energy, system_overhead,
                                  tile_area)
from repro.core.sim import PROTOCOLS, SimParams, run

CYCLES = 8000


def thr(proto, bins, **kw):
    return run(SimParams(protocol=proto, n_addrs=bins, cycles=CYCLES,
                         **kw))["throughput"]


def test_amo_is_roofline():
    """Fig. 3: atomic add bounds every generic-RMW protocol."""
    for bins in (1, 64, 1024):
        amo = thr("amo", bins)
        for proto in ("lrsc", "lrscwait", "colibri"):
            assert thr(proto, bins) <= amo * 1.05


def test_colibri_near_ideal():
    """Fig. 3: Colibri ≈ LRSCwait_ideal across all contention levels,
    with only a slight penalty from node-update round trips."""
    for bins in (1, 16, 256):
        ideal = thr("lrscwait", bins)
        col = thr("colibri", bins)
        assert col >= 0.75 * ideal
        assert col <= ideal * 1.05


def test_lrscwait_q_degrades_above_capacity():
    """Fig. 3: finite-q LRSCwait degrades once contention > q slots (rejected
    LRwaits fail immediately and fall back to retry traffic)."""
    full = run(SimParams(protocol="lrscwait", n_addrs=1, q_slots=256,
                         cycles=CYCLES))
    q8 = run(SimParams(protocol="lrscwait", n_addrs=1, q_slots=8,
                       cycles=CYCLES))
    assert q8["throughput"] < 0.85 * full["throughput"]
    assert int(q8["polls"]) > 1000               # rejects retry (polling)
    assert int(full["polls"]) == 0


def test_paper_headline_throughput_ratios():
    """6.5x at high contention, ~13% at low contention (±35% band)."""
    hi = thr("colibri", 1) / thr("lrsc", 1)
    assert 4.0 < hi < 9.0, hi
    lo = thr("colibri", 256) / thr("lrsc", 256)
    assert 1.02 < lo < 1.45, lo


def test_polling_free():
    """LRSCwait/Colibri never poll (no failed attempts); LRSC does."""
    r_col = run(SimParams(protocol="colibri", n_addrs=1, cycles=CYCLES))
    r_lrsc = run(SimParams(protocol="lrsc", n_addrs=1, cycles=CYCLES))
    assert int(r_col["polls"]) == 0
    assert int(r_lrsc["polls"]) > 100
    assert int(r_col["sleep_cyc"]) > 0          # contenders actually sleep


def test_interference_fig5():
    """Fig. 5: 252 pollers crush LRSC workers; Colibri workers unaffected."""
    kw = dict(n_addrs=1, n_workers=4, net_bw=13, hol_block=16,
              cycles=CYCLES, backoff=128, backoff_exp=1)
    def rel(proto):
        r = run(SimParams(protocol=proto, **kw))
        base = run(SimParams(protocol=proto, n_cores=4, **kw))
        return r["worker_rate"] / base["worker_rate"]
    assert rel("colibri") > 0.9
    assert rel("lrsc") < 0.5                     # paper: 0.26


def test_queue_fairness_fig6():
    """Fig. 6: Colibri distributes ops evenly; LRSC concentrates them.
    Jain's index is the primary metric (bounded, meaningful even when a
    core starves); the NaN-safe span backs the same claim."""
    r_col = run(SimParams(protocol="colibri", n_addrs=2, cycles=CYCLES))
    r_lrsc = run(SimParams(protocol="lrsc", n_addrs=2, cycles=CYCLES))
    assert r_col["jain_fairness"] > r_lrsc["jain_fairness"]
    assert r_col["jain_fairness"] > 0.9
    assert r_col["fairness_span"] < 3.0          # finite: nobody starved


def test_queue_throughput_scaling_fig6():
    """Fig. 6 (concurrent queue, 2 hot addresses, link-update RMWs, fixed
    backoff): Colibri sustains flat throughput to 256 cores and beats LRSC
    everywhere; LRSC collapses at scale. NOTE: the collapse onset in our
    machine model is at 256 cores (paper: 64) — documented calibration
    residual in EXPERIMENTS.md."""
    kw = dict(modify=8, backoff=128, backoff_exp=1)
    col = {n: thr("colibri", 2, n_cores=n, **kw) for n in (8, 64, 256)}
    lrsc = {n: thr("lrsc", 2, n_cores=n, **kw) for n in (8, 64, 256)}
    for n in (8, 64, 256):
        assert col[n] > lrsc[n]                  # colibri best everywhere
    assert col[8] / lrsc[8] > 1.4                # paper: 1.54x at 8 cores
    assert col[256] / lrsc[256] > 2.5            # collapse at scale
    assert col[256] > 0.8 * col[8]               # colibri sustained


def test_area_model_matches_table1():
    fit = fit_area()
    for name, (param, kge) in PAPER_AREA.items():
        design = name.rsplit("_", 1)[0]
        model = tile_area(design, param, fit)
        assert abs(model - kge) / kge < 0.02, (name, model, kge)


def test_colibri_area_scales_linearly():
    """Section IV: Colibri state is O(n + 2m); ideal LRSCwait O(n log n m)."""
    c1 = system_overhead("colibri", 256, 1024)
    c2 = system_overhead("colibri", 512, 2048)
    assert c2 / c1 == pytest.approx(2.0, rel=0.01)
    i1 = system_overhead("lrscwait_ideal", 256, 1024)
    i2 = system_overhead("lrscwait_ideal", 512, 2048)
    assert i2 / i1 > 4.0                          # superlinear


def test_energy_model_table2():
    from repro.core.metrics import energy_stats
    stats = {}
    for proto in ("amo", "colibri", "lrsc", "amo_lock"):
        r = run(SimParams(protocol=proto, n_addrs=1, cycles=CYCLES))
        stats[proto] = energy_stats(r)
    fit = fit_energy(stats)
    for proto, target in PAPER_ENERGY.items():
        model = energy_per_op(stats[proto], fit)
        assert abs(model - target) / target < 0.40, (proto, model, target)
    # ordering: amo << colibri << lrsc, amo_lock
    e = {p: energy_per_op(stats[p], fit) for p in stats}
    assert e["amo"] < e["colibri"] < e["lrsc"]
    assert e["colibri"] < e["amo_lock"]
