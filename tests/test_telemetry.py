"""Observability subsystem (``repro.obs``): windowed telemetry,
event-trace views, Perfetto export, and instrumented Study runs.

The contract under test, in cost order:

* **off is free** — ``telemetry_windows=0`` (the default) adds no scan
  carry (statically elided from the jaxpr) and the knob's presence
  changes no simulation output bit on either backend;
* **on is observational** — ``telemetry_windows>0`` changes no
  simulation stat either, it only *adds* the ``tele`` accumulator;
* **one schema for all protocols** — every registered protocol fills
  the same 13 channels, and the windowed sums reconcile exactly with
  the engine's scalar cumulative counters;
* **backend-agnostic** — the Pallas fused-step path produces the
  bit-identical ``tele`` array;
* the typed views (``Result.timeseries()`` / ``Result.events()`` /
  ``obs.perfetto.export``) expose the paper's headline behaviour:
  colibri retry-free (zero BACKOFF spans, zero polls) where bare LR/SC
  retries, on the same workload.
"""
import json

import numpy as np
import pytest

from repro.analysis import trace_safety
from repro.core import protocols, sweep, workloads
from repro.core.protocols.base import BACKOFF, SLEEP
from repro.core.sim import SimParams, _run
from repro.obs import EventLog, Timeseries, schema
from repro.sync import Result, Spec, Study, run, scenario

SMALL = dict(n_cores=16, cycles=1200, n_addrs=4)


def _assert_runs_equal(r0, r1):
    assert set(r0) == set(r1)
    for k in sorted(r0):
        np.testing.assert_array_equal(np.asarray(r0[k]), np.asarray(r1[k]),
                                      err_msg=f"field {k!r} diverged")


# ---------------------------------------------------------------------------
# off-path: statically elided, bit-identical
# ---------------------------------------------------------------------------

def _num_carry(**kw):
    # single implementation in the static-analysis subsystem (raises if
    # the engine no longer lowers to ONE lax.scan)
    return trace_safety.scan_carry_count(
        SimParams(protocol="colibri", n_cores=16, cycles=400, n_addrs=4,
                  **kw))


def test_off_path_carry_statically_elided():
    """w=0 carries NOTHING extra; w>0 carries exactly the one tele
    array.  This is the PR 4 lesson — an always-on carry was a 3x
    compile/runtime cliff."""
    assert _num_carry(telemetry_windows=64) == \
        _num_carry(telemetry_windows=0) + 1


@pytest.mark.parametrize("backend", ["xla_cpu", "pallas_interpret"])
def test_telemetry_is_purely_observational(backend):
    """Same stats bit-for-bit with the accumulator on vs off, on both
    backends; ``tele`` is strictly additive."""
    off = dict(_run(SimParams(protocol="colibri", backend=backend,
                              telemetry_windows=0, **SMALL)))
    on = dict(_run(SimParams(protocol="colibri", backend=backend,
                             telemetry_windows=32, **SMALL)))
    assert "tele" not in off
    tele = np.asarray(on.pop("tele"))
    assert tele.shape == (32, schema.TELE_K) and tele.dtype == np.int32
    _assert_runs_equal(off, on)


def test_negative_windows_rejected():
    with pytest.raises(ValueError):
        SimParams(protocol="colibri", telemetry_windows=-1, **SMALL)


# ---------------------------------------------------------------------------
# one schema, every protocol: windowed sums == cumulative counters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("protocol", protocols.names())
def test_channel_sums_reconcile_with_counters(protocol):
    wl = workloads.get("rmw_loop")
    st = _run(SimParams(protocol=protocol, workload="rmw_loop",
                        n_addrs=max(4, wl.min_addrs),
                        telemetry_windows=16, n_cores=16, cycles=1200))
    tele = np.asarray(st["tele"])
    col = schema.TELE_COL
    sums = tele.sum(axis=0)
    for channel, counter in (("active", "active_cyc"),
                             ("sleeping", "sleep_cyc"),
                             ("backoff", "backoff_cyc"),
                             ("barwait", "bar_cyc"),
                             ("fails", "polls"),
                             ("msgs", "msgs"),
                             ("net_stall", "net_stall")):
        assert sums[col[channel]] == int(st[counter]), \
            f"{protocol}: windowed {channel} != cumulative {counter}"
    # outcome channels are counts, never negative; unused trailing
    # windows stay all-zero
    assert (tele >= 0).all()
    used = schema.windows_used(1200, 16)
    assert not tele[used:].any()


def test_tele_bit_identical_across_backends():
    for proto in ("colibri", "lrsc"):
        t = {}
        for backend in ("xla_cpu", "pallas_interpret"):
            st = _run(SimParams(protocol=proto, backend=backend,
                                telemetry_windows=24, **SMALL))
            t[backend] = np.asarray(st["tele"])
        np.testing.assert_array_equal(
            t["xla_cpu"], t["pallas_interpret"],
            err_msg=f"{proto}: tele diverged across backends")


# ---------------------------------------------------------------------------
# window geometry
# ---------------------------------------------------------------------------

def test_window_geometry():
    assert schema.window_len(1000, 64) == 16       # ceil(1000/64)
    assert schema.windows_used(1000, 64) == 63     # 63*16 = 1008 >= 1000
    assert schema.window_cycles(1000, 64).sum() == 1000
    assert schema.window_cycles(1000, 64)[-1] == 1000 - 62 * 16
    assert schema.window_starts(1000, 64)[0] == 0
    # degenerate shapes
    assert schema.window_len(10, 64) == 1
    assert schema.windows_used(10, 64) == 10
    with pytest.raises(ValueError):
        schema.window_len(100, 0)


# ---------------------------------------------------------------------------
# Spec / sweep routing
# ---------------------------------------------------------------------------

def test_spec_routes_telemetry_windows():
    s = Spec(protocol="colibri", telemetry_windows=48)
    assert s.costs.telemetry_windows == 48
    assert s.to_params().telemetry_windows == 48
    assert Spec.from_json(s.to_json()) == s
    assert s.replace(telemetry_windows=0).to_params().telemetry_windows == 0


def test_telemetry_windows_is_a_static_sweep_field():
    """w=0 vs w>0 compile to different programs (the carry differs) —
    they must never share one vmapped trace."""
    assert "telemetry_windows" in sweep.STATIC_FIELDS
    base = dict(protocol="colibri", **SMALL)
    k0 = sweep._static_key(SimParams(telemetry_windows=0, **base))
    k64 = sweep._static_key(SimParams(telemetry_windows=64, **base))
    assert k0 != k64


# ---------------------------------------------------------------------------
# Timeseries view
# ---------------------------------------------------------------------------

def _contended(**kw):
    return Spec(workload="zipf_histogram", n_cores=32, cycles=1500,
                record_trace=True, telemetry_windows=25,
                **scenario("zipf_histogram")).replace(**kw)


def test_timeseries_view():
    r = run(_contended(protocol="colibri"))
    ts = r.timeseries()
    assert isinstance(ts, Timeseries)
    assert ts.n_windows == 25 and ts.cycles == 1500
    assert ts.tele.shape == (25, schema.TELE_K)
    # core-census channels can never exceed the core count per cycle
    assert (ts.per_cycle("active") <= ts.n_cores).all()
    assert (ts.active_cores + ts.sleeping_cores <= ts.n_cores).all()
    # colibri on a contended workload: sleeps happen, retries never
    assert ts.counts("enqueues").sum() > 0
    assert ts.counts("backoff").sum() == 0
    assert ts.counts("retires").sum() > 0
    assert ts.queue_depth_max.max() > 0
    assert (ts.queue_depth_mean <= ts.queue_depth_max).all()
    # per-cycle means are undefined for the max-accumulated column
    with pytest.raises(ValueError):
        ts.per_cycle("queue_max")
    json.dumps(ts.to_dict())                       # JSON-clean


def test_timeseries_requires_the_knob():
    r = run(Spec(protocol="colibri", n_cores=16, cycles=400))
    with pytest.raises(ValueError, match="telemetry_windows"):
        r.timeseries()


# ---------------------------------------------------------------------------
# EventLog / Perfetto: the paper's contrast, visibly
# ---------------------------------------------------------------------------

def test_events_retry_contrast_and_perfetto(tmp_path):
    """On one zipf_histogram run, colibri must show ZERO retry
    (BACKOFF) spans and zero polls while lrsc shows retry spans — the
    acceptance contrast, both in the typed view and in the exported
    Perfetto JSON."""
    from repro import obs
    logs = {}
    for proto in ("colibri", "lrsc"):
        r = run(_contended(protocol=proto))
        log = r.events()
        assert isinstance(log, EventLog)
        logs[proto] = (r, log)
    r_c, log_c = logs["colibri"]
    r_l, log_l = logs["lrsc"]
    assert log_c.span_counts(BACKOFF).sum() == 0 and r_c.polls == 0
    assert log_c.span_counts(SLEEP).sum() > 0
    assert log_l.span_counts(BACKOFF).sum() > 0 and r_l.polls > 0
    # spans()/completions() agree with the census
    assert log_c.time_in_state(SLEEP).sum() == \
        sum(s.length for s in log_c.spans(states=(SLEEP,)))
    comp = log_c.completions()
    assert len(comp["cycle"]) > 0 and (comp["wait"] >= 0).all()
    # Perfetto export: valid Chrome-trace JSON with span/counter/meta
    # events; lrsc's file must contain BACKOFF spans, colibri's none
    for proto, (r, _) in logs.items():
        path = obs.perfetto.export(r, tmp_path / f"{proto}.json")
        with open(path) as f:
            doc = json.load(f)
        evs = doc["traceEvents"]
        assert {e["ph"] for e in evs} >= {"X", "M"}
        backoffs = [e for e in evs
                    if e["ph"] == "X" and e["name"] == "BACKOFF"]
        assert bool(backoffs) == (proto == "lrsc")


def test_events_requires_record_trace():
    r = run(Spec(protocol="colibri", n_cores=16, cycles=400))
    with pytest.raises(ValueError, match="record_trace"):
        r.events()


# ---------------------------------------------------------------------------
# Study integration + RunReport instrumentation
# ---------------------------------------------------------------------------

def test_study_carries_telemetry_and_runreport():
    from repro import obs
    st = Study(protocol="colibri", n_cores=16, n_addrs=4, cycles=800,
               telemetry_windows=8).grid(lat=[3, 5])
    with obs.collect() as report:
        results = st.run()
    assert len(results) == 2
    for r in results:
        ts = r.timeseries()
        assert ts.tele.shape == (8, schema.TELE_K)
        assert ts.counts("active").sum() == int(r.stats["active_cyc"])
    # the ambient report saw the sweep: chunks, points, env, timings
    assert report.n_chunks >= 1 and report.n_points == 2
    assert report.backend == "xla_cpu"
    assert report.compile_s >= 0 and report.execute_s >= 0
    assert "chunk" in report.summary()
    json.dumps(report.to_dict())
    # collect() restores the previous ambient report on exit
    assert obs.current() is None


def test_runreport_explicit_argument():
    from repro.obs import RunReport
    rep = RunReport()
    st = Study(protocol="lrsc", n_cores=16, n_addrs=4, cycles=600) \
        .grid(lat=[3, 5])
    st.run(report=rep)
    assert rep.n_points == 2 and rep.n_chunks >= 1
    labels = [c.label for c in rep.chunks]
    assert any("lrsc" in lb for lb in labels)


def test_result_to_row_unaffected_by_telemetry():
    """Report rows (to_row) stay scalar — the tele array must not leak
    into benchmark JSON rows."""
    r = run(Spec(protocol="colibri", n_cores=16, cycles=400,
                 telemetry_windows=8))
    row = r.to_row()
    assert "tele" not in row
    json.dumps(row)
