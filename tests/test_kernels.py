"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
on TPU the same pallas_call lowers to Mosaic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.colibri_scatter import (colibri_histogram,
                                           colibri_scatter_add)
from repro.kernels.colibri_scatter.ref import scatter_add_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.grouped_matmul import grouped_matmul
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rwkv6_wkv import wkv_chunked
from repro.kernels.rwkv6_wkv.ref import wkv_ref

KEY = jax.random.PRNGKey(0)


def keys(n):
    return jax.random.split(KEY, n)


# ---------------------------------------------------------------------------
# colibri_scatter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,bins,d", [(100, 7, 1), (1000, 64, 8),
                                      (2048, 300, 16), (513, 1, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_colibri_scatter_sweep(t, bins, d, dtype):
    k1, k2 = keys(2)
    ks = jax.random.randint(k1, (t,), 0, bins)
    vs = jax.random.normal(k2, (t, d), dtype)
    out = colibri_scatter_add(ks, vs, bins)
    ref = scatter_add_ref(ks, vs.astype(jnp.float32), bins)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=tol, atol=tol * 10)


def test_colibri_scatter_block_shapes():
    """Result must be block-size independent (two-phase commit correctness)."""
    k1, k2 = keys(2)
    ks = jax.random.randint(k1, (777,), 0, 50)
    vs = jax.random.normal(k2, (777, 4))
    a = colibri_scatter_add(ks, vs, 50, block_t=128, block_bins=32)
    b = colibri_scatter_add(ks, vs, 50, block_t=512, block_bins=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


@pytest.mark.parametrize("t,bins", [(100, 7), (1000, 64), (513, 1),
                                    (2048, 300)])
def test_colibri_histogram_parity(t, bins):
    """The paper's benchmark op vs the ref commit and np.bincount."""
    ks = jax.random.randint(keys(1)[0], (t,), 0, bins)
    out = np.asarray(colibri_histogram(ks, bins))
    ref = np.asarray(scatter_add_ref(
        ks, jnp.ones((t, 1), jnp.float32), bins))[:, 0].astype(np.int32)
    np.testing.assert_array_equal(out, ref)
    np.testing.assert_array_equal(
        out, np.bincount(np.asarray(ks), minlength=bins))


def test_trace_latency_hist_matches_engine():
    """The kernel's product caller: folding the exact recorded waits
    onto the engine's geometric bins reproduces the in-scan ``lat_hist``
    accumulator count for count (both see every retirement once)."""
    from repro.core import metrics
    from repro.core.sim import SimParams, execute
    res = execute(SimParams(protocol="colibri", n_cores=32, n_addrs=4,
                            cycles=4000, record_trace=True))
    hk = metrics.trace_latency_hist(res)
    np.testing.assert_array_equal(hk, np.asarray(res["lat_hist"]))
    np.testing.assert_array_equal(
        hk, metrics.trace_latency_hist(res, use_kernel=False))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,sq,skv,h,kv,hd", [
    (2, 128, 128, 4, 4, 64),
    (1, 200, 200, 4, 2, 32),      # GQA + non-multiple seq
    (2, 64, 256, 2, 1, 64),       # MQA, cross lengths
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, sq, skv, h, kv, hd, causal, dtype):
    if causal and sq != skv:
        pytest.skip("causal requires sq == skv in this test")
    k1, k2, k3 = keys(3)
    q = jax.random.normal(k1, (b, sq, h, hd), dtype)
    k = jax.random.normal(k2, (b, skv, kv, hd), dtype)
    v = jax.random.normal(k3, (b, skv, kv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    g = h // kv
    ke = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    ve = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    qe = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    ref = attention_ref(qe, ke, ve, causal=causal).reshape(b, h, sq, hd
                                                           ).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 5)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,c,d,f", [(4, 64, 128, 256), (8, 100, 96, 64),
                                     (1, 256, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(e, c, d, f, dtype):
    k1, k2 = keys(2)
    x = jax.random.normal(k1, (e, c, d), dtype)
    w = jax.random.normal(k2, (e, d, f), dtype)
    out = grouped_matmul(x, w, block_c=64, block_f=64, block_d=64)
    ref = grouped_matmul_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 10)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,t,hd", [(2, 64, 32), (4, 130, 64), (1, 32, 16)])
def test_wkv_chunked_sweep(bh, t, hd):
    k1, k2, k3, k4, k5 = keys(5)
    r = jax.random.normal(k1, (bh, t, hd)) * 0.5
    k = jax.random.normal(k2, (bh, t, hd)) * 0.5
    v = jax.random.normal(k3, (bh, t, hd))
    # realistic rwkv6 decay: w = exp(-exp(x)), x ~ N(-1.5, 1)
    w = jnp.exp(-jnp.exp(jax.random.normal(k4, (bh, t, hd)) - 1.5))
    u = jax.random.normal(k5, (bh, hd)) * 0.1
    out = wkv_chunked(r, k, v, w, u, block_c=32)
    ref = wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_wkv_chunk_size_invariance():
    k1, k2, k3, k4, k5 = keys(5)
    bh, t, hd = 2, 96, 32
    r = jax.random.normal(k1, (bh, t, hd)) * 0.5
    k = jax.random.normal(k2, (bh, t, hd)) * 0.5
    v = jax.random.normal(k3, (bh, t, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(k4, (bh, t, hd)) - 1.5))
    u = jax.random.normal(k5, (bh, hd)) * 0.1
    a = wkv_chunked(r, k, v, w, u, block_c=16)
    b = wkv_chunked(r, k, v, w, u, block_c=48)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,b,w", [(64, 2, 128), (100, 3, 60), (256, 1, 256)])
def test_rglru_scan_sweep(t, b, w):
    k1, k2, k3 = keys(3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (t, b, w)) + 2.0)  # decay ~ (0,1)
    x = jax.random.normal(k2, (t, b, w)) * 0.3
    h0 = jax.random.normal(k3, (b, w))
    out = rglru_scan(a, x, h0, block_c=32, block_b=2, block_w=64)
    ref = rglru_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_rglru_matches_model_block():
    """The kernel agrees with the model's associative-scan path on the same
    gate math (hillclimb swap-in safety)."""
    from repro.configs import get_config
    from repro.models import rglru as RG
    cfg = get_config("recurrentgemma-2b-smoke")
    p = RG.rglru_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, cfg.d_model)) * 0.5
    state = RG.state_init(cfg, 2)
    out_model, _ = RG.rglru_apply(cfg, p, x, state)
    # recompute via the kernel on the same a/b streams
    y, _ = RG._conv1d_causal(x @ p["w_in"], p["conv_w"], p["conv_b"],
                             state["conv"])
    a, b = RG._gates(p, y.astype(jnp.float32))
    h = rglru_scan(a.transpose(1, 0, 2), b.transpose(1, 0, 2), state["h"],
                   block_c=16).transpose(1, 0, 2)
    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    out_kernel = (h.astype(x.dtype) * gate) @ p["w_proj"]
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=2e-4, atol=2e-4)
