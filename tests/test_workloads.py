"""Workload subsystem: registry contract, program validation, and the
cross-product invariant suite — every registered workload × every
registered protocol must satisfy its conservation laws.

Laws checked per (workload, protocol) pair through ``Workload.check``:
queue pops ⊆ pushes at every prefix + total pop order (FIFO per-bank),
stack per-core LIFO alternation, histogram bin totals == completed
updates, barrier phase-lockstep (per-core span ≤ 1), and — the paper's
headline — ``polls == 0`` for the polling-free protocols under *every*
workload, not just the hardcoded RMW loop they were tuned on.
"""
import numpy as np
import pytest

from repro.core import protocols, workloads
from repro.core.sim import SimParams, run
from repro.core.sweep import sweep, sweep_grid
from repro.core.workloads.base import (ADDR_FIXED, ADDR_UNIFORM, K_BARRIER,
                                       Program, Workload, zipf_index)

POLLING_FREE = {"lrscwait", "colibri", "colibri_hier", "mwait_lock"}
SMALL = dict(n_cores=16, n_addrs=4, cycles=2500, record_trace=True)


# ---------------------------------------------------------------- registry

def test_registry_contents_and_errors():
    names = workloads.names()
    for wl in ("rmw_loop", "ms_queue", "treiber_stack", "zipf_histogram",
               "barrier_phases"):
        assert wl in names
    with pytest.raises(KeyError):
        workloads.get("no_such_workload")
    with pytest.raises(ValueError):              # duplicate name rejected
        @workloads.register
        class Dup(Workload):
            name = "rmw_loop"
    with pytest.raises(ValueError):              # anonymous plugin rejected
        workloads.register(Workload)


def test_program_validation():
    ok = dict(kind=(0,), pre_mult=(1,), pre_add=(0,), addr_mode=(0,),
              addr_arg=(0,), mod_mult=(1,), mod_add=(0,))
    Program(**ok)
    with pytest.raises(ValueError):              # ragged table
        Program(**{**ok, "pre_mult": (1, 2)})
    with pytest.raises(ValueError):              # empty program
        Program(kind=(), pre_mult=(), pre_add=(), addr_mode=(),
                addr_arg=(), mod_mult=(), mod_add=())
    with pytest.raises(ValueError):              # barrier needs FIXED addr
        Program(**{**ok, "kind": (K_BARRIER,), "addr_mode": (ADDR_UNIFORM,)})
    with pytest.raises(ValueError):              # unknown address mode
        Program(**{**ok, "addr_mode": (9,)})


def test_unknown_workload_raises():
    """Since the repro.sync redesign, a bad name fails at SimParams
    construction with the registry's entries — not as a KeyError deep in
    the engine."""
    with pytest.raises(ValueError, match="registered workloads"):
        run(SimParams(workload="no_such_workload", n_cores=8, cycles=100))


def test_min_addrs_enforced():
    """ms_queue needs head and tail in distinct banks (static alloc)."""
    with pytest.raises(ValueError):
        run(SimParams(workload="ms_queue", n_addrs=1, n_cores=8, cycles=100))


# ------------------------------------------------- cross-product invariants

@pytest.mark.parametrize("wl", workloads.names())
@pytest.mark.parametrize("proto", protocols.names())
def test_invariants_every_workload_every_protocol(wl, proto):
    p = SimParams(protocol=proto, workload=wl, **SMALL)
    r = run(p)
    assert int(r["ops"].sum()) > 0, "no progress"
    info = workloads.get(wl).check(p, r, r.get("trace_step"))
    assert info["atomics"] >= info["ops"]
    if proto in POLLING_FREE:
        assert int(r["polls"]) == 0, \
            f"{proto} polled under {wl}: {int(r['polls'])}"


# ------------------------------------------------------------ zipf stream

def test_zipf_index_bounds_and_uniform_limit():
    import jax.numpy as jnp
    h = jnp.arange(0, 1 << 24, 40961, dtype=jnp.uint32)
    for n in (1, 2, 37, 1024):
        for skew in (0, 100, 250):
            idx = np.asarray(zipf_index(h, n, skew))
            assert idx.min() >= 0 and idx.max() < n, (n, skew)
    # s=0 is uniform: every bin within 25% of the expected count
    counts = np.bincount(np.asarray(zipf_index(h, 8, 0)), minlength=8)
    assert counts.min() > 0.75 * h.size / 8


def test_zipf_hypothesis_properties():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    import jax.numpy as jnp

    @settings(max_examples=30, deadline=None)
    @given(h=st.integers(0, (1 << 24) - 1), n=st.integers(1, 4096),
           skew=st.integers(0, 300))
    def prop(h, n, skew):
        i = int(zipf_index(jnp.uint32(h), n, skew))
        assert 0 <= i < n
        # monotone in the hash: larger u never maps to a smaller address
        i2 = int(zipf_index(jnp.uint32(min(h + 4096, (1 << 24) - 1)),
                            n, skew))
        assert i2 >= i

    prop()


def test_zipf_skew_concentrates():
    """Higher skew → more mass on the hot bin; s=0 matches uniform share."""
    shares = {}
    for skew in (0, 100, 200):
        p = SimParams(protocol="amo", workload="zipf_histogram", n_cores=32,
                      n_addrs=16, cycles=4000, zipf_skew=skew)
        r = run(p)
        hist = np.asarray(r["addr_ops"])[:16]
        shares[skew] = hist.max() / max(hist.sum(), 1)
    assert shares[0] < 0.2                       # ≈ 1/16 uniform
    assert shares[0] < shares[100] < shares[200]
    assert shares[200] > 0.5


# --------------------------------------------------------------- barrier

def test_barrier_lockstep_and_polling_free():
    """Colibri barrier: arrivals never poll, waiters park in BARWAIT, and
    no core runs ahead; LRSC pays retry storms on the arrival counter."""
    kw = dict(workload="barrier_phases", n_cores=64, n_addrs=1, cycles=6000)
    col = run(SimParams(protocol="colibri", **kw))
    assert int(col["polls"]) == 0
    assert int(col["bar_cyc"]) > 0
    ops = np.asarray(col["ops"])
    assert int(ops.max()) - int(ops.min()) <= 1
    lrsc = run(SimParams(protocol="lrsc", **kw))
    assert int(lrsc["polls"]) > 0
    assert col["throughput"] > lrsc["throughput"]


# ------------------------------------------------------- queue semantics

def test_ms_queue_beats_parameter_approximation_structure():
    """The two-linked-atomic program really issues 2 atomics per op and
    splits them across head/tail banks."""
    p = SimParams(protocol="colibri", workload="ms_queue", n_cores=32,
                  n_addrs=2, cycles=3000, record_trace=True)
    r = run(p)
    info = workloads.get("ms_queue").check(p, r, r["trace_step"])
    assert info["atomics"] == info["pushes"] + info["pops"]
    assert abs(info["pushes"] - info["pops"]) <= p.n_cores
    hist = np.asarray(r["addr_ops"])[:2]
    assert hist[0] > 0 and hist[1] > 0           # both banks active


# ----------------------------------------------------------------- sweep

def test_sweep_matches_run_across_workloads():
    """Mixed-workload config lists group by the workload-aware static
    fingerprint and stay bit-identical to sequential run()."""
    configs = [
        SimParams(protocol="colibri", workload=wl, n_cores=16, n_addrs=4,
                  cycles=700)
        for wl in ("rmw_loop", "ms_queue", "zipf_histogram",
                   "barrier_phases")
    ] + [
        SimParams(protocol="lrsc", workload="treiber_stack", n_cores=16,
                  n_addrs=4, cycles=700, seed=3),
    ]
    for cfg, swept in zip(configs, sweep(configs)):
        ref = run(cfg)
        for k in ("ops", "msgs", "polls", "addr_ops", "bar_cnt",
                  "sleep_cyc", "bar_cyc", "throughput"):
            assert np.array_equal(np.asarray(swept[k]), np.asarray(ref[k])), \
                (cfg.workload, k)


def test_sweep_grid_zipf_skew_axis():
    """zipf_skew is a traced sweep axis: one compile covers the ladder."""
    res = sweep_grid(SimParams(protocol="amo", workload="zipf_histogram",
                               n_cores=16, n_addrs=8, cycles=1000),
                     zipf_skew=(0, 150))
    assert len(res) == 2
    flat, skewed = (np.asarray(r["addr_ops"])[:8] for r in res)
    assert flat.max() / max(flat.sum(), 1) < \
        skewed.max() / max(skewed.sum(), 1)
    for r in res:
        ref = run(r["_config"])
        assert np.array_equal(np.asarray(r["addr_ops"]),
                              np.asarray(ref["addr_ops"]))
