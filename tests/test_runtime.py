"""Runtime substrate tests: optimizer (incl. int8 states), data pipeline
determinism, serving engine, HLO loop-correction parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data import SyntheticPipeline
from repro.distributed.sharding import Policy
from repro.models import build


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def _quad_params():
    return {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.zeros((2, 4))}


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges(state_dtype):
    cfg = optim.AdamWConfig(lr=0.05, weight_decay=0.0, state_dtype=state_dtype,
                            warmup_steps=5, total_steps=200)
    params = _quad_params()
    state = optim.init(cfg, params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)

    step = jax.jit(lambda p, s: optim.update(cfg, jax.grad(loss)(p), s, p))
    for _ in range(200):
        params, state, _ = step(params, state)
    assert loss(params) < 0.05, float(loss(params))


def test_adamw_int8_tracks_fp32():
    """Quantized moments stay within a few percent of the fp32 trajectory."""
    params32 = _quad_params()
    params8 = _quad_params()
    c32 = optim.AdamWConfig(lr=0.01, state_dtype="float32", weight_decay=0.0)
    c8 = optim.AdamWConfig(lr=0.01, state_dtype="int8", weight_decay=0.0)
    s32, s8 = optim.init(c32, params32), optim.init(c8, params8)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)

    for _ in range(50):
        g32 = jax.grad(loss)(params32)
        params32, s32, _ = optim.update(c32, g32, s32, params32)
        g8 = jax.grad(loss)(params8)
        params8, s8, _ = optim.update(c8, g8, s8, params8)
    np.testing.assert_allclose(np.asarray(params8["w"]),
                               np.asarray(params32["w"]), atol=0.05)


def test_grad_clip_bounds_update():
    cfg = optim.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = optim.init(cfg, params)
    huge = {"w": jnp.full((3,), 1e6)}
    _, _, m = optim.update(cfg, huge, state, params)
    assert m["grad_norm"] > 1e5          # reported pre-clip


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_recompute():
    """batch(step) is a pure function — the straggler/restart guarantee."""
    cfg = get_config("smollm-135m-smoke")
    shape = ShapeSpec("t", 128, 4, "train")
    p1 = SyntheticPipeline(cfg, shape)
    p2 = SyntheticPipeline(cfg, shape)
    b1, b2 = p1.batch(17), p2.batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p1.batch(18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_pipeline_histogram_uses_colibri_commit():
    cfg = get_config("smollm-135m-smoke")
    shape = ShapeSpec("t", 64, 2, "train")
    p = SyntheticPipeline(cfg, shape)
    batch = p.batch(0)
    h = p.token_histogram(batch, num_bins=32)
    assert int(h.sum()) == batch["tokens"].size
    ref = np.bincount(np.asarray(batch["tokens"]).reshape(-1) % 32,
                      minlength=32)
    np.testing.assert_array_equal(np.asarray(h), ref)


def test_pipeline_labels_shifted():
    cfg = get_config("smollm-135m-smoke")
    p = SyntheticPipeline(cfg, ShapeSpec("t", 16, 2, "train"))
    b = p.batch(0)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))
    assert (np.asarray(b["labels"][:, -1]) == -1).all()


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------

def test_serve_engine_batched_requests():
    from repro.serving import Request, ServeEngine
    cfg = get_config("smollm-135m-smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=3, cache_len=64)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, size=(5 + i,))
                    .astype(np.int32), max_new_tokens=4, id=i)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    served = eng.run_once()
    assert served == 3
    for r in reqs:
        assert r.done.is_set()
        assert r.result.shape == (4,)

    # batched result == solo result for the same prompt (greedy decode)
    solo = Request(prompt=reqs[0].prompt, max_new_tokens=4)
    eng.submit(solo)
    eng.run_once()
    np.testing.assert_array_equal(solo.result, reqs[0].result)


def test_serve_engine_event_driven():
    """The engine thread sleeps on the coordinator and serves on arrival."""
    import threading
    from repro.serving import Request, ServeEngine
    cfg = get_config("smollm-135m-smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, cache_len=32)
    t = threading.Thread(target=eng.serve_forever, daemon=True)
    t.start()
    out = eng.generate(np.array([1, 2, 3], np.int32), max_new_tokens=3)
    eng.stop()
    assert out.shape == (3,)


# ---------------------------------------------------------------------------
# HLO loop-corrected collective parser
# ---------------------------------------------------------------------------

def test_hlo_loop_correction_synthetic():
    from repro.launch import hlo_analysis as H
    text = """
HloModule m

%cond (p: (s32[])) -> pred[] {
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

%body (p: (s32[])) -> (s32[]) {
  %ar = f32[4,2]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[]) tuple(%iv)
}

ENTRY %main (a: f32[4]) -> f32[] {
  %w = (s32[]) while(%init), condition=%cond, body=%body
  %ag = f32[8]{0} all-gather(%a), dimensions={0}
  ROOT %r = f32[] constant(0)
}
"""
    out = H.collective_bytes_corrected(text)
    assert out["all-reduce"] == 7 * 4 * 2 * 4     # in-loop x7
    assert out["all-gather"] == 8 * 4             # outside x1
    assert out["total_raw"] == 4 * 2 * 4 + 8 * 4
