"""Fault-injection & recovery subsystem (``repro.faults``): static
elision, deterministic schedules, backend equivalence, the liveness
invariants of every protocol's recovery path, and the sweep runner's
poisoned-chunk isolation."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.sweep as sweep_mod
from repro.analysis import trace_safety
from repro.core.protocols.registry import names as proto_names
from repro.core.sim import SimParams, simulate
from repro.faults import FaultPlan
from repro.sync import Result, Spec, Study, run

KILL = FaultPlan(n_kill=2, kill_cyc=300, kill_holder=1, watchdog_cyc=64,
                 progress_cyc=400)
NOWD = dataclasses.replace(KILL, watchdog_cyc=0)


def _params(proto="lrscwait", **kw):
    kw.setdefault("n_cores", 32)
    kw.setdefault("n_addrs", 4)
    kw.setdefault("cycles", 1200)
    return SimParams(protocol=proto, **kw)


# ------------------------------------------------------------ FaultPlan

def test_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(n_kill=-1)
    with pytest.raises(ValueError):
        FaultPlan(kill_holder=2)
    with pytest.raises(ValueError):
        FaultPlan(msg_drop_bp=10_001)
    with pytest.raises(ValueError):
        FaultPlan(n_stall=2)                  # stall needs a duration
    with pytest.raises(ValueError):
        FaultPlan(n_bank_stall=1)
    assert not FaultPlan().enabled
    assert FaultPlan(watchdog_cyc=8).enabled
    assert not FaultPlan(watchdog_cyc=8).injects
    assert FaultPlan(msg_drop_bp=1).injects


def test_schedule_determinism():
    """Victim selection is a pure function of (fault_seed, salt) — the
    same plan always draws the same victims, different seeds draw
    different ones, and the kill/stall/bank draws are decorrelated."""
    a = FaultPlan(n_kill=3, kill_cyc=1, fault_seed=5)
    b = FaultPlan(n_kill=3, kill_cyc=1, fault_seed=5)
    c = FaultPlan(n_kill=3, kill_cyc=1, fault_seed=6)
    assert np.array_equal(a.kill_mask(64), b.kill_mask(64))
    assert not np.array_equal(a.kill_mask(64), c.kill_mask(64))
    assert a.kill_mask(64).sum() == 3
    d = FaultPlan(n_kill=3, kill_cyc=1, n_stall=3, stall_cyc=1,
                  stall_dur=1, fault_seed=5)
    assert not np.array_equal(d.kill_mask(64), d.stall_mask(64))
    assert FaultPlan(n_kill=99, kill_cyc=1).kill_mask(8).sum() == 8


# ------------------------------------------------- static elision

def _num_carry(p):
    # single implementation in the static-analysis subsystem (raises if
    # the engine no longer lowers to ONE lax.scan)
    return trace_safety.scan_carry_count(p)


def test_faults_off_statically_elided():
    """faults=FaultPlan() adds ZERO scan carries and is bit-identical
    to the pre-faults engine — the telemetry/PR 4 carry-cliff lesson
    applied to this subsystem."""
    off = _params()
    explicit = _params(faults=FaultPlan())
    assert _num_carry(off) == _num_carry(explicit)
    assert _num_carry(_params(faults=KILL)) > _num_carry(off)
    r0, r1 = simulate(off), simulate(explicit)
    assert set(r0) == set(r1)
    for k in r0:
        assert jnp.array_equal(r0[k], r1[k]), k
    assert "faults_injected" not in r0 and "dead_mask" not in r0


def test_faults_normalization():
    """dict / None faults normalize; junk is rejected eagerly."""
    p = _params(faults={"n_kill": 1, "kill_cyc": 5, "watchdog_cyc": 8})
    assert p.faults == FaultPlan(n_kill=1, kill_cyc=5, watchdog_cyc=8)
    assert _params(faults=None).faults == FaultPlan()
    with pytest.raises((TypeError, ValueError)):
        _params(faults=7)


# ------------------------------------------------- backend equivalence

def test_backend_bit_identity_with_faults():
    """All fault logic lives outside the fused kernel, so the scan
    oracle and the Pallas interpreter stay bit-identical under the full
    fault mix."""
    fp = FaultPlan(n_kill=2, kill_cyc=200, kill_holder=1, watchdog_cyc=64,
                   msg_drop_bp=150, n_bank_stall=1, bank_stall_cyc=400,
                   bank_stall_dur=100)
    for proto in ("lrscwait", "mwait_lock", "lrsc"):
        r_cpu = simulate(_params(proto, backend="xla_cpu", faults=fp))
        r_int = simulate(_params(proto, backend="pallas_interpret",
                                 faults=fp))
        assert set(r_cpu) == set(r_int)
        for k in r_cpu:
            assert jnp.array_equal(jnp.asarray(r_cpu[k]),
                                   jnp.asarray(r_int[k])), (proto, k)


# ------------------------------------------------- liveness invariants

def test_owner_kill_recovery_all_protocols():
    """The headline invariant: with the reservation watchdog every
    protocol sustains forward progress through an adversarial owner
    kill; without it every holder-based protocol is DETECTED as
    deadlocked (halt flagged, run completes) — and amo, which holds
    nothing, is untouchable by holder kills."""
    for proto in proto_names():
        r = simulate(_params(proto, cycles=3000, faults=KILL))
        halt = int(r["halt_cyc"])
        if proto == "amo":
            assert int(r["faults_injected"]) == 0      # no holders exist
            assert halt < 0
            continue
        assert int(r["faults_injected"]) == 2, proto
        assert int(r["recoveries"]) >= 1, proto
        assert halt < 0, (proto, halt)                 # stayed live
        assert int(r["dead_mask"].sum()) == 2, proto
        # watchdog off: the same kill wedges the system and the
        # forward-progress detector flags it (never a hang)
        r2 = simulate(_params(proto, cycles=4000, faults=NOWD))
        assert int(r2["halt_cyc"]) >= 0, proto
        assert int(r2["recoveries"]) == 0


def test_lost_wakeups_recovered():
    """Dropped wake messages wedge a sleep-based bank until the
    watchdog redelivers: throughput degrades but never halts."""
    fp = FaultPlan(msg_drop_bp=300, watchdog_cyc=64, progress_cyc=400)
    for proto in ("lrscwait", "colibri", "mwait_lock"):
        r = simulate(_params(proto, cycles=3000, faults=fp))
        assert int(r["halt_cyc"]) < 0, proto
        assert int(r["faults_injected"]) > 0, proto
        assert int(r["ops"].sum()) > 0


def test_transient_stall_and_bank_stall_recover():
    base = _params(cycles=3000)
    r_stall = simulate(dataclasses.replace(base, faults=FaultPlan(
        n_stall=4, stall_cyc=500, stall_dur=300, watchdog_cyc=64,
        progress_cyc=400)))
    # the stall window closed before the horizon: nobody is dead at the
    # end and progress resumed
    assert int(r_stall["dead_mask"].sum()) == 0
    assert int(r_stall["halt_cyc"]) < 0
    r_bank = simulate(dataclasses.replace(base, faults=FaultPlan(
        n_bank_stall=1, bank_stall_cyc=500, bank_stall_dur=200,
        watchdog_cyc=64, progress_cyc=400)))
    assert int(r_bank["halt_cyc"]) < 0
    assert int(r_bank["faults_injected"]) >= 1


# ------------------------------------------------- spec / result / metrics

def test_spec_faults_round_trip():
    s = Spec(protocol="lrscwait", n_cores=32, n_addrs=4,
             costs={"cycles": 800}, n_kill=2, kill_cyc=300,
             watchdog_cyc=64)
    assert s.faults.n_kill == 2 and s.faults.watchdog_cyc == 64
    assert Spec.from_json(s.to_json()) == s
    assert Spec.from_dict(s.to_dict()) == s
    assert Spec.from_params(s.to_params()) == s
    s2 = s.replace(faults={"msg_drop_bp": 100})
    assert s2.faults.n_kill == 2 and s2.faults.msg_drop_bp == 100
    assert s.replace(watchdog_cyc=0).faults.watchdog_cyc == 0
    with pytest.raises(ValueError):
        Spec(protocol="lrscwait", faults={"bogus_knob": 1})


def test_result_fault_metrics():
    s = Spec(protocol="lrscwait", n_cores=32, n_addrs=4,
             costs={"cycles": 2000},
             faults=FaultPlan(n_kill=2, kill_cyc=300, watchdog_cyc=64,
                              progress_cyc=400))
    r = run(s)
    assert r.ok and r.error is None
    assert r.progress_ok is True
    assert r.recoveries >= 1 and r.faults_injected == 2
    assert r.stats["stalled_cores"] == 2
    # survivors-only throughput excludes the dead cores' zeros
    assert 0 < r.stats["survivor_throughput"] <= r.throughput + 1e-12
    assert 0 < r.stats["survivor_jain"] <= 1.0
    row = r.to_row()
    for k in ("progress_ok", "recoveries", "faults_injected",
              "stalled_cores", "survivor_throughput", "survivor_jain"):
        assert k in row
    r2 = Result.from_json(r.to_json())
    assert r2.progress_ok is True and r2.recoveries == r.recoveries
    # a fault-free run carries none of this
    r3 = run(Spec(protocol="lrscwait", n_cores=16, costs={"cycles": 400}))
    assert r3.progress_ok is None
    assert "progress_ok" not in r3.to_row()


# ------------------------------------------------- sweep isolation

def _specs(n=8, **kw):
    base = Spec(protocol="lrscwait", n_cores=16, n_addrs=2,
                costs={"cycles": 300}, **kw)
    return [base.replace(seed=s) for s in range(n)]


def test_poisoned_chunk_isolated(monkeypatch):
    """One exploding chunk must not kill Study.stream(): the poison is
    bisected down to its point, which yields a structured error record
    while every other point yields its normal result."""
    orig = sweep_mod._sweep_group

    def poisoned(rep, dyn, batch):
        if (np.asarray(dyn["seed"]) == 5).any():
            raise RuntimeError("injected chunk failure")
        return orig(rep, dyn, batch)

    monkeypatch.setattr(sweep_mod, "_sweep_group", poisoned)
    got = {r.spec.costs.seed: r for r in Study.from_specs(_specs()).stream()}
    assert len(got) == 8
    assert [s for s, r in got.items() if not r.ok] == [5]
    rec = got[5]
    assert "RuntimeError" in rec.error
    assert rec.stats["error_stage"] == "dispatch"
    assert "error" in rec.metrics()
    good = got[0]
    assert good.ok and good.throughput > 0
    # healthy results match an unpoisoned run exactly
    monkeypatch.setattr(sweep_mod, "_sweep_group", orig)
    clean = {r.spec.costs.seed: r for r in
             Study.from_specs(_specs()).stream()}
    assert clean[0].throughput == good.throughput


def test_poisoned_metrics_isolated(monkeypatch):
    """A per-point metric-derivation failure downgrades to a solo retry
    and then an error record — the rest of the chunk is untouched."""
    orig = sweep_mod.derive_metrics
    calls = {"n": 0}

    def flaky(res, n_workers, cycles, energy_fit=None):
        calls["n"] += 1
        if int(np.asarray(res["ops"]).sum()) % 2 == 1 and calls["n"] < 99:
            raise ValueError("derived on an odd total")
        return orig(res, n_workers, cycles, energy_fit=energy_fit)

    monkeypatch.setattr(sweep_mod, "derive_metrics", flaky)
    got = {r.spec.costs.seed: r for r in Study.from_specs(_specs()).stream()}
    assert len(got) == 8
    # every point either derived fine or solo-retried into a result or
    # an error record — the stream always completes
    for r in got.values():
        assert r.ok or "ValueError" in r.error


def test_nonfinite_point_becomes_error_record(monkeypatch):
    orig = sweep_mod.derive_metrics

    def nanify(res, n_workers, cycles, energy_fit=None):
        out = orig(res, n_workers, cycles, energy_fit=energy_fit)
        out["throughput"] = float("nan")
        return out

    monkeypatch.setattr(sweep_mod, "derive_metrics", nanify)
    got = list(Study.from_specs(_specs(n=2)).stream())
    assert len(got) == 2
    for r in got:
        assert not r.ok
        assert r.stats["error_stage"] == "nonfinite"


# ------------------------------------------------- perfetto overlay

def test_perfetto_fault_overlay():
    from repro.obs import perfetto
    s = Spec(protocol="lrscwait", n_cores=16, n_addrs=2,
             costs={"cycles": 1500, "record_trace": True},
             faults=FaultPlan(n_kill=1, kill_cyc=200, watchdog_cyc=0,
                              progress_cyc=300, n_bank_stall=1,
                              bank_stall_cyc=100, bank_stall_dur=50))
    r = run(s)
    ev = perfetto.to_trace_events(r)
    names = {e["name"] for e in ev}
    assert "DEAD" in names               # killed core span
    assert "BANK_STALL" in names
    assert "HALT" in names               # watchdog off -> detected halt
    dead = [e for e in ev if e["name"] == "DEAD"]
    assert all(e["cat"] == "fault" for e in dead)
