"""The ``repro.sync`` public API: bit-identical to the legacy surface,
validated at construction, streaming-equivalent to batch execution.

* **Equivalence** — ``repro.sync.run(Spec(...))`` matches the legacy
  ``sim.run(SimParams(...))`` result dict exactly over the FULL
  protocol × workload grid (the protocol-golden configurations of
  ``tests/test_workloads.py``), and ``Study.run()``/``Study.stream()``
  match the legacy ``sweep()`` shim on a multi-fingerprint,
  multi-chunk grid — so the deprecated shims can never drift from the
  new front door.
* **Deprecation** — ``sim.run`` / ``sweep.sweep`` / ``sweep.sweep_grid``
  warn but keep working.
* **Validation** — unknown protocol/workload names and impossible
  field values raise ``ValueError`` at ``Spec``/``SimParams``
  construction, naming the registries' available entries.
* **Schema** — ``Result.to_json`` round-trips the paper's metric
  triple; ``to_row`` is strict-JSON safe (non-finite → ``None``).
"""
import dataclasses
import json
import math
import warnings

import numpy as np
import pytest

from repro.core import protocols, workloads
from repro.core import sim as sim_mod
from repro.core import sweep as sweep_mod
from repro.core.sim import SimParams
from repro.sync import (Costs, Result, Spec, Study, Topology, run,
                        scenario)
from repro.sync.spec import _FLAT_TO_GROUP

#: same static shapes as tests/test_workloads.py's cross-product suite,
#: so the per-fingerprint engine compiles are shared within one session
GRID_KW = dict(n_cores=16, n_addrs=4, cycles=2500, record_trace=True)

#: keys that must match exactly (integer engine state + the shared
#: metric derivation) — superset of tests/test_sweep.py's list
EXACT_KEYS = ("ops", "opc", "msgs", "polls", "addr_ops", "sleep_cyc",
              "bar_cyc", "backoff_cyc", "bank_ops", "net_stall",
              "throughput", "fairness_min", "fairness_max",
              "lat_hist", "lat_max", "lat_p50", "lat_p95",
              "jain_fairness", "fairness_span", "energy_pj_per_op")


def _assert_same(new, old):
    for k in EXACT_KEYS:
        assert np.array_equal(np.asarray(new[k]), np.asarray(old[k])), k


def _silently(fn, *args, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kw)


# ------------------------------------------------------------ equivalence

@pytest.mark.parametrize("wl", workloads.names())
@pytest.mark.parametrize("proto", protocols.names())
def test_run_bit_identical_to_legacy_full_grid(proto, wl):
    """Every protocol × every workload: the typed front door and the
    deprecated ``sim.run`` return the exact same numbers."""
    new = run(Spec(protocol=proto, workload=wl, **GRID_KW))
    old = _silently(sim_mod.run,
                    SimParams(protocol=proto, workload=wl, **GRID_KW))
    _assert_same(new.stats, old)
    assert new.spec.to_params() == SimParams(protocol=proto, workload=wl,
                                             **GRID_KW)


def test_study_and_stream_match_legacy_sweep():
    """Study.run() == legacy sweep() bit-for-bit on a grid mixing
    protocols, bank buckets and seeds; Study.stream() yields the same
    points (chunk-completion order) on a ≥2-chunk execution."""
    study = Study(Spec(n_cores=16, cycles=600)) \
        .grid(protocol=("colibri", "lrsc"), n_addrs=(1, 8)) \
        .zip(seed=(0, 1, 2))
    specs = study.specs()
    assert len(specs) == len(study) == 12
    legacy = _silently(sweep_mod.sweep, [s.to_params() for s in specs],
                       max_batch=2)
    batch = study.run(max_batch=2)
    for n, o in zip(batch, legacy):
        _assert_same(n.stats, o)
    # streaming: same rows, identified by spec (≥2 chunks at max_batch=2)
    streamed = {}
    for r in study.stream(max_batch=2):
        assert r.spec not in streamed
        streamed[r.spec] = r
    want = {s: r for s, r in zip(specs, batch)}
    assert set(streamed) == set(want)
    for s in specs:
        _assert_same(streamed[s].stats, want[s].stats)


def test_sweep_grid_shim_matches_study_grid():
    base = Spec(protocol="amo", n_cores=16, cycles=600)
    legacy = _silently(sweep_mod.sweep_grid, base.to_params(),
                       n_addrs=(1, 4), seed=(0, 1))
    new = Study(base).grid(n_addrs=(1, 4), seed=(0, 1)).run()
    assert [(r.spec.topology.n_addrs, r.spec.costs.seed) for r in new] \
        == [(q["_config"].n_addrs, q["_config"].seed) for q in legacy]
    for r, q in zip(new, legacy):
        _assert_same(r.stats, q)


# ------------------------------------------------------------ deprecation

def test_legacy_entry_points_emit_deprecation_warning():
    p = SimParams(protocol="amo", n_cores=8, cycles=60)
    with pytest.warns(DeprecationWarning, match="repro.sync.run"):
        sim_mod.run(p)
    with pytest.warns(DeprecationWarning, match="repro.sync.Study"):
        sweep_mod.sweep([p])
    with pytest.warns(DeprecationWarning, match="grid"):
        sweep_mod.sweep_grid(p, seed=(0,))


# ------------------------------------------------- construction-time errors

@pytest.mark.parametrize("ctor", [Spec, SimParams])
def test_unknown_protocol_names_registry(ctor):
    with pytest.raises(ValueError) as e:
        ctor(protocol="no_such_protocol")
    for name in protocols.names():               # error lists every entry
        assert name in str(e.value)


@pytest.mark.parametrize("ctor", [Spec, SimParams])
def test_unknown_workload_names_registry(ctor):
    with pytest.raises(ValueError) as e:
        ctor(workload="no_such_workload")
    for name in workloads.names():
        assert name in str(e.value)


@pytest.mark.parametrize("bad", [dict(n_cores=0), dict(n_cores=-4),
                                 dict(cycles=0), dict(n_addrs=0),
                                 dict(unroll=0), dict(q_slots=0),
                                 dict(workload="ms_queue", n_addrs=1)])
@pytest.mark.parametrize("ctor", [Spec, SimParams])
def test_invalid_values_raise_at_construction(ctor, bad):
    with pytest.raises(ValueError):
        ctor(**bad)


def test_unknown_spec_field_rejected():
    with pytest.raises(ValueError, match="unknown Spec field"):
        Spec(n_cores=8, frequency=3)
    with pytest.raises(ValueError, match="unknown Spec field"):
        Spec(n_cores=8).replace(frequency=3)
    with pytest.raises(ValueError, match="unknown protocol field"):
        Spec(protocol={"name": "colibri", "slots": 8})


# ------------------------------------------------------- Spec construction

def test_spec_construction_forms_agree():
    flat = Spec(protocol="lrscwait", workload="ms_queue", q_slots=8,
                n_cores=64, n_addrs=2, lat=3, seed=7)
    grouped = Spec(protocol={"name": "lrscwait", "q_slots": 8},
                   workload="ms_queue",
                   topology={"n_cores": 64, "n_addrs": 2},
                   costs={"lat": 3, "seed": 7})
    typed = Spec(protocol={"name": "lrscwait", "q_slots": 8},
                 workload="ms_queue",
                 topology=Topology(n_cores=64, n_addrs=2),
                 costs=Costs(lat=3, seed=7))
    assert flat == grouped == typed
    assert flat == Spec.from_dict(flat.to_dict())          # nested dict
    assert flat == Spec.from_json(flat.to_json())          # JSON
    assert flat == Spec.from_params(flat.to_params())      # SimParams lift
    assert hash(flat) == hash(grouped)                     # dict-key usable


def test_spec_replace_partial_groups():
    base = Spec(protocol="colibri", n_cores=64)
    r = base.replace(protocol="lrsc", topology={"n_addrs": 8}, seed=3)
    assert r.protocol.name == "lrsc"
    assert r.protocol.q_slots == base.protocol.q_slots     # kept
    assert r.topology.n_addrs == 8 and r.topology.n_cores == 64
    assert r.costs.seed == 3
    assert base.costs.seed == 0                            # frozen


def test_spec_replace_group_instance_plus_flat_field():
    """A whole-group instance and a flat field of the same group compose
    regardless of kwarg order: the flat change lands on top."""
    base = Spec(protocol="colibri")
    for r in (base.replace(costs=Costs(cycles=100), seed=5),
              base.replace(seed=5, costs=Costs(cycles=100))):
        assert r.costs.cycles == 100 and r.costs.seed == 5


def test_spec_covers_every_simparams_field():
    """Adding a SimParams field without classifying it into a Spec
    sub-group must fail loudly (the twin of the sweep's STATIC/DYN
    coverage test).  The FaultPlan group is the one non-flattened
    group: its flat fields all route through Spec, but they lower onto
    the single ``SimParams.faults`` field."""
    from repro.faults import FaultPlan
    fault_fields = {f.name for f in dataclasses.fields(FaultPlan)}
    flat = set(_FLAT_TO_GROUP) | {"protocol", "workload", "topology"}
    assert fault_fields <= set(_FLAT_TO_GROUP)
    assert (flat - fault_fields) | {"faults"} == \
        {f.name for f in dataclasses.fields(SimParams)}


# ------------------------------------------------------------------ Result

def test_result_json_round_trip_preserves_triple():
    r = run(Spec(protocol="colibri", workload="ms_queue", n_cores=16,
                 cycles=400, **scenario("ms_queue")))
    r2 = Result.from_json(r.to_json())
    assert r2.spec == r.spec
    assert (r2.throughput, r2.jain_fairness, r2.energy_pj_per_op) \
        == (r.throughput, r.jain_fairness, r.energy_pj_per_op)
    assert (r2.lat_p50, r2.lat_p95, r2.lat_max) \
        == (r.lat_p50, r.lat_p95, r.lat_max)
    assert r2.polls == r.polls and r2.ops_total == r.ops_total
    # a second serialization round is stable (metrics-only stats)
    assert json.loads(r2.to_json()) == json.loads(r.to_json())


def test_result_row_is_strict_json_safe():
    r = run(Spec(protocol="colibri", n_cores=8, cycles=300))
    row = r.to_row(figure="x", extra_ratio=float("nan"))
    json.dumps(row)                                        # no Infinity/NaN
    assert row["figure"] == "x" and row["extra_ratio"] is None
    for k in ("throughput", "jain_fairness", "energy_pj_per_op",
              "lat_p95"):
        assert isinstance(row[k], float) and math.isfinite(row[k])
    starved = Result(spec=r.spec,
                     stats={**dict(r.stats),
                            "fairness_span": float("inf")})
    assert starved.to_row()["fairness_span"] is None
    # a starved span survives the JSON round trip as inf (not a dropped
    # key that would KeyError the accessor and shrink later rows)
    back = Result.from_json(starved.to_json())
    assert back.fairness_span == math.inf
    assert back.to_row()["fairness_span"] is None


# ------------------------------------------------------------------- Study

def test_study_grid_zip_ordering_and_immutability():
    s0 = Study(protocol="amo", n_cores=8, cycles=100)
    s1 = s0.grid(n_addrs=(1, 2), lat=(3, 5))
    s2 = s1.zip(seed=(0, 1), work=(10, 12))
    assert len(s0) == 1 and len(s1) == 4 and len(s2) == 8  # forks kept
    pts = [(x.topology.n_addrs, x.costs.lat, x.costs.seed, x.costs.work)
           for x in s2.specs()]
    assert pts == [(1, 3, 0, 10), (1, 3, 1, 12), (1, 5, 0, 10),
                   (1, 5, 1, 12), (2, 3, 0, 10), (2, 3, 1, 12),
                   (2, 5, 0, 10), (2, 5, 1, 12)]


def test_study_axis_errors():
    st = Study(protocol="amo")
    with pytest.raises(ValueError, match="equal length"):
        st.zip(seed=(0, 1), lat=(1,))
    with pytest.raises(ValueError, match="empty"):
        st.grid(seed=())
    with pytest.raises(ValueError):                        # unknown field
        st.grid(n_banks=(1, 2)).specs()
    with pytest.raises(ValueError):                        # bad value, eager
        st.grid(n_cores=(8, 0)).specs()
    with pytest.raises(ValueError):
        Study.from_specs([])
