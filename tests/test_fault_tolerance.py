"""Fault-tolerance integration tests: checkpoint/restart, elastic reshard,
event-driven coordination (the framework-level Mwait analogue)."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.distributed import ElasticController, EventCoordinator
from repro.launch.train import TrainRun, run_training

SHAPE = ShapeSpec("smoke", 64, 4, "train")


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "q": (jnp.zeros((2, 2), jnp.int8), jnp.ones((2, 1)))}
    ck.save(7, tree, wait=True)
    assert ck.latest_step() == 7
    restored = ck.restore(7, jax.eval_shape(lambda: tree))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_torn_save_is_invisible(tmp_path):
    """A crash mid-save (no manifest) must not be picked up by latest_step."""
    ck = Checkpointer(str(tmp_path))
    ck.save(3, {"x": jnp.ones(4)}, wait=True)
    os.makedirs(os.path.join(str(tmp_path), "step_000000009"), exist_ok=True)
    assert ck.latest_step() == 3                  # no manifest -> ignored


def test_failure_resume_bit_identical(tmp_path):
    """Kill training mid-run, resume from checkpoint, and land on the SAME
    final loss as an uninterrupted run (deterministic pipeline + optimizer).
    """
    cfg = get_config("smollm-135m-smoke")
    steps, ckpt_every = 8, 2

    # uninterrupted reference
    run_a = TrainRun(cfg=cfg, shape=SHAPE, steps=steps,
                     ckpt_dir=str(tmp_path / "a"), ckpt_every=ckpt_every,
                     log_every=100)
    ref = run_training(run_a)

    # crash at step 5 (after the step-4 checkpoint), then resume
    run_b = TrainRun(cfg=cfg, shape=SHAPE, steps=steps,
                     ckpt_dir=str(tmp_path / "b"), ckpt_every=ckpt_every,
                     log_every=100)
    with pytest.raises(RuntimeError, match="simulated failure"):
        run_training(run_b, crash_at=5)
    resumed = run_training(run_b, resume=True)

    assert np.isclose(ref["loss"], resumed["loss"], rtol=1e-5), \
        (ref["loss"], resumed["loss"])
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-5, atol=1e-6),
        ref["params"], resumed["params"])


def test_elastic_restore_different_topology(tmp_path):
    """Restore a checkpoint into a differently-sharded target (elastic
    rescale path) — values must survive resharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path))
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    ck.save(1, {"w": x}, wait=True)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))      # version-proof axis_types shim
    restored = ck.restore(
        1, {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
        sharding_fn=lambda path, t: NamedSharding(mesh, P("data", None)))
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))


def test_event_coordinator_no_polling():
    """Waiters sleep until notified (Mwait semantics incl. expected-value)."""
    coord = EventCoordinator()
    results = []

    def waiter():
        results.append(coord.wait("ckpt", timeout=5.0))

    t = threading.Thread(target=waiter)
    t.start()
    coord.notify("ckpt", step=42)
    t.join(timeout=5.0)
    assert results == [{"step": 42}]
    # expected-value check: value already differs -> immediate return
    out = coord.wait("ckpt", expected=None, timeout=0.1)
    assert out == {"step": 42}
    with pytest.raises(TimeoutError):
        coord.wait("never", timeout=0.05)


def test_elastic_controller_membership():
    coord = EventCoordinator()
    ctl = ElasticController(coord, n_workers=4)
    assert ctl.healthy()
    coord.notify("worker_failed", worker=2)
    assert not ctl.healthy()
    assert coord.value("membership_changed") == {"alive": 3}
    coord.notify("worker_joined", worker=2)
    assert ctl.healthy()


def test_async_save_overlaps_and_notifies(tmp_path):
    coord = EventCoordinator()
    ck = Checkpointer(str(tmp_path), coordinator=coord)
    seen = []
    coord.subscribe("checkpoint_saved", lambda step: seen.append(step))
    ck.save(11, {"x": jnp.ones((256, 256))})
    ck.wait()
    assert seen == [11]
    assert ck.latest_step() == 11
