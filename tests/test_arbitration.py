"""Arbitration-primitive equivalence and overflow regression tests.

The engine's per-cycle hot path replaced two O(n log n)/overflow-prone
constructs with linear-time ones:

* rotating-fair network acceptance: a stable ``jnp.argsort`` ranking
  became a permutation-scatter + cumsum rank
  (:func:`repro.core.sim.accept_rotating_fair`);
* per-bank FIFO arbitration: the fused ``arr_cyc * (n + 1) + rot`` int32
  key became two chained segment-mins
  (:func:`repro.core.sim.fifo_bank_winners`).

Both must select **exactly** the same winners as the constructs they
replaced — the protocol golden values in ``tests/test_protocols.py``
depend on it.  Hypothesis drives random (request-mask, budget, rotation)
triples against reference implementations of the old paths; the
overflow test pins the one behaviour that intentionally changed: at
``n_cores = 1024`` the old key wrapped past int32 once a request's
arrival stamp crossed ~2.09M cycles, inverting FIFO order, while the
new path serves the true oldest request over the whole int32 horizon.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sim import accept_rotating_fair, fifo_bank_winners


# ---- reference implementations: the pre-overhaul constructs ----------

def _accept_argsort_ref(all_req, rot, budget):
    """The former acceptance path: stable argsort of rotated priority."""
    n = all_req.shape[0]
    big = np.iinfo(np.int32).max
    order = np.argsort(np.where(all_req, rot, big), kind="stable")
    rank = np.zeros(n, np.int32)
    rank[order] = np.arange(n, dtype=np.int32)
    return all_req & (rank < budget)


def _fifo_key_ref(arrived, arr_cyc, rot, addr, a, n, dtype=np.int64):
    """The former FIFO path: fused arrival-stamp/rotation key (computed
    in ``dtype`` — int64 gives the intended no-overflow semantics, int32
    reproduces the latent wrap bug)."""
    big = np.iinfo(dtype).max
    key = (arr_cyc.astype(dtype) * (n + 1) + rot).astype(dtype)
    bkey = np.where(arrived, key, big)
    best = np.full(a, big, dtype)
    np.minimum.at(best, addr[arrived], bkey[arrived])
    return arrived & (bkey == best[addr])


def _case(rng, n):
    """One random (request-mask, rotation, bank-map, stamps) tuple."""
    all_req = rng.random(n) < rng.uniform(0.05, 0.95)
    rot = rng.permutation(n).astype(np.int32)
    a = int(rng.integers(1, max(n // 4, 2)))
    addr = rng.integers(0, a, n).astype(np.int32)
    arr_cyc = rng.integers(0, 5000, n).astype(np.int32)
    return all_req, rot, a, addr, arr_cyc


def test_accept_matches_argsort_reference_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=120, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(1, 96),
           st.integers(0, 130))
    def prop(seed, n, budget):
        rng = np.random.default_rng(seed)
        all_req, rot, _, _, _ = _case(rng, n)
        want = _accept_argsort_ref(all_req, rot, budget)
        got = np.asarray(accept_rotating_fair(
            jnp.asarray(all_req), jnp.asarray(rot), jnp.int32(budget)))
        assert np.array_equal(got, want), (seed, n, budget)
        # the engine's affine-rotation fast path (roll/cumsum/roll, no
        # scatter) must agree with the argsort reference too
        shift = int(rng.integers(0, 10 * n)) % n
        arot = ((np.arange(n) + shift) % n).astype(np.int32)
        want2 = _accept_argsort_ref(all_req, arot, budget)
        got2 = np.asarray(accept_rotating_fair(
            jnp.asarray(all_req), jnp.asarray(arot), jnp.int32(budget),
            shift=jnp.int32(shift)))
        assert np.array_equal(got2, want2), (seed, n, budget, shift)

    prop()


def test_fifo_winners_match_key_reference_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=120, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(1, 96))
    def prop(seed, n):
        rng = np.random.default_rng(seed)
        all_req, rot, a, addr, arr_cyc = _case(rng, n)
        arrived = all_req
        want = _fifo_key_ref(arrived, arr_cyc, rot, addr, a, n)
        got = np.asarray(fifo_bank_winners(
            jnp.asarray(arrived), jnp.asarray(arr_cyc), jnp.asarray(rot),
            jnp.asarray(addr), a))
        assert np.array_equal(got, want), (seed, n)
        # exactly one winner per bank with >=1 arrived request
        banks = np.unique(addr[arrived])
        per_bank = np.bincount(addr[got], minlength=a)
        assert np.array_equal(np.sort(np.nonzero(per_bank)[0]), banks)
        assert per_bank.max(initial=0) <= 1

    prop()


def test_fifo_long_horizon_no_int32_overflow():
    """Regression for the latent int32 FIFO-key overflow: at n=1024 the
    old ``arr_cyc * 1025 + rot`` key wraps once ``arr_cyc`` crosses
    ~2.09M cycles, making the *younger* request win.  The segment-min
    path keeps true FIFO order at the full int32 horizon."""
    n, a = 1024, 4
    wrap_stamp = (np.iinfo(np.int32).max // (n + 1)) + 16     # wraps old key
    old_stamp = wrap_stamp - 1000                             # older, no wrap
    arrived = np.zeros(n, bool)
    arrived[[3, 700]] = True
    addr = np.zeros(n, np.int32)                              # same bank
    arr_cyc = np.full(n, -1, np.int32)
    arr_cyc[3] = wrap_stamp                                   # younger
    arr_cyc[700] = old_stamp                                  # true oldest
    rot = np.roll(np.arange(n, dtype=np.int32), 7)
    got = np.asarray(fifo_bank_winners(
        jnp.asarray(arrived), jnp.asarray(arr_cyc), jnp.asarray(rot),
        jnp.asarray(addr), a))
    assert got[700] and not got[3]                            # FIFO upheld
    # the int64 reference agrees; the int32 reference reproduces the bug
    ref64 = _fifo_key_ref(arrived, arr_cyc, rot, addr, a, n, np.int64)
    ref32 = _fifo_key_ref(arrived, arr_cyc, rot, addr, a, n, np.int32)
    assert np.array_equal(got, ref64)
    assert ref32[3] and not ref32[700]                        # the old bug


def test_fifo_long_horizon_random_stamps():
    """Lexicographic (stamp, rot) order holds across the whole int32
    stamp range, n=1024, many banks."""
    rng = np.random.default_rng(7)
    n, a = 1024, 16
    arrived = rng.random(n) < 0.5
    addr = rng.integers(0, a, n).astype(np.int32)
    arr_cyc = rng.integers(0, np.iinfo(np.int32).max - 1, n,
                           dtype=np.int64).astype(np.int32)
    rot = rng.permutation(n).astype(np.int32)
    want = _fifo_key_ref(arrived, arr_cyc, rot, addr, a, n, np.int64)
    got = np.asarray(fifo_bank_winners(
        jnp.asarray(arrived), jnp.asarray(arr_cyc), jnp.asarray(rot),
        jnp.asarray(addr), a))
    assert np.array_equal(got, want)
