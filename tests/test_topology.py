"""Topology subsystem (``repro.core.topologies``): the hierarchical-NoC
registry, its compiled placement tables, and the engine's network-stage
integration.

The contract under test, in cost order:

* **the tables are a lawful cover** — for every registered topology and
  any (n, a, clusters) shape, each (core, bank) pair gets exactly one
  hop path (the compile is deterministic and total), hop counts are odd
  (1 + 2 per crossed level), level crossings nest (crossing level l+1
  implies crossing level l — the pairing tree), and the extra latency
  is monotone in the hop count.  Property-tested with hypothesis when
  the container has it, and always with a seeded random sweep so the
  guarantee never silently disappears;
* **flat is free** — under ``topology="flat"`` the ``clusters`` knob is
  statically irrelevant: every protocol × workload point is
  bit-identical across cluster settings, and no ``hops`` stat appears;
* **clusters are backend-agnostic** — the Pallas fused-step path never
  sees the topology (extra latency is billed once at issue, link caps
  run in the engine's network stage), so xla_cpu and pallas_interpret
  stay bit-identical on the hierarchical topologies too;
* **hop energy is additive** — ``energy_pj_per_op`` bills exactly
  ``e_hop × hops / ops`` on top of the flat decomposition;
* the windowed telemetry splits accepted traffic into intra- vs
  cross-cluster messages (zero cross-cluster under flat);
* ``nb_feb``'s full/empty bit tracks its queue (``feb == (qlen == 0)``)
  through grants, parks, and watchdog evictions — the invariant the
  model checker certifies, exercised here directly on the hooks.
"""
import types

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocols, topologies, workloads
from repro.core.protocols.base import (OUT_EVICT, OUT_GRANT, OUT_SLEEP,
                                       Ctx, FusedCtx)
from repro.core.sim import SimParams, _run
from repro.core.topologies import LinkLevel, Topology, base as topo_base
from repro.core.topologies import registry as topo_registry
from repro.sync import Spec, run

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:          # container without hypothesis: the seeded
    given = None             # sweep below covers the same property


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_contents_and_errors():
    assert set(topologies.names()) >= {"flat", "cluster2", "cluster3"}
    with pytest.raises(KeyError, match="registered"):
        topo_registry.get("no_such_topology")

    class Dup(Topology):
        name = "flat"

    with pytest.raises(ValueError, match="duplicate"):
        topo_registry.register(Dup)

    class Anon(Topology):
        pass

    with pytest.raises(ValueError, match="no name"):
        topo_registry.register(Anon)


def test_link_level_validation():
    with pytest.raises(ValueError, match="extra_lat"):
        LinkLevel("bad", extra_lat=-1, bw_div=1)
    with pytest.raises(ValueError, match="bw_div"):
        LinkLevel("bad", extra_lat=0, bw_div=0)


def test_spec_routes_topology():
    s = Spec(protocol="colibri", topology="cluster2", clusters=8)
    p = s.to_params()
    assert p.topology == "cluster2" and p.clusters == 8
    assert Spec.from_json(s.to_json()) == s
    assert s.replace(topology="flat").to_params().topology == "flat"
    with pytest.raises(ValueError):
        SimParams(protocol="colibri", topology="no_such_topology",
                  n_cores=8, cycles=100)


# ---------------------------------------------------------------------------
# placement tables: a lawful permutation-free cover
# ---------------------------------------------------------------------------

def _check_tables(topo, clusters: int, n: int, a: int) -> None:
    """The full table lawfulness property for one (topology, shape)."""
    p = types.SimpleNamespace(clusters=clusters)
    t = topo.tables(p, n, a)
    t2 = topo.tables(p, n, a)
    # exactly one path per (core, bank): the compile is a deterministic
    # total function of the shape — every pair covered, never two answers
    assert t.hops.shape == t.extra.shape == (n, a)
    np.testing.assert_array_equal(t.hops, t2.hops)
    np.testing.assert_array_equal(t.extra, t2.extra)
    assert len(t.cross) == len(topo.levels)
    # hop law: 1 + 2 per crossed level, so always odd and >= 1
    crossings = sum((x.astype(np.int64) for x in t.cross),
                    np.zeros((n, a), np.int64))
    np.testing.assert_array_equal(t.hops, 1 + 2 * crossings)
    assert (t.hops >= 1).all() and ((t.hops - 1) % 2 == 0).all()
    # extra law: per-level latencies of exactly the crossed levels
    want = sum((lv.extra_lat * x.astype(np.int64)
                for lv, x in zip(topo.levels, t.cross)),
               np.zeros((n, a), np.int64))
    np.testing.assert_array_equal(t.extra, want)
    assert (t.extra >= 0).all() and (t.extra[t.hops == 1] == 0).all()
    # nesting: crossing an outer level implies crossing every inner one
    for inner, outer in zip(t.cross, t.cross[1:]):
        assert (~outer | inner).all(), "level crossings must nest"
    # monotone: same hop count => same extra; more hops => >= extra
    by_hops = {}
    for h, e in zip(t.hops.ravel().tolist(), t.extra.ravel().tolist()):
        by_hops.setdefault(h, set()).add(e)
    assert all(len(v) == 1 for v in by_hops.values())
    ladder = [next(iter(by_hops[h])) for h in sorted(by_hops)]
    assert ladder == sorted(ladder)
    # placement ids stay in range
    assert t.core_cluster.shape == (n,) and t.bank_cluster.shape == (a,)
    assert (0 <= t.core_cluster).all()
    assert (t.core_cluster < max(1, min(clusters, n))).all()
    assert (0 <= t.bank_cluster).all()
    assert (t.bank_cluster < max(1, min(clusters, max(a, 1)))).all()
    assert t.is_flat == (not topo.levels)
    if t.is_flat:
        assert (t.hops == 1).all() and (t.extra == 0).all()


def test_tables_property_seeded_sweep():
    rng = np.random.default_rng(20240808)
    shapes = [(2, 1, 1), (2, 1, 2), (4, 2, 2), (5, 3, 2), (16, 4, 4),
              (33, 7, 4), (64, 16, 8), (256, 16, 4)]
    shapes += [(int(rng.integers(2, 129)), int(rng.integers(1, 33)),
                int(rng.integers(1, 17))) for _ in range(40)]
    for n, a, clusters in shapes:
        for name in topologies.names():
            _check_tables(topo_registry.get(name), clusters, n, a)


if given is not None:
    @given(st.integers(2, 256), st.integers(1, 64), st.integers(1, 32),
           st.sampled_from(["flat", "cluster2", "cluster3"]))
    @settings(max_examples=80, deadline=None)
    def test_tables_property_hypothesis(n, a, clusters, name):
        _check_tables(topo_registry.get(name), clusters, n, a)


def test_block_placement_matches_hw_event_geometry():
    """cluster_of must agree with the hw_event protocol's group split,
    so the event unit a core registers with IS its topology cluster."""
    from repro.core.protocols.hw_event import HwEvent
    for n, clusters in ((8, 2), (16, 4), (13, 4), (7, 8)):
        p = types.SimpleNamespace(topology="cluster2", clusters=clusters,
                                  n_groups=999)
        g, gsz, _ = HwEvent._geom(p, n)
        cc = topo_base.cluster_of(np.arange(n), n, clusters)
        np.testing.assert_array_equal(
            cc, np.minimum(np.arange(n) // gsz, g - 1))


# ---------------------------------------------------------------------------
# flat is free: clusters statically irrelevant, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("protocol", protocols.names())
def test_flat_bit_identical_across_cluster_knob(protocol):
    """Full protocol × workload grid: under topology="flat" the
    clusters knob (a static recompile) must not move a single bit, and
    no hops stat may appear."""
    for wl in workloads.names():
        base = dict(protocol=protocol, workload=wl, n_cores=16,
                    n_addrs=4, cycles=700)
        r1 = _run(SimParams(clusters=1, **base))
        r4 = _run(SimParams(clusters=4, **base))
        assert "hops" not in r1 and "hops" not in r4
        assert set(r1) == set(r4)
        for k in sorted(r1):
            np.testing.assert_array_equal(
                np.asarray(r1[k]), np.asarray(r4[k]),
                err_msg=f"{protocol}/{wl}: field {k!r} diverged")


# ---------------------------------------------------------------------------
# hierarchical topologies: backend parity and engine effects
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("protocol,topology",
                         [("colibri", "cluster2"),
                          ("lrscwait", "cluster2"),
                          ("hw_event", "cluster2"),
                          ("nb_feb", "cluster2"),
                          ("colibri_hier", "cluster3")])
def test_cluster_backend_parity(protocol, topology):
    """xla_cpu and pallas_interpret stay bit-identical per topology —
    the kernel never sees the tables (billed at issue / network stage)."""
    res = {}
    for backend in ("xla_cpu", "pallas_interpret"):
        res[backend] = _run(SimParams(
            protocol=protocol, workload="zipf_histogram", backend=backend,
            topology=topology, clusters=4, n_cores=32, n_addrs=4,
            cycles=900))
    a, b = res["xla_cpu"], res["pallas_interpret"]
    assert set(a) == set(b)
    for k in sorted(a):
        np.testing.assert_array_equal(
            np.asarray(a[k]), np.asarray(b[k]),
            err_msg=f"{protocol}/{topology}: field {k!r} diverged")
    assert int(a["hops"]) > 0 and int(a["ops"].sum()) > 0


def test_cluster_slows_contention_and_counts_hops():
    """Hierarchical latency + per-level link caps must cost throughput
    on a contended workload, and every remote acceptance adds hops."""
    base = dict(protocol="colibri", workload="zipf_histogram", n_cores=32,
                n_addrs=4, cycles=1500, zipf_skew=200)
    flat = _run(SimParams(**base))
    c2 = _run(SimParams(topology="cluster2", clusters=4, **base))
    c3 = _run(SimParams(topology="cluster3", clusters=8, **base))
    assert "hops" not in flat
    assert int(c2["hops"]) > 0 and int(c3["hops"]) > 0
    assert int(flat["ops"].sum()) > int(c2["ops"].sum()) > 0
    assert int(c2["ops"].sum()) >= int(c3["ops"].sum()) > 0


def test_hop_energy_is_additive():
    """energy_per_op with a hops stat = flat decomposition + e_hop·hops
    per op, exactly."""
    from repro.core import costmodel, metrics
    res = _run(SimParams(protocol="colibri", workload="zipf_histogram",
                         topology="cluster2", clusters=4, n_cores=32,
                         n_addrs=4, cycles=900))
    stats = metrics.energy_stats(res)
    assert stats["hops"] > 0
    fit = costmodel.default_fit()
    with_hops = costmodel.energy_per_op(stats, fit)
    without = costmodel.energy_per_op(
        {k: v for k, v in stats.items() if k != "hops"}, fit)
    np.testing.assert_allclose(
        with_hops - without, fit.e_hop * stats["hops"] / stats["ops"],
        rtol=1e-12)


def test_noc_telemetry_splits_local_and_cross_cluster():
    base = dict(protocol="colibri", workload="zipf_histogram", n_cores=32,
                n_addrs=4, cycles=1200, telemetry_windows=12,
                zipf_skew=150)
    flat = run(Spec(**base)).timeseries()
    c2 = run(Spec(topology="cluster2", clusters=4, **base)).timeseries()
    assert flat.counts("xcl_msgs").sum() == 0
    assert flat.counts("loc_msgs").sum() > 0
    assert c2.counts("xcl_msgs").sum() > 0
    assert c2.counts("loc_msgs").sum() > 0
    # the named accessors are per-cycle rates over the same windows
    assert c2.cross_cluster_msgs.shape == (c2.n_used,)
    assert (c2.local_msgs >= 0).all()


def test_perfetto_noc_counter_track(tmp_path):
    import json

    from repro import obs
    r = run(Spec(protocol="colibri", workload="zipf_histogram",
                 topology="cluster2", clusters=4, n_cores=16, n_addrs=4,
                 cycles=800, record_trace=True, telemetry_windows=8,
                 zipf_skew=150))
    path = obs.perfetto.export(r, tmp_path / "noc.json")
    with open(path) as f:
        evs = json.load(f)["traceEvents"]
    noc = [e for e in evs if e["ph"] == "C" and e["name"] == "link msgs"]
    assert noc, "telemetry-backed NoC counter track missing"
    assert sum(e["args"]["cross_cluster"] for e in noc) > 0
    assert sum(e["args"]["local"] for e in noc) > 0


# ---------------------------------------------------------------------------
# nb_feb: the full/empty bit tracks the queue
# ---------------------------------------------------------------------------

def _feb_ok(bank) -> bool:
    return bool(np.asarray(bank["feb"])[0]) == \
        (int(np.asarray(bank["qlen"])[0]) == 0)


def test_nb_feb_bit_tracks_queue_through_eviction():
    """feb == (qlen == 0) after every grant, park, and watchdog
    eviction — including draining the queue by evicting dead cores,
    where a stale empty bit would deadlock the bank forever."""
    proto = protocols.get("nb_feb")
    p = SimParams(protocol="nb_feb", n_cores=3, n_addrs=1, cycles=100)
    n, a = 3, 1
    q_cap = proto.q_cap(p, n)
    bank = proto.init_bank_state(p, a, n, q_cap)
    assert bool(np.asarray(bank["feb"])[0]) and _feb_ok(bank)
    expect = [OUT_GRANT, OUT_SLEEP, OUT_SLEEP]
    for c in range(n):
        fx = FusedCtx(p=p, n=n, a=a, q_cap=q_cap,
                      win=jnp.asarray([c], jnp.int32),
                      acq_b=jnp.asarray([True]),
                      rel_b=jnp.asarray([False]))
        bank, fo = proto.fused_access(fx, dict(bank))
        assert int(fo.kind[0]) == expect[c]
        assert _feb_ok(bank)
    assert int(np.asarray(bank["qlen"])[0]) == 3
    # every core dies; the watchdog evicts the head one timeout at a
    # time until the bank drains — the bit must flip full again exactly
    # when the queue empties
    z = jnp.zeros((n,), bool)
    zb = jnp.zeros((a,), bool)
    ctx = Ctx(p=p, n=n, a=a, q_cap=q_cap, is_acq=z, is_rel=z,
              wa=jnp.zeros((n,), jnp.int32),
              wc=jnp.arange(n, dtype=jnp.int32),
              ba=jnp.arange(a, dtype=jnp.int32),
              win_core=jnp.full((a,), n, jnp.int32), acq_b=zb, rel_b=zb,
              mod_dur=jnp.ones((n,), jnp.int32))
    cs = dict(st=jnp.zeros((n,), jnp.int32), tmr=jnp.zeros((n,), jnp.int32),
              nxt=jnp.full((n,), -1, jnp.int32),
              polls=jnp.zeros((), jnp.int32), msgs=jnp.zeros((), jnp.int32))
    killed = jnp.ones((n,), bool)
    for left in (2, 1, 0):
        cs, bank, kind = proto.on_timeout(
            ctx, cs, dict(bank), jnp.asarray([True]), killed,
            jnp.asarray([0], jnp.int32))
        assert int(kind[0]) == OUT_EVICT
        assert int(np.asarray(bank["qlen"])[0]) == left
        assert _feb_ok(bank)
    assert bool(np.asarray(bank["feb"])[0])
