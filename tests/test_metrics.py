"""Metrics subsystem (`core.metrics`) + energy-model contract fixes.

Covers: Jain-index properties, the NaN-safe fairness span (the former
``max / max(min, 1e-9)`` span manufactured ~1e9 pseudo-values whenever a
core starved), completion-latency percentiles against a pure-NumPy
trace oracle (exact on the trace path, ≤ one geometric-bucket width on
the always-on histogram path), energy threading through ``sweep()``,
the `fit_energy` required-key validation, the BARWAIT clock-gated
energy billing regression, and the degenerate configurations.
"""
import math

import numpy as np
import pytest

from repro.core import costmodel
from repro.core.metrics import (LAT_SUB, METRIC_TRIPLE, energy_stats,
                                fairness_span, jain_fairness, json_safe,
                                latency_percentiles)
from repro.core.sim import SimParams, run
from repro.core.sweep import sweep


# ------------------------------------------------------------------ fairness

def test_jain_uniform_is_one():
    assert jain_fairness(np.full(64, 17)) == pytest.approx(1.0)


def test_jain_monopoly_is_one_over_n():
    for n in (4, 64, 256):
        x = np.zeros(n)
        x[0] = 123
        assert jain_fairness(x) == pytest.approx(1.0 / n)


def test_jain_degenerate_slices():
    assert jain_fairness(np.array([])) == 0.0
    assert jain_fairness(np.zeros(8)) == 0.0
    assert 0.0 < jain_fairness(np.array([1, 0, 0, 1])) < 1.0


def test_jain_scale_invariant():
    x = np.array([3, 1, 4, 1, 5, 9])
    assert jain_fairness(x) == pytest.approx(jain_fairness(x * 1000))


def test_fairness_span_nan_safe():
    assert fairness_span(np.full(8, 5)) == pytest.approx(1.0)
    assert fairness_span(np.array([10, 5])) == pytest.approx(2.0)
    assert fairness_span(np.array([10, 0])) == math.inf   # starved core
    assert fairness_span(np.zeros(4)) == 0.0              # nothing ran
    assert fairness_span(np.array([])) == 0.0
    assert json_safe(math.inf) is None                    # report-safe
    assert json_safe(2.0) == 2.0


# ------------------------------------------------------------------- latency

def _oracle(waits: np.ndarray, q: float) -> float:
    """Independent inverted-CDF percentile: value at rank ceil(q*k)."""
    s = np.sort(waits)
    return float(s[max(int(math.ceil(q * s.size)), 1) - 1])


@pytest.mark.parametrize("proto", ("lrsc", "colibri"))
def test_latency_percentiles_vs_trace_oracle(proto):
    """Trace path is exact against a pure-NumPy oracle; the always-on
    histogram path agrees within one geometric bucket width and its max
    is exact."""
    kw = dict(protocol=proto, n_cores=32, n_addrs=1, cycles=2500)
    rt = run(SimParams(record_trace=True, **kw))
    tw = np.asarray(rt["trace_wait"])
    waits = tw[tw >= 0]
    assert waits.size > 0
    assert rt["lat_p50"] == _oracle(waits, 0.50)
    assert rt["lat_p95"] == _oracle(waits, 0.95)
    assert rt["lat_max"] == float(waits.max())

    rh = run(SimParams(**kw))                    # histogram path
    assert rh["lat_max"] == float(waits.max())
    assert int(np.asarray(rh["lat_hist"]).sum()) == waits.size
    width = 2.0 ** (1.0 / (2 * LAT_SUB)) * 1.001  # half-bucket each side
    for key, q in (("lat_p50", 0.50), ("lat_p95", 0.95)):
        exact = _oracle(waits, q)
        assert (exact + 1) / width <= rh[key] + 1 <= (exact + 1) * width, \
            (key, rh[key], exact)


def test_latency_histogram_counts_every_completion():
    r = run(SimParams(protocol="colibri", n_cores=16, n_addrs=4, cycles=1500))
    assert int(np.asarray(r["lat_hist"]).sum()) == int(r["opc"].sum())


def test_latency_reflects_retry_storms():
    """LRSC's retry/backoff loops show up in the tail: its p95 acquire
    latency at high contention dominates polling-free Colibri's."""
    kw = dict(n_cores=64, n_addrs=1, cycles=6000)
    lrsc = run(SimParams(protocol="lrsc", **kw))
    col = run(SimParams(protocol="colibri", **kw))
    assert lrsc["lat_p95"] > col["lat_p50"]
    assert lrsc["lat_max"] > 0 and col["lat_max"] > 0


# -------------------------------------------------------------------- energy

def test_energy_threading_through_sweep_equals_per_point():
    """Every sweep() point's energy_pj_per_op equals calling the cost
    model directly on that point's stat totals, for the default frozen
    fit and for a custom fit passed through the energy_fit parameter."""
    configs = [SimParams(protocol=p, n_cores=32, cycles=1200, n_addrs=a)
               for p, a in (("colibri", 1), ("lrsc", 4), ("amo", 16))]
    for cfg, res in zip(configs, sweep(configs)):
        want = costmodel.energy_per_op(energy_stats(res),
                                       costmodel.default_fit())
        assert res["energy_pj_per_op"] == want
    custom = costmodel.EnergyFit(e_msg=1.0, e_bank=2.0, e_active=0.1,
                                 e_backoff=0.2, e_sleep=0.01, residuals={})
    for cfg, res in zip(configs, sweep(configs, energy_fit=custom)):
        want = costmodel.energy_per_op(energy_stats(res), custom)
        assert res["energy_pj_per_op"] == want
        assert res["energy_pj_per_op"] != costmodel.energy_per_op(
            energy_stats(res), costmodel.default_fit())


def test_fit_energy_missing_key_raises_with_name():
    """The seed's fit_energy KeyError'd on the undocumented backoff_cyc;
    now every required key is validated up front with a ValueError that
    names the missing key."""
    r = run(SimParams(protocol="colibri", n_cores=16, n_addrs=1, cycles=800))
    good = energy_stats(r)
    for missing in ("backoff_cyc", "bar_cyc", "ops"):
        bad = {k: v for k, v in good.items() if k != missing}
        with pytest.raises(ValueError, match=missing):
            costmodel.fit_energy({"colibri": bad})
        with pytest.raises(ValueError, match=missing):
            costmodel.energy_per_op(bad, costmodel.default_fit())


def test_barrier_cycles_billed_at_clock_gated_rate():
    """Regression for the energy model dropping bar_cyc: BARWAIT cycles
    (the clock-gated barrier wait of Glaser et al., arXiv:2004.06662)
    are billed at the e_sleep rate, so a barrier_phases run reports
    strictly more energy than the same stats with the barrier wait
    zeroed — by exactly e_sleep * bar_cyc / ops."""
    r = run(SimParams(protocol="colibri", workload="barrier_phases",
                      n_cores=32, n_addrs=1, cycles=4000))
    stats = energy_stats(r)
    assert stats["bar_cyc"] > 0
    fit = costmodel.default_fit()
    with_bar = costmodel.energy_per_op(stats, fit)
    without = costmodel.energy_per_op({**stats, "bar_cyc": 0.0}, fit)
    assert with_bar > without
    assert with_bar - without == pytest.approx(
        fit.e_sleep * stats["bar_cyc"] / stats["ops"])
    assert r["energy_pj_per_op"] == with_bar


def test_frozen_fit_tracks_fresh_calibration():
    """The frozen CALIBRATED_ENERGY constants must stay close to a fresh
    Table II fit on the current engine (same calibration scenario at a
    cheaper cycle count; per-op ratios are stable)."""
    stats = {}
    for proto in ("amo", "colibri", "lrsc", "amo_lock"):
        kw = dict(backoff=128, backoff_exp=1) if proto == "amo_lock" else {}
        stats[proto] = energy_stats(run(SimParams(
            protocol=proto, n_addrs=1, cycles=6000, **kw)))
    fresh = costmodel.fit_energy(stats)
    frozen = costmodel.default_fit()
    for proto in stats:
        a = costmodel.energy_per_op(stats[proto], fresh)
        b = costmodel.energy_per_op(stats[proto], frozen)
        assert abs(a - b) / max(a, 1.0) < 0.25, (proto, a, b)


# ---------------------------------------------------------------- degenerate

def test_all_workers_degenerate_reports_zero_triple():
    """n_workers == n_cores leaves no atomic cores: the whole metric
    family reports 0.0 instead of crashing on empty slices."""
    r = run(SimParams(protocol="colibri", n_cores=8, n_workers=8, n_addrs=1,
                      cycles=500))
    assert r["throughput"] == 0.0
    assert r["jain_fairness"] == 0.0
    assert r["fairness_span"] == 0.0
    assert r["lat_p50"] == 0.0 and r["lat_p95"] == 0.0 and r["lat_max"] == 0.0
    assert r["energy_pj_per_op"] == 0.0
    assert r["worker_rate"] > 0.0


def test_latency_percentiles_empty_inputs():
    out = latency_percentiles({"lat_hist": np.zeros(8, np.int64),
                               "lat_max": np.int32(0)})
    assert out == {"lat_p50": 0.0, "lat_p95": 0.0, "lat_max": 0.0}
    out = latency_percentiles({"trace_wait": np.full((5, 3), -1),
                               "lat_max": np.int32(0)})
    assert out["lat_p95"] == 0.0


def test_metric_triple_always_present():
    """Every run()/sweep() result carries the paper's metric triple —
    with and without workers, traces, and across workloads."""
    cfgs = [
        SimParams(protocol="colibri", n_cores=16, n_addrs=1, cycles=600),
        SimParams(protocol="lrsc", n_cores=16, n_addrs=1, cycles=600,
                  n_workers=4, record_trace=True),
        SimParams(protocol="mwait_lock", workload="ms_queue", n_cores=16,
                  n_addrs=2, cycles=600),
    ]
    for cfg in cfgs:
        r = run(cfg)
        for k in METRIC_TRIPLE:
            assert k in r, (cfg.protocol, k)
    for r in sweep(cfgs):
        for k in METRIC_TRIPLE:
            assert k in r, k
