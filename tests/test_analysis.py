"""Static-analysis subsystem (``repro.analysis``): the model checker,
the trace-safety auditor and the integer-range analyzer.

Three layers of evidence that the gate means something:

* **the matrix** — every registered protocol passes every pass on the
  quick small-scope subset (the exact CI smoke invocation);
* **known-bad protocols** — toy plugins seeded with the classic bugs
  (a dropped wakeup, a poller wearing a retry-free contract, a watchdog
  that evicts live owners) each trip exactly the rule built to catch
  them;
* **mutation checks** — the two bugs this repo actually shipped and
  fixed (the PR 6 ``wake_grp`` cross-bank aliasing, the PR 8 class of
  stale-owner eviction) are re-seeded as protocol mutants and must be
  flagged, so the checker provably covers its origin stories.
"""
import dataclasses
import json

import jax.numpy as jnp
import pytest

from repro.analysis import int_range, model_check, trace_safety
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.model_check import Config, check_protocol
from repro.analysis.report import (Finding, PassReport, all_findings,
                                   fail_fast, summarize)
from repro.analysis.trace_safety import (audit_protocol, audit_static_fields,
                                         expected_scan_carries,
                                         reference_params, scan_carry_count,
                                         scatter_count)
from repro.core import sim
from repro.core.protocols.base import OUT_EVICT, OUT_NONE, Contract
from repro.core.protocols.colibri_hier import ColibriHier
from repro.core.protocols.lrscwait import LrscWait
from repro.core.protocols.registry import names as proto_names

TINY = [Config(n=2, a=1, ops=1)]


def _rules(rep):
    return {f.rule for f in rep.findings}


# ---------------------------------------------------------------------------
# report plumbing
# ---------------------------------------------------------------------------

def test_finding_and_report_plumbing():
    f = Finding("model", "lost-wakeup", "toy", "a sleeper starved",
                where="n=2 a=1")
    assert "model:lost-wakeup" in f.render() and "[n=2 a=1]" in f.render()
    good = PassReport(pass_name="range", subject="backoff")
    bad = PassReport(pass_name="model", subject="toy", findings=[f])
    assert good.ok and not bad.ok
    assert bad.to_dict()["findings"][0]["rule"] == "lost-wakeup"
    json.dumps([good.to_dict(), bad.to_dict()])
    assert all_findings([good, bad]) == [f]
    s = summarize([good, bad])
    assert "ok" in s and "1 finding(s)" in s
    assert "lost-wakeup" in fail_fast([bad], limit=5)
    assert "more" in fail_fast([bad, bad, bad], limit=2)


# ---------------------------------------------------------------------------
# the matrix: every protocol x every pass, quick scope (the CI smoke)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("protocol", proto_names())
def test_model_check_passes_every_protocol(protocol):
    rep = check_protocol(protocol, quick=True)
    assert rep.ok, fail_fast([rep])
    assert rep.stats["states"] > 0 and rep.stats["transitions"] > 0


@pytest.mark.parametrize("protocol", proto_names())
def test_trace_audit_passes_every_protocol(protocol):
    rep = audit_protocol(protocol, quick=True)
    assert rep.ok, fail_fast([rep])
    assert rep.stats["hot_scatters"] <= rep.stats["scatter_budget"]


def test_static_fields_audit_passes():
    assert audit_static_fields().ok


# ---------------------------------------------------------------------------
# known-bad toy protocols: each seeded bug trips its intended rule
# ---------------------------------------------------------------------------

class _ToyLostWakeup(LrscWait):
    """Releases never arm the wake timer — the queued sleeper starves."""
    name = "toy_lost_wakeup"

    def wake_delay(self, p):
        return 0


class _ToyPoller(LrscWait):
    """One queue slot (held by the grantee) turns every contending
    acquire into an immediate FAIL — polling, while the contract still
    claims the paper's retry-free wait-class behaviour."""
    name = "toy_poller"
    contract = Contract(exclusive_grant=True, wait_class=True,
                        retry_free=True, queue_counts_holder=True,
                        max_hot_scatters=4)

    def q_cap(self, p, n):
        return 1


class _ToyLiveEvictor(LrscWait):
    """Watchdog that evicts the queue head without checking it is dead
    — the PR 8 stale-owner bug class, re-seeded: a slow-but-live owner
    loses the reservation and the bank double-grants."""
    name = "toy_live_evictor"

    def on_timeout(self, ctx, cs, bank, stuck_b, killed, owner):
        q_cap = ctx.q_cap
        qhead, qlen = bank["qhead"], bank["qlen"]
        evict_b = stuck_b & (qlen > 0)        # BUG: ignores ``killed``
        qhead = jnp.where(evict_b, (qhead + 1) % q_cap, qhead)
        qlen = qlen - evict_b
        wake_b = evict_b & (qlen > 0)
        bank["wake_tmr"] = jnp.where(wake_b, self.wake_delay(ctx.p),
                                     bank["wake_tmr"])
        bank.update(qhead=qhead, qlen=qlen)
        kind = jnp.where(evict_b, OUT_EVICT, OUT_NONE).astype(jnp.int32)
        return cs, bank, kind


def test_toy_lost_wakeup_is_caught():
    rep = check_protocol(_ToyLostWakeup(), kill=False, configs=TINY)
    assert "lost-wakeup" in _rules(rep), fail_fast([rep]) or "no findings"


def test_toy_poller_is_caught():
    rep = check_protocol(_ToyPoller(), kill=False, configs=TINY)
    assert "retry-free" in _rules(rep), fail_fast([rep]) or "no findings"


def test_toy_live_evictor_is_caught():
    rep = check_protocol(_ToyLiveEvictor(), kill=False, configs=TINY)
    assert "live-evict" in _rules(rep), fail_fast([rep]) or "no findings"


def test_fail_requires_full_rule():
    """A FAIL with queue slots free is flagged even when the contract
    honestly gives up ``retry_free`` — the q=1 poller under lrscwait's
    own contract violates ``fail_requires_full`` instead (the queue has
    a free slot only the holder occupies... q_cap=1 with the holder
    counted IS full, so use q_cap=2: rejecting the second waiter with
    one slot free must be flagged)."""

    class _EarlyRejector(LrscWait):
        name = "toy_early_rejector"

        def q_cap(self, p, n):
            return 2

        def on_access(self, ctx, cs, bank):
            # shrink the admission test only: pretend full at qlen >= 1
            # by lying to the parent about capacity, then restore it for
            # the queue-slot arithmetic via the real q_cap in ctx
            full_ctx = dataclasses.replace(ctx, q_cap=1)
            return super().on_access(full_ctx, cs, bank)

        def fused_access(self, fx, bank):
            return super().fused_access(dataclasses.replace(fx, q_cap=1),
                                        bank)

    rep = check_protocol(_EarlyRejector(), kill=False,
                         configs=[Config(n=3, a=1, ops=1)])
    assert "fail-not-full" in _rules(rep), fail_fast([rep]) or "no findings"


# ---------------------------------------------------------------------------
# mutation checks: the repo's own shipped-and-fixed bugs, re-seeded
# ---------------------------------------------------------------------------

class _WakeGrpAliasing(ColibriHier):
    """The PR 6 bug, verbatim: ``on_wake`` consumes ``wake_grp`` as a
    flat local-queue id without rebasing by ``bank * G``, so any wake on
    a bank other than bank 0 pops (and wakes from) ANOTHER bank's local
    queue."""
    name = "mutant_wake_grp_alias"

    def on_wake(self, ctx, cs, bank):
        from repro.core.protocols.base import MOD
        G, _, cap_l = self._geom(ctx.p, ctx.n)
        wake_tmr = bank["wake_tmr"]
        wq = bank["wake_grp"]                # BUG: missing ba * G rebase
        lqbuf, lqhead, lqlen = bank["lqbuf"], bank["lqhead"], bank["lqlen"]
        fire = wake_tmr == 1
        wake_tmr = jnp.maximum(wake_tmr - 1, 0)
        head_core = lqbuf[wq, lqhead[wq]]
        valid = fire & (lqlen[wq] > 0)
        fire_core = jnp.where(valid, head_core, ctx.n)
        woken = jnp.zeros((ctx.n,), bool).at[fire_core].set(True,
                                                            mode="drop")
        cs["st"] = jnp.where(woken, MOD, cs["st"])
        cs["tmr"] = jnp.where(woken, ctx.mod_dur, cs["tmr"])
        oob = jnp.where(valid, wq, ctx.a * G)
        lqhead = (lqhead.at[oob].add(1, mode="drop")) % cap_l
        lqlen = lqlen.at[oob].add(-1, mode="drop")
        bank.update(wake_tmr=wake_tmr, lqhead=lqhead, lqlen=lqlen)
        return cs, bank, (wake_tmr == 1).sum()


def test_pr6_wake_grp_aliasing_mutant_is_caught():
    """Cross-bank aliasing needs >= 2 banks to exist at all (the PR 6
    lesson: every single-bank test was green) — on the 2-bank 2-group
    config the checker must refute the mutant."""
    rep = check_protocol(_WakeGrpAliasing(), kill=False,
                         configs=[Config(n=4, a=2, ops=1, n_groups=2)])
    assert not rep.ok
    assert _rules(rep) <= {"queue-conservation", "lost-wakeup",
                           "wake-corrupt", "double-grant", "deadlock",
                           "completion-unreachable"}, fail_fast([rep])


def test_pr6_single_bank_config_misses_the_mutant():
    """On one bank the flat id and the group id coincide — the mutant
    is invisible.  This is WHY configs_for pins a multi-bank config for
    colibri_hier; the test locks that in."""
    rep = check_protocol(_WakeGrpAliasing(), kill=False,
                         configs=[Config(n=3, a=1, ops=2, n_groups=2)])
    assert rep.ok
    cfgs = model_check.configs_for("colibri_hier")
    assert any(c.a >= 2 for c in cfgs)


def test_pr8_stale_owner_recovery_is_exercised():
    """The fault pass must actually reach watchdog evictions for the
    wait-class protocols (a dead holder wedges the bank until the FIFO
    recovery hands the reservation on) — otherwise the recovery rules
    are vacuous."""
    rep = check_protocol("lrscwait", quick=False, kill=True,
                         configs=[Config(n=3, a=1, ops=1)])
    assert rep.ok, fail_fast([rep])
    # and with recovery sabotaged (never evict), the same scope must
    # deadlock: proof the kill pass depends on on_timeout being right
    class _NoRecovery(LrscWait):
        name = "mutant_no_recovery"

        def on_timeout(self, ctx, cs, bank, stuck_b, killed, owner):
            kind = jnp.zeros((ctx.a,), jnp.int32)    # OUT_NONE everywhere
            return cs, bank, kind

    bad = check_protocol(_NoRecovery(), kill=True,
                         configs=[Config(n=3, a=1, ops=1)])
    assert "recovery-deadlock" in _rules(bad), fail_fast([bad]) \
        or "no findings"


# ---------------------------------------------------------------------------
# trace-safety auditor: budgets are real, regressions are named
# ---------------------------------------------------------------------------

def test_scan_carry_count_matches_budget():
    for name in ("colibri", "lrscwait", "amo"):
        p = reference_params(name)
        assert scan_carry_count(p) == expected_scan_carries(p)


def test_feature_deltas_are_exact():
    base = reference_params("colibri")
    tele = reference_params("colibri", telemetry_windows=8)
    assert expected_scan_carries(tele) == expected_scan_carries(base) + 1
    assert scan_carry_count(tele) == scan_carry_count(base) + 1


def test_scatter_budget_regression_is_flagged(monkeypatch):
    """Tightening a protocol's declared budget below its real scatter
    count must fail the audit — i.e. a regression REINTRODUCING hot
    scatters is a finding, not a benchmark mystery."""
    from repro.core.protocols.registry import get
    proto = get("lrscwait")
    assert scatter_count(reference_params("lrscwait")) > 0
    monkeypatch.setattr(
        proto, "contract",
        dataclasses.replace(proto.contract, max_hot_scatters=0))
    rep = audit_protocol("lrscwait", quick=True)
    assert "scatter-budget" in _rules(rep)


def test_carry_contract_drift_is_flagged(monkeypatch):
    """Dropping a key from the frozen engine-carry contract desyncs the
    budget from the real scan — the audit must notice."""
    monkeypatch.setattr(trace_safety, "ENGINE_CARRY_KEYS",
                        trace_safety.ENGINE_CARRY_KEYS[:-1])
    rep = audit_protocol("amo", quick=True)
    assert "carry-count" in _rules(rep)


def test_static_knob_drift_is_flagged(monkeypatch):
    monkeypatch.setattr(trace_safety, "CARRY_AFFECTING_FIELDS",
                        trace_safety.CARRY_AFFECTING_FIELDS
                        + ("not_a_static_field",))
    rep = audit_static_fields()
    assert "static-knob" in _rules(rep)


# ---------------------------------------------------------------------------
# integer-range analyzer: the PR 3 wrap as a theorem
# ---------------------------------------------------------------------------

def test_fused_key_threshold_n1024():
    """The PR 3 bug, quantified: at n=1024 the fused arbitration path
    is safe through exactly 2_095_104 cycles."""
    t = int_range.max_safe_cycles(1024)
    assert t == 2_095_104
    # the engine's guard sits exactly on the proved threshold
    assert sim.fused_key_fits_int32(t, 1024)
    assert not sim.fused_key_fits_int32(t + 1, 1024)
    # every admitted key interval fits; the guard keeps ONE cycle of
    # headroom below the int32 no-winner sentinel, so the raw interval
    # wraps one cycle later than the guard flips
    assert int_range.fused_key_interval(1024, t).fits_int32()
    assert int_range.fused_key_interval(1024, t + 1).fits_int32()
    assert not int_range.fused_key_interval(1024, t + 2).fits_int32()


def test_interval_arithmetic():
    iv = int_range.Interval
    assert (iv(1, 3) + iv(10, 20)) == iv(11, 23)
    assert (iv(-2, 3) * iv(5, 7)) == iv(-14, 21)
    assert iv(1, 4).shl(iv(0, 3)) == iv(1, 32)
    assert iv(0, 2**31 - 1).fits_int32()
    assert not iv(0, 2**31).fits_int32()
    with pytest.raises(ValueError):
        iv(5, 4)
    with pytest.raises(ValueError):
        iv(-1, 1).shl(iv(0, 1))


def test_range_pass_is_green():
    reps = int_range.check_all()
    assert all(r.ok for r in reps), fail_fast(reps)
    fused = next(r for r in reps if r.subject == "fused-arbitration-key")
    assert fused.stats["n1024_threshold"] == 2_095_104


def test_envelope_drift_is_flagged(monkeypatch):
    monkeypatch.setitem(int_range.ANALYSIS_BOUNDS, "bogus_field", (0, 1))
    rep = int_range.check_envelope()
    assert "envelope" in _rules(rep)
    assert any("bogus_field" in f.detail for f in rep.findings)


def test_backoff_bounded_in_envelope():
    iv = int_range.backoff_interval(2**20, 8)
    assert iv.fits_int32() and iv.lo == 0


# ---------------------------------------------------------------------------
# CLI: the CI gate's entry point
# ---------------------------------------------------------------------------

def test_cli_green_run_with_json(tmp_path, capsys):
    out = tmp_path / "report.json"
    assert analysis_main(["range", "--json", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["ok"] and doc["passes"] == ["range"]
    assert {r["pass"] for r in doc["reports"]} == {"range"}
    assert "OK:" in capsys.readouterr().out


def test_cli_exits_nonzero_on_findings(monkeypatch, capsys):
    bad = PassReport(pass_name="range", subject="seeded", findings=[
        Finding("range", "key-overflow", "seeded", "seeded failure")])
    monkeypatch.setattr(int_range, "check_all",
                        lambda quick=False: [bad])
    assert analysis_main(["range"]) == 1
    assert "key-overflow" in capsys.readouterr().out


def test_run_passes_rejects_unknown_pass():
    from repro.analysis import run_passes
    with pytest.raises(ValueError, match="unknown pass"):
        run_passes(["modle"])
