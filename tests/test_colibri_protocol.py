"""Property tests for the message-level Colibri protocol (paper §IV-A).

Hypothesis drives adversarial message interleavings; the invariants are the
paper's correctness argument: mutual exclusion, exactly-once service,
FIFO/starvation-freedom, quiescent queue consistency — including the
SuccessorUpdate/SCwait race ("bounce") and Mwait chain-drain.
"""
from __future__ import annotations

import random

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.colibri import ColibriSystem


def drive(system: ColibriSystem, n_cores: int, ops_per_core: int, rng):
    """Each core performs ops_per_core LRSCwait pairs; the scheduler delivers
    messages in rng-chosen order; cores issue their SCwait a random number of
    deliveries after their LR response arrives."""
    remaining = {c: ops_per_core for c in range(n_cores)}
    can_issue = {c: True for c in range(n_cores)}
    sc_pending = []          # cores that have the reservation, will SCwait

    base_responses = 0
    while True:
        actions = []
        if not system.mwait:
            newly_granted = system.responses[base_responses:]
            for c in newly_granted:
                sc_pending.append(c)
            base_responses = len(system.responses)
        for c in range(n_cores):
            if remaining[c] > 0 and can_issue[c] and not system.outstanding.get(c):
                actions.append(("lr", c))
        for c in list(sc_pending):
            actions.append(("sc", c))
        chans = system.pending_channels()
        for ch in chans:
            actions.append(("deliver", ch))
        if not actions:
            break
        kind, arg = rng.choice(actions)
        if kind == "lr":
            system.core_issue_lrwait(arg)
            remaining[arg] -= 1
        elif kind == "sc":
            sc_pending.remove(arg)
            system.core_issue_scwait(arg)
        else:
            system.deliver(arg)


@settings(max_examples=60, deadline=None)
@given(n_cores=st.integers(2, 8), ops=st.integers(1, 4),
       seed=st.integers(0, 2**32 - 1))
def test_lrscwait_invariants(n_cores, ops, seed):
    system = ColibriSystem(n_cores)
    drive(system, n_cores, ops, random.Random(seed))
    system.check_final(expected_ops=n_cores * ops)
    # mutual exclusion was monitored online; SCwait never failed:
    assert len(system.sc_ok) == n_cores * ops


@settings(max_examples=40, deadline=None)
@given(n_cores=st.integers(2, 8), seed=st.integers(0, 2**32 - 1))
def test_mwait_chain_drain(n_cores, seed):
    """All Mwait waiters are woken by a single store, in FIFO order, without
    any interference from the cores (paper §IV-B)."""
    rng = random.Random(seed)
    system = ColibriSystem(n_cores, mwait=True)
    for c in range(n_cores):
        system.core_issue_lrwait(c)
    # deliver all Mwait enqueues (random order across channels)
    while system.pending_channels():
        system.deliver(rng.choice(system.pending_channels()))
    assert system.responses == []        # nobody woken before the store
    system.store(42)
    while system.pending_channels():
        system.deliver(rng.choice(system.pending_channels()))
    assert system.responses == system.lr_arrival_order
    assert len(system.responses) == n_cores
    assert system.head is None and system.tail is None
    assert not system.violations, system.violations


def test_double_lrwait_rejected():
    """Deadlock-freedom constraint: one outstanding LRwait per core."""
    system = ColibriSystem(2)
    system.core_issue_lrwait(0)
    with pytest.raises(AssertionError):
        system.core_issue_lrwait(0)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_successor_update_bounce(seed):
    """The race the paper analyses: B enqueues behind A, but A's SCwait
    passes its Qnode before the SuccessorUpdate arrives — the update must
    bounce back as a WakeUpRequest and B must still be served."""
    system = ColibriSystem(2)
    system.core_issue_lrwait(0)
    system.deliver(("core:0", "mem"))         # A granted immediately
    system.deliver(("mem", "core:0"))         # A receives LR response
    system.core_issue_lrwait(1)
    system.deliver(("core:1", "mem"))         # B enqueued; SuccUpdate -> A
    # A issues SCwait BEFORE the SuccessorUpdate is delivered
    system.core_issue_scwait(0)
    rng = random.Random(seed)
    while system.pending_channels():
        system.deliver(rng.choice(system.pending_channels()))
    # B must have been granted despite the race
    assert system.responses == [0, 1]
    system.core_issue_scwait(1)
    while system.pending_channels():
        system.deliver(rng.choice(system.pending_channels()))
    system.check_final(expected_ops=2)
