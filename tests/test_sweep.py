"""The vmapped sweep runner must be a pure batching transform: results
identical to per-config ``sim.run``, regardless of how configs are
grouped, padded (mixed ``n_addrs`` share one bank allocation), or
ordered."""
import dataclasses

import numpy as np
import pytest

from repro.core.sim import SimParams, run
from repro.core.sweep import STATIC_FIELDS, sweep, sweep_grid

EXACT_KEYS = ("ops", "msgs", "polls", "sleep_cyc", "backoff_cyc",
              "bank_ops", "net_stall", "throughput", "fairness_min",
              "fairness_max",
              # the metrics layer derives these from the same integer
              # state, so sweep and run agree exactly, not approximately
              "lat_hist", "lat_max", "lat_p50", "lat_p95",
              "jain_fairness", "fairness_span", "energy_pj_per_op")


def _assert_same(swept, ref):
    for k in EXACT_KEYS:
        assert np.array_equal(np.asarray(swept[k]), np.asarray(ref[k])), k


def test_sweep_matches_run_mixed_axes():
    """Mixed contention/latency/seed configs, two protocols: every point
    equals its sequential run() twin exactly (integer engine state)."""
    configs = [
        SimParams(protocol="colibri", n_cores=32, cycles=1200, n_addrs=1),
        SimParams(protocol="colibri", n_cores=32, cycles=1200, n_addrs=8,
                  lat=3, seed=1),
        SimParams(protocol="lrsc", n_cores=32, cycles=1200, n_addrs=4,
                  work=6),
        SimParams(protocol="lrsc", n_cores=32, cycles=1200, n_addrs=1,
                  backoff=128, backoff_exp=1),
    ]
    for cfg, swept in zip(configs, sweep(configs)):
        _assert_same(swept, run(cfg))


def test_sweep_matches_run_queue_and_workers():
    """Queue-based protocol with traced n_workers + head-of-line blocking
    (the Fig.5 regime) through the sweep path."""
    configs = [
        SimParams(protocol="lrscwait", n_cores=32, cycles=1200, n_addrs=1,
                  n_workers=w, net_bw=13, hol_block=16) for w in (0, 4, 8)
    ]
    for cfg, swept in zip(configs, sweep(configs)):
        ref = run(cfg)
        _assert_same(swept, ref)
        if cfg.n_workers:
            assert swept["worker_rate"] == ref["worker_rate"]


def test_sweep_grid_product_order():
    res = sweep_grid(SimParams(protocol="amo", n_cores=16, cycles=600),
                     n_addrs=(1, 4), seed=(0, 1))
    assert len(res) == 4
    assert [(r["_config"].n_addrs, r["_config"].seed) for r in res] == \
        [(1, 0), (1, 1), (4, 0), (4, 1)]
    for r in res:
        _assert_same(r, run(r["_config"]))


def test_sweep_rejects_non_sweepable_axis():
    with pytest.raises(ValueError):
        sweep_grid(SimParams(), n_cores=(8, 16))


def test_sweep_rejects_bad_max_batch():
    with pytest.raises(ValueError):
        sweep([SimParams(n_cores=8, cycles=100)], max_batch=0)


def test_sweep_mixed_worker_axis_chunks_identical():
    """A fingerprint group mixing worker and worker-free configs stays
    bit-identical to run() even when chunking isolates a worker-free
    chunk: the dropped n_workers axis must not fall back to the group
    leader's nonzero static value (phantom Fig.5 workers)."""
    configs = [
        SimParams(protocol="colibri", n_cores=16, n_addrs=1, cycles=500,
                  n_workers=w) for w in (8, 0, 4)
    ]
    for mb in (None, 1):
        for cfg, swept in zip(configs, sweep(configs, max_batch=mb)):
            ref = run(cfg)
            _assert_same(swept, ref)
            assert np.array_equal(np.asarray(swept["w_served"]),
                                  np.asarray(ref["w_served"]))


def test_sweep_chunking_identical():
    """max_batch chunking is invisible: a 5-point group split into 2-point
    chunks (and into singletons) returns exactly the unchunked results."""
    configs = [SimParams(protocol="colibri", n_cores=32, cycles=900,
                         n_addrs=a, seed=s)
               for a, s in [(1, 0), (8, 1), (4, 2), (1, 3), (16, 4)]]
    ref = [run(c) for c in configs]
    for mb in (2, 1):
        for want, swept in zip(ref, sweep(configs, max_batch=mb)):
            _assert_same(swept, want)


def test_sweep_one_transfer_per_chunk(monkeypatch):
    """A 100-point single-fingerprint grid moves device->host in ONE
    ``jax.device_get`` of the whole result pytree (the former per-key
    ``np.asarray`` loop paid one host sync per array per group); with
    max_batch=30 it is one transfer per chunk.  This is the mechanism
    behind the batched-transfer timing win, asserted deterministically
    instead of with a flaky wall-clock bound."""
    import jax

    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    base = SimParams(protocol="amo", n_cores=16, cycles=300)
    # n_addrs 9..16 share one power-of-two bank bucket -> one group
    res = sweep_grid(base, n_addrs=(9, 12, 14, 16),
                     seed=tuple(range(25)))                  # 100 points
    assert len(res) == 100
    assert len(calls) == 1                                   # one chunk
    calls.clear()
    res2 = sweep_grid(base, max_batch=30, n_addrs=(9, 12, 14, 16),
                      seed=tuple(range(25)))
    assert len(calls) == 4                                   # ceil(100/30)
    for a, b in zip(res, res2):
        _assert_same(a, b)


def test_sweep_shards_across_devices():
    """With >1 device visible the chunk batch axis is sharded across the
    mesh; results stay bit-identical to per-config run().  Forced host
    devices require a fresh process (XLA_FLAGS is read at jax init)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=2"),
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    code = (
        "import jax, numpy as np\n"
        "assert jax.device_count() == 2, jax.device_count()\n"
        "from repro.core.sim import SimParams, run\n"
        "from repro.core.sweep import sweep\n"
        "cfgs = [SimParams(protocol='colibri', n_cores=16, cycles=300,\n"
        "                  n_addrs=a, seed=s)\n"
        "        for a, s in [(1, 0), (4, 1), (2, 2)]]\n"   # odd: pads
        "for c, r in zip(cfgs, sweep(cfgs)):\n"
        "    q = run(c)\n"
        "    assert np.array_equal(r['ops'], q['ops'])\n"
        "    assert int(r['msgs']) == int(q['msgs'])\n"
        "    assert int(r['polls']) == int(q['polls'])\n"
        "print('sharded-ok')\n")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "sharded-ok" in out.stdout


def test_static_fields_cover_simparams():
    """Every SimParams field is either a static grouping key or a sweep
    axis — adding a field without classifying it should fail loudly."""
    from repro.core.sim import DYN_FIELDS
    fields = {f.name for f in dataclasses.fields(SimParams)}
    assert fields == set(STATIC_FIELDS) | set(DYN_FIELDS)
