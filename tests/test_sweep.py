"""The vmapped sweep runner must be a pure batching transform: results
identical to per-config ``sim.run``, regardless of how configs are
grouped, padded (mixed ``n_addrs`` share one bank allocation), or
ordered."""
import dataclasses

import numpy as np
import pytest

from repro.core.sim import SimParams, run
from repro.core.sweep import STATIC_FIELDS, sweep, sweep_grid

EXACT_KEYS = ("ops", "msgs", "polls", "sleep_cyc", "backoff_cyc",
              "bank_ops", "net_stall", "throughput", "fairness_min",
              "fairness_max")


def _assert_same(swept, ref):
    for k in EXACT_KEYS:
        assert np.array_equal(np.asarray(swept[k]), np.asarray(ref[k])), k


def test_sweep_matches_run_mixed_axes():
    """Mixed contention/latency/seed configs, two protocols: every point
    equals its sequential run() twin exactly (integer engine state)."""
    configs = [
        SimParams(protocol="colibri", n_cores=32, cycles=1200, n_addrs=1),
        SimParams(protocol="colibri", n_cores=32, cycles=1200, n_addrs=8,
                  lat=3, seed=1),
        SimParams(protocol="lrsc", n_cores=32, cycles=1200, n_addrs=4,
                  work=6),
        SimParams(protocol="lrsc", n_cores=32, cycles=1200, n_addrs=1,
                  backoff=128, backoff_exp=1),
    ]
    for cfg, swept in zip(configs, sweep(configs)):
        _assert_same(swept, run(cfg))


def test_sweep_matches_run_queue_and_workers():
    """Queue-based protocol with traced n_workers + head-of-line blocking
    (the Fig.5 regime) through the sweep path."""
    configs = [
        SimParams(protocol="lrscwait", n_cores=32, cycles=1200, n_addrs=1,
                  n_workers=w, net_bw=13, hol_block=16) for w in (0, 4, 8)
    ]
    for cfg, swept in zip(configs, sweep(configs)):
        ref = run(cfg)
        _assert_same(swept, ref)
        if cfg.n_workers:
            assert swept["worker_rate"] == ref["worker_rate"]


def test_sweep_grid_product_order():
    res = sweep_grid(SimParams(protocol="amo", n_cores=16, cycles=600),
                     n_addrs=(1, 4), seed=(0, 1))
    assert len(res) == 4
    assert [(r["_config"].n_addrs, r["_config"].seed) for r in res] == \
        [(1, 0), (1, 1), (4, 0), (4, 1)]
    for r in res:
        _assert_same(r, run(r["_config"]))


def test_sweep_rejects_non_sweepable_axis():
    with pytest.raises(ValueError):
        sweep_grid(SimParams(), n_cores=(8, 16))


def test_static_fields_cover_simparams():
    """Every SimParams field is either a static grouping key or a sweep
    axis — adding a field without classifying it should fail loudly."""
    from repro.core.sim import DYN_FIELDS
    fields = {f.name for f in dataclasses.fields(SimParams)}
    assert fields == set(STATIC_FIELDS) | set(DYN_FIELDS)
