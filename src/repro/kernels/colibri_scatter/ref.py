"""Pure-jnp oracle: native scatter-add (the retry-style baseline)."""
import jax.numpy as jnp


def scatter_add_ref(keys: jnp.ndarray, vals: jnp.ndarray,
                    num_bins: int) -> jnp.ndarray:
    shape = (num_bins,) + vals.shape[1:]
    return jnp.zeros(shape, vals.dtype).at[keys].add(vals)


def histogram_ref(keys: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    return jnp.bincount(keys, length=num_bins)
