from repro.kernels.colibri_scatter.ops import colibri_scatter_add

__all__ = ["colibri_scatter_add"]
