from repro.kernels.colibri_scatter.ops import (colibri_histogram,
                                               colibri_scatter_add)

__all__ = ["colibri_histogram", "colibri_scatter_add"]
