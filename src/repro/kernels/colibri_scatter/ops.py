"""Public op: colibri_scatter_add = sort-linearize (enqueue) + kernel commit."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.colibri_scatter.kernel import scatter_commit


@partial(jax.jit, static_argnames=("num_bins", "block_t", "block_bins"))
def colibri_scatter_add(keys: jnp.ndarray, vals: jnp.ndarray, num_bins: int,
                        block_t: int = 512, block_bins: int = 128
                        ) -> jnp.ndarray:
    """Retry-free scatter-add: sort once (linearization point), commit once
    per bin. keys: (T,) int32 in [0, num_bins); vals: (T, d) or (T,)."""
    squeeze = vals.ndim == 1
    if squeeze:
        vals = vals[:, None]
    order = jnp.argsort(keys, stable=True)
    out = scatter_commit(keys[order], vals[order], num_bins,
                         block_t=block_t, block_bins=block_bins,
                         interpret=interpret_mode())
    return out[:, 0] if squeeze else out


@partial(jax.jit, static_argnames=("num_bins",))
def colibri_histogram(keys: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    """The paper's benchmark op as a kernel."""
    return colibri_scatter_add(
        keys, jnp.ones((keys.shape[0],), jnp.float32), num_bins
    ).astype(jnp.int32)
