"""THE paper kernel: retry-free contended scatter-RMW on TPU.

TPU adaptation of Colibri (DESIGN.md §2): the linearization happens ONCE at
"request time" — a stable sort of the keys outside the kernel (XLA's TPU
sort) — and this kernel performs the **serve + commit** phase: a segmented
reduction over the sorted stream, committing each bin exactly once. No
atomics, no retries, no serialized conflict resolution at the destination.

The within-block reduction is MXU-shaped: a one-hot (bins_tile × block_t)
matrix multiplies the (block_t × d) value block — the histogram becomes a
matmul, which is exactly how a TPU wants to count.

Grid: (bins_tiles, t_blocks); t sweeps innermost so the VMEM accumulator
carries partial sums for one bins-tile across the whole stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 512
DEFAULT_BLOCK_BINS = 128


def _kernel(keys_ref, vals_ref, out_ref, acc_ref, *, block_bins: int):
    tb = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(tb == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bin_base = pl.program_id(0) * block_bins
    keys = keys_ref[...]                                   # (block_t,)
    vals = vals_ref[...]                                   # (block_t, d)
    # one-hot commit matrix for this bins tile: (block_bins, block_t)
    local = keys - bin_base
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_bins, keys.shape[0]), 0)
    onehot = (rows == local[None, :]).astype(jnp.float32)
    acc_ref[...] += jnp.dot(onehot, vals.astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(tb == nb - 1)
    def _():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def scatter_commit(sorted_keys: jnp.ndarray, sorted_vals: jnp.ndarray,
                   num_bins: int, *, block_t: int = DEFAULT_BLOCK_T,
                   block_bins: int = DEFAULT_BLOCK_BINS,
                   interpret: bool = True) -> jnp.ndarray:
    """Segmented commit of a key-sorted stream. vals: (T, d) -> (bins, d)."""
    t, d = sorted_vals.shape
    bt = min(block_t, t)
    bb = min(block_bins, num_bins)
    pad_t = (-t) % bt
    pad_b = (-num_bins) % bb
    keys = jnp.pad(sorted_keys, (0, pad_t), constant_values=num_bins + pad_b)
    vals = jnp.pad(sorted_vals, ((0, pad_t), (0, 0)))
    nbins = num_bins + pad_b
    grid = (nbins // bb, (t + pad_t) // bt)
    out = pl.pallas_call(
        functools.partial(_kernel, block_bins=bb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt,), lambda b, i: (i,)),
            pl.BlockSpec((bt, d), lambda b, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda b, i: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nbins, d), sorted_vals.dtype),
        scratch_shapes=[pltpu.VMEM((bb, d), jnp.float32)],
        interpret=interpret,
    )(keys, vals)
    return out[:num_bins]
