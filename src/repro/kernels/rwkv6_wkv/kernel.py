"""Chunked-parallel RWKV-6 WKV kernel (data-dependent decay linear attention).

The exact recurrence (ref.py / models.rwkv6) is O(T) sequential; this kernel
processes the sequence in chunks of C: within a chunk the interaction is a
(C × C) masked matmul (MXU work), across chunks a (hd × hd) state matrix is
carried in VMEM scratch. Decay products are evaluated in log space; the
cross-term factorisation exp(L_prev[t])·exp(-L[s]) is clamped at ±30 — the
clamp only bites when the true decay ratio underflows anyway.

Grid: (B·H, T_chunks) with chunks innermost (state carry).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CLAMP = 30.0


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, block_c: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)              # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = jnp.log(jnp.maximum(w_ref[0].astype(jnp.float32), 1e-38))  # ≤ 0
    u = u_ref[0].astype(jnp.float32)              # (1, hd) bonus row

    L = jnp.cumsum(lw, axis=0)                    # inclusive log-decay
    L_prev = L - lw                               # exclusive
    S = s_ref[...]                                # (hd, hd) carried state

    # inter-chunk: contributions of all previous chunks through S
    r_dec = r * jnp.exp(jnp.maximum(L_prev, -CLAMP))
    out = jax.lax.dot_general(r_dec, S, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    # intra-chunk: pairwise s < t via factored decay ratios
    k_inv = k * jnp.exp(jnp.minimum(-L, CLAMP))
    att = jax.lax.dot_general(r_dec, k_inv, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (C, C)
    c = r.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    att = jnp.where(rows > cols, att, 0.0)        # strictly causal
    out += jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    # diagonal bonus term: out_t += (r_t · (u ⊙ k_t)) v_t
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)
    out += diag * v
    o_ref[0, ...] = out.astype(o_ref.dtype)

    # state update: S' = diag(exp(L_last)) S + (k ⊙ exp(L_last - L))^T v
    l_last = L[-1]
    k_tail = k * jnp.exp(L[-1][None, :] - L)      # ≤ 1, safe
    s_ref[...] = jnp.exp(l_last)[:, None] * S + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def wkv_chunked_kernel(r, k, v, w, u, *, block_c: int = 64,
                       interpret: bool = True) -> jnp.ndarray:
    """r,k,v,w: (BH, T, hd); u: (BH, hd). Returns (BH, T, hd) fp32."""
    bh, t, hd = r.shape
    c = min(block_c, t)
    pad = (-t) % c
    def pp(x):
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    rp, kp, vp = pp(r), pp(k), pp(v)
    wp = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    grid = (bh, (t + pad) // c)
    out = pl.pallas_call(
        functools.partial(_kernel, block_c=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, hd), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t + pad, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rp, kp, vp, wp, u)
    return out[:, :t]
