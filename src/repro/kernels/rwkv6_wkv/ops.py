"""Public chunked WKV op."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import interpret_mode
from repro.kernels.rwkv6_wkv.kernel import wkv_chunked_kernel


@partial(jax.jit, static_argnames=("block_c",))
def wkv_chunked(r, k, v, w, u, block_c: int = 64):
    """Chunked-parallel RWKV-6 WKV. r,k,v,w: (BH,T,hd); u: (BH,hd)."""
    return wkv_chunked_kernel(r, k, v, w, u, block_c=block_c,
                              interpret=interpret_mode())
