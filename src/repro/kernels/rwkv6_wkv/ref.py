"""Pure-jnp oracle: exact sequential WKV recurrence."""
import jax.numpy as jnp
from jax import lax


def wkv_ref(r, k, v, w, u):
    """r,k,v,w: (BH, T, hd); u: (BH, hd). Exact recurrence, fp32."""
    bh, t, hd = r.shape
    r, k, v, w = (x.astype(jnp.float32) for x in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                      # (BH, hd)
        kv = kt[:, :, None] * vt[:, None, :]      # (BH, hd, hd)
        out = jnp.einsum("bk,bkv->bv", rt, S + u[:, :, None] * kv)
        return wt[:, :, None] * S + kv, out

    xs = tuple(x.transpose(1, 0, 2) for x in (r, k, v, w))
    S0 = jnp.zeros((bh, hd, hd), jnp.float32)
    _, outs = lax.scan(step, S0, xs)
    return outs.transpose(1, 0, 2)
