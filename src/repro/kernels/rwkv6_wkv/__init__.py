from repro.kernels.rwkv6_wkv.ops import wkv_chunked

__all__ = ["wkv_chunked"]
