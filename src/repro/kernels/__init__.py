"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package provides:
  * ``kernel.py`` — ``pl.pallas_call`` with explicit BlockSpec VMEM tiling,
    written for TPU (MXU-aligned tiles, fp32 accumulation);
  * ``ops.py``    — the jit'd public wrapper (interpret=True on CPU);
  * ``ref.py``    — the pure-jnp oracle the kernel is validated against.

This container is CPU-only: kernels execute via ``interpret=True`` (the
kernel body runs in Python on CPU) for correctness; on real TPU the same
code lowers to Mosaic. Model graphs use the pure-JAX path for the dry-run
(XLA:CPU cannot lower TPU pallas_call) and switch with ``use_pallas=True``.
"""


def on_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    return not on_tpu()
