"""Pure-jnp oracle for the grouped expert GEMM."""
import jax.numpy as jnp


def grouped_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(E, C, d) @ (E, d, f) -> (E, C, f) in fp32 accumulation."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
