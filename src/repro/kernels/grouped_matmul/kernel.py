"""Grouped expert GEMM for MoE: (E, C, d) @ (E, d, f) -> (E, C, f).

Consumes the colibri-dispatch buffers directly (one GEMM per expert over its
capacity slots). Grid: (E, C_tiles, f_tiles, d_tiles) with the contraction
dim innermost accumulating in fp32 VMEM scratch — MXU-aligned (128) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    db = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(db == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(db == nd - 1)
    def _():
        o_ref[0, ...] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul_kernel(x: jnp.ndarray, w: jnp.ndarray, *,
                          block_c: int = 128, block_f: int = 128,
                          block_d: int = 256, interpret: bool = True
                          ) -> jnp.ndarray:
    e, c, d = x.shape
    f = w.shape[2]
    bc, bf, bd = min(block_c, c), min(block_f, f), min(block_d, d)
    pc, pf, pd = (-c) % bc, (-f) % bf, (-d) % bd
    xp = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    wp = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    grid = (e, (c + pc) // bc, (f + pf) // bf, (d + pd) // bd)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bd, bf), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c + pc, f + pf), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:, :c, :f]
