"""Public grouped-matmul op."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.grouped_matmul.kernel import grouped_matmul_kernel


@partial(jax.jit, static_argnames=("block_c", "block_f", "block_d"))
def grouped_matmul(x: jnp.ndarray, w: jnp.ndarray, block_c: int = 128,
                   block_f: int = 128, block_d: int = 256) -> jnp.ndarray:
    """MoE expert GEMM over dispatch buffers: (E,C,d) @ (E,d,f) -> (E,C,f)."""
    return grouped_matmul_kernel(x, w, block_c=block_c, block_f=block_f,
                                 block_d=block_d, interpret=interpret_mode())
