"""Tiled Pallas kernel for the fused engine step.

One ``pl.pallas_call`` fuses the three bank-side stages of a simulated
cycle (see ``ref.py`` for the op-by-op oracle):

1. **arbitration** — per-bank FIFO lexicographic (arrival stamp, rotated
   priority) segment-min over the parked requests, computed as a running
   two-key min over ``(block_a, block_n)`` tiles of the dense ``(a, n)``
   request matrix;
2. **protocol update** — the protocol's :meth:`Protocol.fused_access`
   dense bank-state update, traced over this block's bank lanes;
3. **histogram** — the completion-latency histogram rows for this
   block's retiring grants.

Grid: ``(a // block_a,)`` bank tiles; the core dimension is swept by an
in-kernel ``fori_loop`` over ``n // block_n`` chunks, so no grid cell
ever depends on another (safe on parallel GPU grids, trivially correct
under ``interpret=True`` on CPU).  Bank-state arrays follow the layout
rule that their leading dim is ``m * a`` for a per-protocol ``m`` (flat
Colibri queues: m=1; hierarchical local queues: m=n_groups), so every
bank array blocks cleanly to ``(m * block_a, ...)`` at tile ``at``.

Per-tile partial outputs (histogram rows, [polls, msgs, lat_max] stat
rows) are reduced OUTSIDE the kernel — cross-tile accumulation through a
shared output block is exactly the pattern that breaks on parallel
grids.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.metrics import LAT_BINS, LAT_SUB
from repro.core.protocols.base import (OUT_DONE, OUT_FAIL, OUT_GRANT,
                                       OUT_SLEEP, P_ACQ, P_REL, FusedCtx)
from repro.kernels.engine_step.ref import _BIG, _param_ns

#: number of reduced stat columns per tile: [polls, msgs, lat_max]
_N_STATS = 3


def _kernel(*refs, proto, p, n, block_a, block_n, q_cap, cycles,
            core_names, bank_names, xset_names):
    n_core, n_bank, n_xset = len(core_names), len(bank_names), len(xset_names)
    nin = 6 + n_core + n_bank
    scal_ref, cand_ref, rot_ref, addr_ref, phase_ref, acq_ref = refs[:6]
    core_refs = dict(zip(core_names, refs[6:6 + n_core]))
    bank_refs = dict(zip(bank_names, refs[6 + n_core:nin]))
    outs = refs[nin:]
    valid_ref, win_ref, kind_ref, tmr_ref = outs[:4]
    bank_out = dict(zip(bank_names, outs[4:4 + n_bank]))
    xv_refs = dict(zip(xset_names, outs[4 + n_bank:4 + n_bank + n_xset]))
    xm_refs = dict(zip(xset_names,
                       outs[4 + n_bank + n_xset:4 + n_bank + 2 * n_xset]))
    stats_ref, hist_ref = outs[-2:]

    scal = scal_ref[...]
    cyc, shift, lat = scal[0], scal[1], scal[2]
    # global bank ids of this tile's lanes
    bl = (pl.program_id(0) * block_a
          + jax.lax.broadcasted_iota(jnp.int32, (block_a,), 0))

    # ---- stage 1: chunked two-key segment-min over the core dimension.
    # Running (stamp, rot) pair per bank lane; merging a chunk keeps the
    # smaller stamp, and on stamp ties the smaller rot — associative, so
    # chunk order never matters and the result equals the global
    # lexicographic min (= ref.py's one-shot dense min).
    def merge(i, carry):
        run_cyc, run_rot = carry
        sl = pl.ds(i * block_n, block_n)
        cand, rot, adr = cand_ref[sl], rot_ref[sl], addr_ref[sl]
        m = adr[None, :] == bl[:, None]                # (block_a, block_n)
        c2 = jnp.where(m, cand[None, :], _BIG)
        t_cyc = jnp.min(c2, axis=1)
        tie = (c2 == t_cyc[:, None]) & (c2 != _BIG)
        t_rot = jnp.min(jnp.where(tie, rot[None, :], _BIG), axis=1)
        better = t_cyc < run_cyc
        same = t_cyc == run_cyc
        run_rot = jnp.where(better, t_rot,
                            jnp.where(same, jnp.minimum(run_rot, t_rot),
                                      run_rot))
        return jnp.minimum(run_cyc, t_cyc), run_rot

    init = (jnp.full((block_a,), _BIG, jnp.int32),
            jnp.full((block_a,), _BIG, jnp.int32))
    best_cyc, best_rot = jax.lax.fori_loop(0, n // block_n, merge, init)
    valid = best_cyc != _BIG
    win = jnp.where(valid, (best_rot - shift) % n, n).astype(jnp.int32)
    wcs = jnp.minimum(win, n - 1)                      # gather-safe

    # ---- stage 2: protocol dense bank update over this tile
    phase_w = phase_ref[...][wcs]
    acq_b = valid & (phase_w == P_ACQ)
    rel_b = valid & (phase_w == P_REL)
    fx = FusedCtx(p=_param_ns(p, lat), n=n, a=block_a, q_cap=q_cap,
                  win=win, acq_b=acq_b, rel_b=rel_b,
                  core={f: core_refs[f][...][wcs] for f in core_names})
    bank2, fo = proto.fused_access(
        fx, {k: bank_refs[k][...] for k in bank_names})

    valid_ref[...] = valid
    win_ref[...] = win
    kind_ref[...] = fo.kind
    tmr_ref[...] = fo.tmr
    for k in bank_names:
        bank_out[k][...] = bank2[k]
    for f in xset_names:
        val, msk = fo.xset[f]
        xv_refs[f][...] = val.astype(jnp.int32)
        xm_refs[f][...] = msk

    # ---- stage 3: completion-latency histogram row for this tile
    done_cyc = cyc + jnp.maximum(fo.tmr, 1)
    fut = (fo.kind == OUT_DONE) & (done_cyc < cycles)
    lat_b = done_cyc - acq_ref[...][wcs]
    lbkt = jnp.clip((LAT_SUB * jnp.log2(
        lat_b.astype(jnp.float32) + 1.0)).astype(jnp.int32),
        0, LAT_BINS - 1)
    lbins = jax.lax.broadcasted_iota(jnp.int32, (LAT_BINS, block_a), 0)
    hist_ref[...] = jnp.sum((lbkt[None, :] == lbins) & fut[None, :],
                            axis=1).astype(jnp.int32)[None, :]
    polls = (fo.kind == OUT_FAIL).sum()
    msgs = (fo.msgs.sum() if fo.msgs is not None
            else jnp.zeros((), jnp.int32))
    lat_max = jnp.max(jnp.where(fut, lat_b, 0))
    stats_ref[...] = jnp.stack([polls, msgs, lat_max]).astype(
        jnp.int32)[None, :]


def fused_step_call(proto, p, bank, *, cand_cyc, rot, addr, phase,
                    acq_start, core, cyc, shift, lat, n, a, q_cap, cycles,
                    block_a=None, block_n=None, interpret=True):
    """Launch the tiled kernel; same contract as ``ref.fused_step_ref``."""
    block_a = a if block_a is None else block_a
    block_n = n if block_n is None else block_n
    if a % block_a or n % block_n:
        raise ValueError(
            f"tile sizes must divide the extents: a={a} block_a={block_a}, "
            f"n={n} block_n={block_n}")
    ga = a // block_a
    core_names = tuple(proto.fused_core_fields)
    bank_names = tuple(sorted(bank))
    xset_names = tuple(proto.fused_xset_fields)

    def _const(shape):                       # same full block at every tile
        return pl.BlockSpec(shape, lambda at: (0,) * len(shape))

    def _banked(shape):                      # leading dim is m*a -> m*block_a
        m = shape[0] // a
        rest = tuple(shape[1:])
        return pl.BlockSpec((m * block_a,) + rest,
                            lambda at: (at,) + (0,) * len(rest))

    scal = jnp.stack([jnp.asarray(cyc, jnp.int32),
                      jnp.asarray(shift, jnp.int32),
                      jnp.asarray(lat, jnp.int32)])
    in_specs = ([_const((3,))] + [_const((n,))] * 5
                + [_const((n,)) for _ in core_names]
                + [_banked(bank[k].shape) for k in bank_names])
    lane = pl.BlockSpec((block_a,), lambda at: (at,))
    row = lambda w: pl.BlockSpec((1, w), lambda at: (at, 0))  # noqa: E731
    out_specs = ([lane] * 4
                 + [_banked(bank[k].shape) for k in bank_names]
                 + [lane] * (2 * len(xset_names))
                 + [row(_N_STATS), row(LAT_BINS)])
    out_shape = ([jax.ShapeDtypeStruct((a,), jnp.bool_)]
                 + [jax.ShapeDtypeStruct((a,), jnp.int32)] * 3
                 + [jax.ShapeDtypeStruct(bank[k].shape, bank[k].dtype)
                    for k in bank_names]
                 + [jax.ShapeDtypeStruct((a,), jnp.int32)
                    for _ in xset_names]
                 + [jax.ShapeDtypeStruct((a,), jnp.bool_)
                    for _ in xset_names]
                 + [jax.ShapeDtypeStruct((ga, _N_STATS), jnp.int32),
                    jax.ShapeDtypeStruct((ga, LAT_BINS), jnp.int32)])
    outs = pl.pallas_call(
        functools.partial(_kernel, proto=proto, p=p, n=n, block_a=block_a,
                          block_n=block_n, q_cap=q_cap, cycles=cycles,
                          core_names=core_names, bank_names=bank_names,
                          xset_names=xset_names),
        grid=(ga,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(scal, cand_cyc, rot, addr, phase, acq_start,
      *[core[f] for f in core_names], *[bank[k] for k in bank_names])

    valid, win, kind, tmr = outs[:4]
    nb, nx = len(bank_names), len(xset_names)
    bank_new = dict(zip(bank_names, outs[4:4 + nb]))
    xv = outs[4 + nb:4 + nb + nx]
    xm = outs[4 + nb + nx:4 + nb + 2 * nx]
    stats, hist = outs[-2:]
    return dict(valid=valid, win=win, kind=kind, tmr=tmr, bank=bank_new,
                xset={f: (v, m) for f, v, m in zip(xset_names, xv, xm)},
                polls=stats[:, 0].sum(), msgs=stats[:, 1].sum(),
                hist=hist.sum(axis=0), lat_max=stats[:, 2].max())
