"""Public op: one fused bank-side engine step.

``fused_step`` is what ``core.sim`` calls per scan iteration on the
pallas backends; ``use_kernel=False`` routes to the pure-jnp oracle
(``ref.fused_step_ref``) — the **unfused** ablation baseline, identical
dataflow as separate XLA ops.  Both forms return the same dict, so the
engine's outcome-apply code is backend-agnostic.

Not jitted here: the call sits inside ``simulate``'s ``lax.scan`` body
and is traced (and on the interpret path, inlined as XLA ops) as part of
the engine's own jit.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core.protocols.base import (OUT_DONE, OUT_FAIL, OUT_GRANT,
                                       OUT_SLEEP)
from repro.kernels.engine_step.kernel import fused_step_call
from repro.kernels.engine_step.ref import fused_step_ref

#: default tile sizes: tile only when the extent cleanly splits — typical
#: bank counts (a <= 256) stay single-tile, 4096-core runs sweep the core
#: dimension in 1024-lane chunks (EXPERIMENTS.md §Pallas-backend ablates)
PREF_BLOCK_A = 256
PREF_BLOCK_N = 1024


def outcome_counts(kind: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Per-cycle tallies of the fused step's ``OUT_*`` outcome codes.

    ``kind`` is the ``(a,)`` per-bank outcome array :func:`fused_step`
    returns; the four scalars feed the engine's windowed telemetry
    (``repro.obs``).  By the documented OUT_*->(st, nxt) apply mapping
    (``core.protocols.base``) these equal the scan path's gathered
    (st, nxt) tallies exactly, so telemetry stays backend-identical.
    """
    return dict(grants=(kind == OUT_GRANT).sum(),
                retires=(kind == OUT_DONE).sum(),
                fails=(kind == OUT_FAIL).sum(),
                enqueues=(kind == OUT_SLEEP).sum())


def pick_block(extent: int, pref: int) -> int:
    """Largest clean tile: ``pref`` when it divides ``extent``, else the
    whole extent (degenerate single tile — never a remainder tile)."""
    return pref if extent > pref and extent % pref == 0 else extent


def fused_step(proto, p, bank: Dict, *, cand_cyc, rot, addr, phase,
               acq_start, core: Dict, cyc, shift, lat,
               n: int, a: int, q_cap: int, cycles: int,
               interpret: bool = True, block_a=None, block_n=None,
               use_kernel: bool = True) -> Dict:
    """Arbitrate + protocol-update + histogram for one cycle's parked
    requests.  See ``ref.fused_step_ref`` for the argument contract."""
    if not use_kernel:
        return fused_step_ref(
            proto, p, bank, cand_cyc=cand_cyc, rot=rot, addr=addr,
            phase=phase, acq_start=acq_start, core=core, cyc=cyc,
            shift=shift, lat=lat, n=n, a=a, q_cap=q_cap, cycles=cycles)
    return fused_step_call(
        proto, p, bank, cand_cyc=cand_cyc, rot=rot, addr=addr, phase=phase,
        acq_start=acq_start, core=core, cyc=cyc, shift=shift, lat=lat,
        n=n, a=a, q_cap=q_cap, cycles=cycles,
        block_a=pick_block(a, PREF_BLOCK_A) if block_a is None else block_a,
        block_n=pick_block(n, PREF_BLOCK_N) if block_n is None else block_n,
        interpret=interpret)
