"""Pure-jnp oracle for the fused engine step.

``fused_step_ref`` composes the three bank-side stages the Pallas kernel
fuses — per-bank FIFO lexicographic-min arbitration over the parked
requests, the protocol's dense :meth:`Protocol.fused_access` bank update,
and the completion-latency histogram — as separate XLA ops over the full
``(a, n)`` extent.  It is both

* the **unfused** ablation path (``fused_step(..., use_kernel=False)``,
  the EXPERIMENTS.md §Pallas-backend baseline), and
* the ground truth ``tests/test_engine_kernels.py`` checks the tiled
  kernel against, input-for-input.

The engine's own ``lax.scan`` path (``core.sim`` with
``backend="xla_cpu"``) remains the end-to-end bit-exactness oracle; this
module only restates its bank-side stages in the kernel's dataflow
(outcome codes out, no per-core writes).
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import Dict

import jax.numpy as jnp

from repro.core.metrics import LAT_BINS, LAT_SUB
from repro.core.protocols.base import (OUT_DONE, OUT_FAIL, P_ACQ, P_REL,
                                       FusedCtx)

#: int32 sentinel for "no request" (matches ``core.sim._BIG``)
_BIG = jnp.iinfo(jnp.int32).max


def _param_ns(p, lat):
    """FusedCtx.p namespace: the static SimParams fields with ``lat``
    (the one traced scalar the fused forms consume) swapped in."""
    import dataclasses
    vals = {f.name: getattr(p, f.name) for f in dataclasses.fields(p)}
    vals["lat"] = lat
    return SimpleNamespace(**vals)


def fused_step_ref(proto, p, bank: Dict, *, cand_cyc, rot, addr, phase,
                   acq_start, core: Dict, cyc, shift, lat,
                   n: int, a: int, q_cap: int, cycles: int) -> Dict:
    """One fused bank-side step, dense ``(a, n)``.

    Inputs: ``cand_cyc`` is the (n,) arrival stamp with ``_BIG`` on the
    non-contending lanes (``where(parked & st==REQ, arr_cyc, _BIG)``);
    ``rot``/``addr``/``phase``/``acq_start`` are the engine's (n,) per-
    core arrays; ``core`` holds the (n,) fields ``proto.fused_core_fields``
    names; ``cyc``/``shift``/``lat`` may be traced scalars.

    Returns a dict with per-bank ``valid``/``win``/``kind``/``tmr``, the
    updated ``bank`` pytree, the protocol's ``xset`` per-core writes, and
    the step's reduced stats: ``polls``, ``msgs``, ``lat_max`` (scalars)
    and ``hist`` ((LAT_BINS,) counts).
    """
    ba = jnp.arange(a, dtype=jnp.int32)
    # ---- per-bank FIFO lexicographic (arrival stamp, rotated prio) min
    m = addr[None, :] == ba[:, None]                       # (a, n)
    c2 = jnp.where(m, cand_cyc[None, :], _BIG)
    best_cyc = jnp.min(c2, axis=1)                         # (a,)
    tie = (c2 == best_cyc[:, None]) & (c2 != _BIG)
    best_rot = jnp.min(jnp.where(tie, rot[None, :], _BIG), axis=1)
    valid = best_cyc != _BIG
    # decode the winning CORE from its rot (the rotation is affine)
    win = jnp.where(valid, (best_rot - shift) % n, n).astype(jnp.int32)
    wcs = jnp.minimum(win, n - 1)                          # gather-safe

    # ---- protocol dense bank update (kernel-fusable form)
    phase_w = phase[wcs]
    acq_b = valid & (phase_w == P_ACQ)
    rel_b = valid & (phase_w == P_REL)
    fx = FusedCtx(p=_param_ns(p, lat), n=n, a=a, q_cap=q_cap,
                  win=win, acq_b=acq_b, rel_b=rel_b,
                  core={f: v[wcs] for f, v in core.items()})
    bank, fo = proto.fused_access(fx, bank)

    # ---- completion-latency histogram (bank-side, see core.sim)
    done_cyc = cyc + jnp.maximum(fo.tmr, 1)
    fut = (fo.kind == OUT_DONE) & (done_cyc < cycles)
    lat_b = done_cyc - acq_start[wcs]
    lbkt = jnp.clip((LAT_SUB * jnp.log2(
        lat_b.astype(jnp.float32) + 1.0)).astype(jnp.int32),
        0, LAT_BINS - 1)
    lbins = jnp.arange(LAT_BINS, dtype=jnp.int32)
    hist = jnp.sum((lbkt[None, :] == lbins[:, None]) & fut[None, :],
                   axis=1).astype(jnp.int32)
    lat_max = jnp.max(jnp.where(fut, lat_b, 0)).astype(jnp.int32)

    polls = (fo.kind == OUT_FAIL).sum().astype(jnp.int32)
    msgs = (fo.msgs.sum().astype(jnp.int32) if fo.msgs is not None
            else jnp.zeros((), jnp.int32))
    return dict(valid=valid, win=win, kind=fo.kind, tmr=fo.tmr,
                bank=bank, xset=dict(fo.xset),
                polls=polls, msgs=msgs, hist=hist, lat_max=lat_max)
