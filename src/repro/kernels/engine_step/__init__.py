"""Fused per-cycle engine step as a Pallas kernel.

One tiled pass over ``(a, n)`` fusing the three bank-side stages of the
cycle-level engine (``repro.core.sim``): per-bank FIFO segment-min
arbitration, the protocol's dense bank-centric state update (the
``Protocol.fused_access`` kernel-fusable form), and completion-latency
histogram accumulation.  Selected per run by the ``backend`` Spec knob
(``repro.sync.Spec(backend="pallas_interpret")`` on CPU); the engine's
``lax.scan`` XLA path is the bit-exactness oracle.
"""
from repro.kernels.engine_step.ops import fused_step, outcome_counts
from repro.kernels.engine_step.ref import fused_step_ref

__all__ = ["fused_step", "fused_step_ref", "outcome_counts"]
