"""Streaming-softmax (flash) attention kernel: causal + GQA.

Grid: (batch*q_heads, q_blocks, kv_blocks); the kv dimension is innermost so
the VMEM scratch (running max / denominator / accumulator) carries across kv
blocks for one query tile. Softmax statistics in fp32; QK^T and PV hit the
MXU with ``preferred_element_type=f32``. Causal masking is positional (the
upper-triangle blocks are masked; skipping them entirely is a Mosaic grid
remap noted as a TPU perf follow-up in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, scale: float, block_q: int, block_k: int,
            seq_kv: int):
    kb = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kb == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    q_pos = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_kv
    if causal:
        mask &= q_pos >= k_pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == nk - 1)
    def _():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)[:, None]
                         ).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret: bool = True
                           ) -> jnp.ndarray:
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd) — kv already expanded to q heads
    (GQA expansion is free under XLA; the absorbed-GQA variant is a TPU perf
    follow-up). Returns (BH, Sq, hd)."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    scale = hd ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pq, pk = (-sq) % bq, (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    grid = (bh, (sq + pq) // bq, (skv + pk) // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, scale=scale, block_q=bq,
                          block_k=bk, seq_kv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq]
