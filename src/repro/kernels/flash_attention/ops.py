"""Public flash-attention op with GQA head layout handling."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.flash_attention.kernel import flash_attention_kernel


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H % KV == 0.
    Returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)
    out = flash_attention_kernel(qf, kf, vf, causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=interpret_mode())
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
