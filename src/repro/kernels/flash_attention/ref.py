"""Pure-jnp oracle: naive O(S^2) softmax attention."""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True):
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd)."""
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
