"""Public RG-LRU scan op."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import interpret_mode
from repro.kernels.rglru_scan.kernel import rglru_scan_kernel


@partial(jax.jit, static_argnames=("block_c", "block_b", "block_w"))
def rglru_scan(a, b, h0, block_c: int = 256, block_b: int = 8,
               block_w: int = 256):
    """Linear recurrence h_t = a_t ⊙ h_{t-1} + b_t. a, b: (T,B,w); h0: (B,w)."""
    return rglru_scan_kernel(a, b, h0, block_c=block_c, block_b=block_b,
                             block_w=block_w, interpret=interpret_mode())
