"""Blocked RG-LRU recurrence kernel: h_t = a_t ⊙ h_{t-1} + b_t.

Gates/decays are computed element-wise outside (cheap, fusible by XLA); the
kernel owns the sequential dependence: grid (B_tiles, w_tiles, T_chunks)
with T innermost, carrying h in VMEM scratch so the chain never round-trips
HBM. Inside a chunk the scan runs as a log-depth associative doubling on a
(C, bb·bw) tile — VPU-friendly, no scalar loop.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, o_ref, h_ref, *, block_c: int):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)            # (C, bb, bw)
    b = b_ref[...].astype(jnp.float32)
    # log-depth associative doubling over the chunk:
    # (a, b) ∘ (a', b') = (a·a', a'·b + b')
    steps = max(int(math.ceil(math.log2(a.shape[0]))), 0)
    av, bv = a, b
    for s in range(steps):
        sh = 1 << s
        a_prev = jnp.roll(av, sh, axis=0)
        b_prev = jnp.roll(bv, sh, axis=0)
        idx = jax.lax.broadcasted_iota(jnp.int32, av.shape, 0)
        valid = idx >= sh
        bv = jnp.where(valid, av * b_prev + bv, bv)
        av = jnp.where(valid, av * a_prev, av)
    h = bv + av * h_ref[...][None]                # inject carried state
    o_ref[...] = h.astype(o_ref.dtype)
    h_ref[...] = h[-1]


def rglru_scan_kernel(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray, *,
                      block_c: int = 256, block_b: int = 8,
                      block_w: int = 256, interpret: bool = True
                      ) -> jnp.ndarray:
    """a, b: (T, B, w) fp32; h0: (B, w). Returns h sequence (T, B, w)."""
    t, bdim, w = a.shape
    c = min(block_c, t)
    bb = min(block_b, bdim)
    bw = min(block_w, w)
    pt, pb, pw = (-t) % c, (-bdim) % bb, (-w) % bw
    ap = jnp.pad(a, ((0, pt), (0, pb), (0, pw)))
    bp = jnp.pad(b, ((0, pt), (0, pb), (0, pw)))
    h0p = jnp.pad(h0, ((0, pb), (0, pw)))[None]
    grid = ((bdim + pb) // bb, (w + pw) // bw, (t + pt) // c)
    out = pl.pallas_call(
        functools.partial(_kernel, block_c=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, bb, bw), lambda i, j, k: (k, i, j)),
            pl.BlockSpec((c, bb, bw), lambda i, j, k: (k, i, j)),
            pl.BlockSpec((1, bb, bw), lambda i, j, k: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((c, bb, bw), lambda i, j, k: (k, i, j)),
        out_shape=jax.ShapeDtypeStruct((t + pt, bdim + pb, w + pw),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bw), jnp.float32)],
        interpret=interpret,
    )(ap, bp, h0p)
    return out[:t, :bdim, :w]
