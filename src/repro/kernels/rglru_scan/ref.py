"""Pure-jnp oracle: associative scan for h_t = a_t h_{t-1} + b_t."""
import jax.numpy as jnp
from jax import lax


def rglru_scan_ref(a, b, h0):
    """a, b: (T, B, w); h0: (B, w)."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    a_cum, h = lax.associative_scan(combine, (a.astype(jnp.float32),
                                              b.astype(jnp.float32)), axis=0)
    return h + a_cum * h0[None].astype(jnp.float32)
