"""Deterministic synthetic token pipeline.

Determinism is a fault-tolerance feature: batch(step) is a pure function of
(seed, step), so any pod can recompute any microbatch after a restart or a
straggler reassignment — no data-loader state to checkpoint.

The pipeline also feeds its token statistics through the colibri
ordered-commit histogram (``core.dispatch``) — the framework's own use of
the paper's primitive on the data path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core import dispatch as D


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    # zipf-ish unigram skew for realistic vocab statistics
    skew: float = 1.2


class SyntheticPipeline:
    """Markov-ish synthetic LM data with a skewed unigram distribution."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg
        # precompute a skewed unigram table (host, numpy)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-data_cfg.skew)
        self.cum = np.cumsum(probs / probs.sum())

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """Pure function of (seed, step) — recomputable anywhere."""
        b, s = self.shape.global_batch, self.shape.seq_len
        rng = np.random.Generator(np.random.Philox(
            key=self.data_cfg.seed + step))
        u = rng.random((b, s))
        tokens = np.searchsorted(self.cum, u).astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1                       # mask final position
        out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if self.cfg.frontend == "audio":
            feats = rng.standard_normal(
                (b, self.cfg.encoder.seq_len, self.cfg.d_model)) * 0.02
            out["encoder_feats"] = jnp.asarray(
                feats, jnp.dtype(self.cfg.compute_dtype))
        if self.cfg.frontend == "vlm":
            p = rng.standard_normal(
                (b, self.cfg.num_patches, self.cfg.d_model)) * 0.02
            out["patch_embeds"] = jnp.asarray(
                p, jnp.dtype(self.cfg.compute_dtype))
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    def token_histogram(self, batch: Dict[str, jnp.ndarray],
                        num_bins: int = 256) -> jnp.ndarray:
        """Vocab-bucket histogram via the colibri ordered commit — the
        data-path instance of the paper's retry-free scatter."""
        keys = (batch["tokens"].reshape(-1)
                % jnp.int32(num_bins)).astype(jnp.int32)
        return D.histogram(keys, num_bins)
