"""Attention blocks: GQA (full / sliding-window / decode) and MLA (DeepSeek).

All softmax math in fp32. Long sequences use a blocked online-softmax
(flash-style) pure-JAX path so prefill_32k never materialises S×S scores;
the Pallas kernel (repro.kernels.flash_attention) is the TPU hot path and is
validated against these functions.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_map
from repro.models import layers as L

Params = Dict[str, Any]
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _expand_kv(k: jnp.ndarray, num_q_heads: int) -> jnp.ndarray:
    """(B,S,KV,hd) -> (B,S,H,hd) by repeating each kv head G times."""
    b, s, kv, hd = k.shape
    if kv == num_q_heads:
        return k
    g = num_q_heads // kv
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, g, hd)).reshape(
        b, s, num_q_heads, hd)


def naive_attention(q, k, v, *, causal: bool, q_pos=None, kv_pos=None,
                    window: int = 0, scale: Optional[float] = None):
    """Reference O(S^2)-memory attention. q:(B,Sq,H,hd) k,v:(B,Skv,H,hd)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    if q_pos is None:
        q_pos = jnp.arange(sq)
    if kv_pos is None:
        kv_pos = jnp.arange(skv)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def blocked_attention(q, k, v, *, causal: bool, q_block: int = 1024,
                      kv_block: int = 1024, scale: Optional[float] = None,
                      q_offset: int = 0):
    """Flash-style online-softmax attention, O(S·block) memory.

    q: (B,Sq,H,hd); k,v: (B,Skv,H,hd). ``q_offset`` shifts query positions
    (used when Sq != Skv in cached generation)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5

    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    # pad to block multiples
    pq = (-sq) % qb
    pk = (-skv) % kb
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // qb, kp.shape[1] // kb
    qc = qp.reshape(b, nq, qb, h, hd).transpose(1, 0, 2, 3, 4)    # (nq,B,qb,H,hd)
    kc = kp.reshape(b, nk, kb, h, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nk, kb, h, hd).transpose(1, 0, 2, 3, 4)

    q_pos = jnp.arange(nq * qb).reshape(nq, qb) + q_offset
    kv_pos = jnp.arange(nk * kb).reshape(nk, kb)
    kv_valid = kv_pos < skv

    @partial(jax.checkpoint, prevent_cse=False)
    def q_chunk(args):
        # checkpointed (flash-attention style): backward recomputes block
        # scores from q/k instead of stacking per-block softmax residuals.
        iq, qi = args                                             # qi: (B,qb,H,hd)
        qi32 = qi.astype(jnp.float32) * scale

        def kv_step(carry, args2):
            m, l, acc = carry
            ik, ki, vi, kpos, kval = args2
            s = jnp.einsum("bqhd,bkhd->bhqk", qi32, ki.astype(jnp.float32))
            mask = kval[None, :]
            if causal:
                mask = mask & (q_pos[iq][:, None] >= kpos[None, :])
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))                     # (B,H,qb)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, qb), jnp.float32)
        a0 = jnp.zeros((b, h, qb, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kc, vc, kv_pos, kv_valid))
        out = acc / jnp.maximum(l, 1e-30)[..., None]              # (B,H,qb,hd)
        return out.transpose(0, 2, 1, 3)                          # (B,qb,H,hd)

    outs = lax.map(q_chunk, (jnp.arange(nq), qc))                 # (nq,B,qb,H,hd)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * qb, h, hd)
    return out[:, :sq].astype(q.dtype)


def sliding_window_attention(q, k, v, *, window: int,
                             scale: Optional[float] = None,
                             q_sub: int = 256):
    """Banded causal attention: each query chunk of size W attends to its own
    and the previous chunk only — exact for window ≤ W, O(S·W/q_sub) live
    memory (queries sub-chunked, bodies checkpointed)."""
    b, s, h, hd = q.shape
    w = min(window, s)
    p = (-s) % w
    qp = jnp.pad(q, ((0, 0), (0, p), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, p), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, p), (0, 0), (0, 0)))
    n = qp.shape[1] // w
    qc = qp.reshape(b, n, w, h, hd).transpose(1, 0, 2, 3, 4)   # (n,B,w,H,hd)
    kc = kp.reshape(b, n, w, h, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, n, w, h, hd).transpose(1, 0, 2, 3, 4)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:1]), kc[:-1]], axis=0)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:1]), vc[:-1]], axis=0)
    k2 = jnp.concatenate([kprev, kc], axis=2)                  # (n,B,2w,H,hd)
    v2 = jnp.concatenate([vprev, vc], axis=2)
    scale = scale if scale is not None else hd ** -0.5
    sub = min(q_sub, w)
    nsub = w // sub if w % sub == 0 else (w + (-w) % sub) // sub
    psub = nsub * sub - w

    @jax.checkpoint
    def chunk(args):
        ci, qi, ki, vi = args          # qi: (B,w,H,hd); ki/vi: (B,2w,H,hd)
        qi = jnp.pad(qi, ((0, 0), (0, psub), (0, 0), (0, 0)))
        qs = qi.reshape(b, nsub, sub, h, hd).transpose(1, 0, 2, 3, 4)

        def sub_chunk(args2):
            si, qj = args2                                     # (B,sub,H,hd)
            lg = jnp.einsum("bqhd,bkhd->bhqk", qj.astype(jnp.float32),
                            ki.astype(jnp.float32)) * scale
            gq = ci * w + si * sub + jnp.arange(sub)[:, None]
            gk = ci * w - w + jnp.arange(2 * w)[None, :]
            mask = (gq >= gk) & (gq - gk < window) & (gk >= 0) & (gk < s)
            lg = jnp.where(mask[None, None], lg, NEG_INF)
            pr = jax.nn.softmax(lg, axis=-1)
            o = jnp.einsum("bhqk,bkhd->bqhd", pr, vi.astype(jnp.float32))
            return o.astype(q.dtype)

        outs = lax.map(sub_chunk, (jnp.arange(nsub), qs))      # (nsub,B,sub,..)
        return outs.transpose(1, 0, 2, 3, 4).reshape(b, nsub * sub, h, hd)[:, :w]

    outs = lax.map(chunk, (jnp.arange(n), qc, k2, v2))         # (n,B,w,H,hd)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n * w, h, hd)
    return out[:, :s].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     scale: Optional[float] = None):
    """Single-token decode. q:(B,1,H,hd); caches:(B,S,H,hd); pos:(B,) current
    write position (keys at index <= pos are valid)."""
    b, _, h, hd = q.shape
    s = k_cache.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k_cache,
                        preferred_element_type=jnp.float32)[:, :, 0]  # (B,H,S)
    idx = jnp.arange(s)[None, :]
    mask = idx <= pos[:, None]
    if window:
        mask &= idx > pos[:, None] - window
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out[:, None].astype(q.dtype)                           # (B,1,H,hd)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = L.split(key, 4)
    p = {"wq": L.dense_init(k1, d, h * hd, dtype),
         "wk": L.dense_init(k2, d, kv * hd, dtype),
         "wv": L.dense_init(k3, d, kv * hd, dtype),
         "wo": L.dense_init(k4, h * hd, d, dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _qkv(cfg: ModelConfig, p: Params, x):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


def _head_shard(policy, q, ke, ve):
    """§Perf hillclimb #1: pad heads to a TP multiple and pin q/k/v to a
    head-sharded layout BEFORE the attention chunk loops. Without this, head
    counts not divisible by the model axis (qwen2: 28 heads vs TP=16) make
    the SPMD partitioner reshard K/V inside the flash chunk loops — per-
    chunk gathers multiplied by loop trip counts (measured: 17.6 s of
    collectives in one qwen2 prefill_32k step). Padded heads are sliced off
    before wo; the extra FLOPs are ≤ +(tp-1)/H of attention."""
    if policy is None or policy.mesh is None or policy.tp_axis is None:
        return q, ke, ve, q.shape[2]
    tp = policy.axis_size(policy.tp_axis)
    h = q.shape[2]
    h_pad = -(-h // tp) * tp
    if h_pad != h:
        pad = ((0, 0), (0, 0), (0, h_pad - h), (0, 0))
        q, ke, ve = jnp.pad(q, pad), jnp.pad(ke, pad), jnp.pad(ve, pad)
    dp = policy.dp_axes if len(policy.dp_axes) > 1 else policy.dp_axes[0]
    spec = (dp, None, policy.tp_axis, None)
    return (policy.constrain(q, *spec), policy.constrain(ke, *spec),
            policy.constrain(ve, *spec), h)


def _cp_attention(policy, cfg, q, k, v, *, causal: bool, scale: float):
    """Context-parallel attention: shard_map over the model axis with
    sequence-sharded queries and replicated (unexpanded) K/V."""
    from jax.sharding import PartitionSpec as P
    dp = policy.dp_axes if len(policy.dp_axes) > 1 else policy.dp_axes[0]
    tp = policy.tp_axis

    def body(qb, kb, vb):
        ke = _expand_kv(kb, cfg.num_heads)
        ve = _expand_kv(vb, cfg.num_heads)
        off = lax.axis_index(tp) * qb.shape[1]
        return blocked_attention(qb, ke, ve, causal=causal, scale=scale,
                                 q_offset=off)

    return shard_map(
        body, mesh=policy.mesh,
        in_specs=(P(dp, tp, None, None), P(dp, None, None, None),
                  P(dp, None, None, None)),
        out_specs=P(dp, tp, None, None), check_vma=False)(q, k, v)


def gqa_apply(cfg: ModelConfig, p: Params, x, positions, *, causal=True,
              window: int = 0, rope: bool = True,
              kv_out: bool = False, policy=None):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = _qkv(cfg, p, x)
    if rope and cfg.partial_rotary_factor > 0:
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary_factor)
        k = L.apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary_factor)
    scale = q.shape[-1] ** -0.5
    if policy is not None and policy.mesh is not None \
            and policy.sequence_parallel and not window \
            and q.shape[1] % policy.axis_size(policy.tp_axis) == 0:
        # §Perf hillclimb: context-parallel attention via shard_map. q stays
        # SEQUENCE-sharded over the model axis (head divisibility is
        # irrelevant); the small UNEXPANDED GQA K/V are gathered once per
        # layer; expansion + flash chunking run locally per shard. A plain
        # with_sharding_constraint is NOT enough here: the chunk scan
        # iterates the sharded axis, so the partitioner would re-gather
        # every chunk (measured 149 GB/step on qwen2 prefill).
        out = _cp_attention(policy, cfg, q, k, v, causal=causal, scale=scale)
    else:
        ke = _expand_kv(k, cfg.num_heads)
        ve = _expand_kv(v, cfg.num_heads)
        q, ke, ve, h_real = _head_shard(policy, q, ke, ve)
        if window:
            out = sliding_window_attention(q, ke, ve, window=window,
                                           scale=scale)
        else:
            out = blocked_attention(q, ke, ve, causal=causal, scale=scale)
        out = out[:, :, :h_real]
    b, s = x.shape[:2]
    out = out.reshape(b, s, -1) @ p["wo"]
    return (out, (k, v)) if kv_out else (out, None)


def gqa_decode(cfg: ModelConfig, p: Params, x, cache: Params, pos, *,
               window: int = 0, rope: bool = True):
    """One-token decode with KV cache. x:(B,1,d); pos:(B,). Returns
    (out, new_cache). Cache k/v: (B,S,KV,hd) (ring buffer of size W for
    sliding-window layers)."""
    q, k, v = _qkv(cfg, p, x)
    if rope and cfg.partial_rotary_factor > 0:
        q = L.apply_rope(q, pos[:, None], cfg.rope_theta, cfg.partial_rotary_factor)
        k = L.apply_rope(k, pos[:, None], cfg.rope_theta, cfg.partial_rotary_factor)
    s_cache = cache["k"].shape[1]
    slot = pos % s_cache if window else pos

    def upd(c, new):
        return jax.vmap(
            lambda cb, nb, pb: lax.dynamic_update_slice(cb, nb, (pb, 0, 0))
        )(c, new, slot)

    k_cache = upd(cache["k"], k.astype(cache["k"].dtype))
    v_cache = upd(cache["v"], v.astype(cache["v"].dtype))
    ke = _expand_kv(k_cache, cfg.num_heads)
    ve = _expand_kv(v_cache, cfg.num_heads)
    if window:
        # ring buffer: entry at index i holds global position
        # floor((pos - i) / W) * W + i -> valid iff within window of pos.
        b = x.shape[0]
        idx = jnp.arange(s_cache)[None, :]
        age = (slot[:, None] - idx) % s_cache                      # 0..W-1 steps ago
        mask = age <= jnp.minimum(pos, s_cache - 1)[:, None]
        logits = jnp.einsum(
            "bqhd,bkhd->bhk", q * cfg.resolved_head_dim ** -0.5, ke,
            preferred_element_type=jnp.float32)
        logits = jnp.where(mask[:, None], logits, NEG_INF)
        pr = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhk,bkhd->bhd", pr.astype(ve.dtype), ve,
                         preferred_element_type=jnp.float32)
        out = out[:, None].astype(x.dtype)
    else:
        out = decode_attention(q, ke, ve, pos)
    b, s = x.shape[:2]
    out = out.reshape(b, s, -1) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}


def gqa_cache_init(cfg: ModelConfig, batch: int, seq: int, dtype) -> Params:
    hd = cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, seq, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, seq, cfg.num_kv_heads, hd), dtype)}


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig, dtype) -> Params:
    d, hd, h = cfg.d_model, cfg.resolved_head_dim, cfg.num_heads
    k1, k2, k3, k4 = L.split(key, 4)
    return {"wq": L.dense_init(k1, d, h * hd, dtype),
            "wk": L.dense_init(k2, d, h * hd, dtype),
            "wv": L.dense_init(k3, d, h * hd, dtype),
            "wo": L.dense_init(k4, h * hd, d, dtype)}


def cross_attn_apply(cfg: ModelConfig, p: Params, x, enc_kv=None, enc=None):
    """x:(B,S,d); enc:(B,Se,d) or precomputed enc_kv=(k,v)."""
    b, s, _ = x.shape
    hd, h = cfg.resolved_head_dim, cfg.num_heads
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    if enc_kv is None:
        se = enc.shape[1]
        k = (enc @ p["wk"]).reshape(b, se, h, hd)
        v = (enc @ p["wv"]).reshape(b, se, h, hd)
    else:
        k, v = enc_kv
    out = blocked_attention(q, k, v, causal=False)
    return out.reshape(b, s, -1) @ p["wo"], (k, v)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = L.split(key, 8)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": L.dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": L.rmsnorm_init(m.q_lora_rank, dtype),
        "w_uq": L.dense_init(ks[1], m.q_lora_rank, h * qk_head, dtype),
        "w_dkv": L.dense_init(ks[2], d, m.kv_lora_rank, dtype),
        "kv_norm": L.rmsnorm_init(m.kv_lora_rank, dtype),
        "w_kr": L.dense_init(ks[3], d, m.qk_rope_head_dim, dtype),
        # up-projections stored per-head for the absorbed decode path
        "w_uk": (jax.random.normal(ks[4], (h, m.qk_nope_head_dim, m.kv_lora_rank),
                                   jnp.float32) * m.kv_lora_rank ** -0.5).astype(dtype),
        "w_uv": (jax.random.normal(ks[5], (h, m.kv_lora_rank, m.v_head_dim),
                                   jnp.float32) * m.kv_lora_rank ** -0.5).astype(dtype),
        "wo": L.dense_init(ks[6], h * m.v_head_dim, d, dtype),
    }


def _mla_q(cfg, p, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    cq = L.rmsnorm(p["q_norm"], x @ p["w_dq"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(cfg: ModelConfig, p: Params, x, positions):
    """Full-sequence MLA (train / prefill). Returns (out, (c_kv, k_rope))."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv = L.rmsnorm(p["kv_norm"], x @ p["w_dkv"], cfg.norm_eps)   # (B,S,r)
    k_rope = L.apply_rope((x @ p["w_kr"])[:, :, None, :], positions,
                          cfg.rope_theta)                          # (B,S,1,rr)
    k_nope = jnp.einsum("bsr,hdr->bshd", c_kv, p["w_uk"])          # (B,S,H,nope)
    v = jnp.einsum("bsr,hrv->bshv", c_kv, p["w_uv"])               # (B,S,H,v)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, k.shape[-1] - v.shape[-1])))
    out = blocked_attention(q, k, vp, causal=True, scale=scale)
    out = out[..., : m.v_head_dim]
    out = out.reshape(b, s, -1) @ p["wo"]
    return out, (c_kv, k_rope[:, :, 0])


def mla_decode(cfg: ModelConfig, p: Params, x, cache: Params, pos):
    """Absorbed-matrix decode: attention runs in the latent space; the cache
    holds only (c_kv, k_rope) — the MLA memory saving."""
    m = cfg.mla
    b = x.shape[0]
    q_nope, q_rope = _mla_q(cfg, p, x, pos[:, None])
    c_new = L.rmsnorm(p["kv_norm"], x @ p["w_dkv"], cfg.norm_eps)  # (B,1,r)
    kr_new = L.apply_rope((x @ p["w_kr"])[:, :, None, :], pos[:, None],
                          cfg.rope_theta)[:, :, 0]                 # (B,1,rr)

    def upd(c, new):
        return jax.vmap(lambda cb, nb, pb: lax.dynamic_update_slice(
            cb, nb, (pb, 0)))(c, new.astype(c.dtype), pos)

    ckv = upd(cache["c_kv"], c_new)                                # (B,S,r)
    krope = upd(cache["k_rope"], kr_new)                           # (B,S,rr)
    # absorbed scores
    q_lat = jnp.einsum("bqhd,hdr->bhr", q_nope, p["w_uk"],
                       preferred_element_type=jnp.float32)         # (B,H,r)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat.astype(ckv.dtype), ckv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bsd->bhs", q_rope, krope,
                        preferred_element_type=jnp.float32)
    logits = (s_lat + s_rope) * scale
    idx = jnp.arange(ckv.shape[1])[None, :]
    logits = jnp.where((idx <= pos[:, None])[:, None], logits, NEG_INF)
    pr = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pr.astype(ckv.dtype), ckv,
                     preferred_element_type=jnp.float32)           # (B,H,r)
    out = jnp.einsum("bhr,hrv->bhv", ctx.astype(p["w_uv"].dtype), p["w_uv"],
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, -1).astype(x.dtype) @ p["wo"]
    return out, {"c_kv": ckv, "k_rope": krope}


def mla_cache_init(cfg: ModelConfig, batch: int, seq: int, dtype) -> Params:
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, seq, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, seq, m.qk_rope_head_dim), dtype)}
