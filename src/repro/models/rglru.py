"""RG-LRU recurrent block (Griffin / recurrentgemma).

Recurrence (per channel, fp32):
    r_t = σ(α_r ⊙ y_t + β_r)                  (recurrence gate)
    i_t = σ(α_i ⊙ y_t + β_i)                  (input gate)
    log a_t = -c · softplus(Λ) ⊙ r_t          (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ y_t)

Train/prefill uses ``lax.associative_scan`` (log-depth parallel scan);
decode is a single fused step. State: h (B,w) + conv1d tail (B,3,w) —
O(1) in sequence length ⇒ this arch runs long_500k.

Note: the gates here are per-channel affine (element-wise); Griffin uses
block-diagonal linear gates. Documented simplification — FLOP-negligible.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]
C_RGLRU = 8.0


def rglru_init(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    w = cfg.recurrent.lru_width or d
    cw = cfg.recurrent.conv1d_width
    ks = L.split(key, 6)
    return {
        "w_in": L.dense_init(ks[0], d, w, dtype),
        "w_gate": L.dense_init(ks[1], d, w, dtype),       # gelu gate branch
        "conv_w": (jax.random.normal(ks[2], (cw, w), jnp.float32) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "alpha_r": jnp.zeros((w,), jnp.float32),
        "beta_r": jnp.zeros((w,), jnp.float32),
        "alpha_i": jnp.zeros((w,), jnp.float32),
        "beta_i": jnp.zeros((w,), jnp.float32),
        # Λ init so a ≈ 0.9..0.999 at r=1
        "lam": (jax.random.uniform(ks[3], (w,), jnp.float32) * 2.0 + 2.0),
        "w_proj": L.dense_init(ks[4], w, d, dtype),
    }


def _conv1d_causal(y, conv_w, conv_b, tail=None):
    """Causal depthwise conv. y: (B,S,w); tail: (B,cw-1,w) carried state."""
    cw = conv_w.shape[0]
    if tail is None:
        tail = jnp.zeros((y.shape[0], cw - 1, y.shape[2]), y.dtype)
    ypad = jnp.concatenate([tail.astype(y.dtype), y], axis=1)
    out = sum(ypad[:, i: i + y.shape[1]] * conv_w[i] for i in range(cw))
    new_tail = ypad[:, -(cw - 1):] if cw > 1 else tail
    return out + conv_b, new_tail


def _gates(p, y32):
    r = jax.nn.sigmoid(p["alpha_r"] * y32 + p["beta_r"])
    i = jax.nn.sigmoid(p["alpha_i"] * y32 + p["beta_i"])
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * y32)
    return a, x_in


def rglru_apply(cfg: ModelConfig, p: Params, x, state: Params
                ) -> Tuple[jnp.ndarray, Params]:
    """x: (B,S,d) -> (out, new_state). state = {"h": (B,w), "conv": (B,cw-1,w)}."""
    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    y, new_tail = _conv1d_causal(x @ p["w_in"], p["conv_w"], p["conv_b"],
                                 state["conv"])
    y32 = y.astype(jnp.float32)
    a, x_in = _gates(p, y32)
    # prepend carried state as a pseudo-step: h_0 absorbed via (a_0=1? no):
    # run assoc scan on the sequence then blend h_prev with the prefix decay.
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    a_cum, h_seq = lax.associative_scan(combine, (a, x_in), axis=1)
    h = h_seq + a_cum * state["h"][:, None]           # inject carried state
    out = (h.astype(x.dtype) * gate) @ p["w_proj"]
    return out, {"h": h[:, -1], "conv": new_tail}


def rglru_decode(cfg: ModelConfig, p: Params, x, state: Params):
    """x: (B,1,d) single step."""
    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    y, new_tail = _conv1d_causal(x @ p["w_in"], p["conv_w"], p["conv_b"],
                                 state["conv"])
    y32 = y[:, 0].astype(jnp.float32)
    a, x_in = _gates(p, y32)
    h = a * state["h"] + x_in
    out = (h[:, None].astype(x.dtype) * gate) @ p["w_proj"]
    return out, {"h": h, "conv": new_tail}


def state_init(cfg: ModelConfig, batch: int) -> Params:
    w = cfg.recurrent.lru_width or cfg.d_model
    cw = cfg.recurrent.conv1d_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, w), jnp.float32)}
