"""Mixture-of-experts FFN with **colibri dispatch** — the paper's technique
as a first-class framework feature.

Token→expert assignment is a contended-RMW problem: T·k requests racing for
E expert queues with bounded capacity. Classic implementations either
scatter-add with duplicate indices (serialized conflict resolution — the
LRSC retry analogue) or drop randomly on overflow. Colibri dispatch:

  * requests are linearized once by a stable sort (``core.dispatch``),
  * each request gets its FIFO queue position (Qnode depth) — oldest
    requests win under capacity pressure (``LRSCwait_q`` semantics,
    starvation-free in arrival order),
  * the dispatch table is built with a single commit per (expert, slot).

Distribution (hierarchical EP): experts shard over the intra-pod ``data``
axis (a2a stays on intra-pod ICI); each expert's FFN shards over ``model``
(TP); pods replicate experts and sync gradients over ``pod``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import dispatch as D
from repro.distributed.sharding import Policy, shard_map
from repro.models import layers as L

Params = Dict[str, Any]


def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = L.split(key, 5)

    def experts(k, din, dout):
        std = din ** -0.5
        return (jax.random.normal(k, (e, din, dout), jnp.float32) * std).astype(dtype)

    p = {"router": L.dense_init(ks[0], d, e, jnp.float32),
         "w_gate": experts(ks[1], d, f),
         "w_up": experts(ks[2], d, f),
         "w_down": experts(ks[3], f, d)}
    return p


def shared_init(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    return L.mlp_init(key, cfg.d_model, m.d_ff_expert * m.num_shared_experts,
                      "silu", dtype)


def capacity_for(num_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    t_assign = num_tokens * m.top_k
    cap = int(math.ceil(t_assign * m.capacity_factor / m.num_experts))
    cap = max(cap, 8)
    cap = min(cap, t_assign)
    return int(-(-cap // 8) * 8) if cap >= 8 else cap   # round up to 8


def _route(cfg: ModelConfig, router_w, x_flat):
    """Router: probs, top-k ids/gates, aux load-balance loss (fp32)."""
    m = cfg.moe
    logits = x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T,E)
    gates, ids = lax.top_k(probs, m.top_k)                      # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux: E * sum_e f_e * p_e
    t = x_flat.shape[0]
    f_e = D.histogram(ids.reshape(-1), m.num_experts).astype(jnp.float32) \
        / (t * m.top_k)
    p_e = probs.mean(0)
    aux = m.num_experts * jnp.sum(f_e * p_e)
    return ids, gates, aux


def _expert_ffn(w_gate, w_up, w_down, xbuf):
    """xbuf: (E, C, d) -> (E, C, d). Plain SwiGLU per expert."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xbuf, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xbuf, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


# ---------------------------------------------------------------------------
# Local path (single device / no mesh)
# ---------------------------------------------------------------------------

def _moe_local(cfg: ModelConfig, p: Params, x_flat) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m = cfg.moe
    t, d = x_flat.shape
    ids, gates, aux = _route(cfg, p["router"], x_flat)
    keys = ids.reshape(-1)                                      # (T*k,)
    cap = capacity_for(t, cfg)
    src, valid, disp = D.dispatch_indices(keys, m.num_experts, cap)
    token_of = src // m.top_k                                   # assignment -> token
    xbuf = jnp.take(x_flat, jnp.where(valid, token_of, 0), axis=0)
    xbuf = jnp.where(valid[..., None], xbuf, 0)                 # (E,C,d)
    ybuf = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"], xbuf)
    y_assign = D.combine_from_slots(ybuf, keys, disp.queue_pos, disp.keep,
                                    gates.reshape(-1))
    y = y_assign.reshape(t, m.top_k, d).sum(1)
    return y.astype(x_flat.dtype), aux


# ---------------------------------------------------------------------------
# Sharded path (EP over "data", expert-TP over "model")
# ---------------------------------------------------------------------------

def _moe_sharded_body(cfg: ModelConfig, ep_axis: str, tp_axis: Optional[str],
                      router_w, w_gate, w_up, w_down, x_blk):
    """shard_map body. x_blk: (B_l, S, d) local tokens (replicated over tp).
    w_*: (E_l, d, f_l) local expert shards."""
    m = cfg.moe
    n_ep = lax.psum(1, ep_axis)
    b_l, s, d = x_blk.shape
    x_flat = x_blk.reshape(b_l * s, d)
    t = b_l * s
    ids, gates, aux = _route(cfg, router_w, x_flat)
    keys = ids.reshape(-1)
    cap = capacity_for(t, cfg)
    # --- enqueue: colibri ordered dispatch into the global expert queues ---
    src, valid, disp = D.dispatch_indices(keys, m.num_experts, cap)
    token_of = src // m.top_k
    xbuf = jnp.take(x_flat, jnp.where(valid, token_of, 0), axis=0)
    xbuf = jnp.where(valid[..., None], xbuf, 0)                 # (E, C, d)
    # --- serve: a2a tokens to their expert's owner (intra-pod ICI) ---
    xrecv = lax.all_to_all(xbuf, ep_axis, split_axis=0, concat_axis=0,
                           tiled=True)                          # (n_ep*E_l, C, d)
    e_l = m.num_experts // n_ep
    xrecv = xrecv.reshape(n_ep, e_l, cap, d).transpose(1, 0, 2, 3) \
                 .reshape(e_l, n_ep * cap, d)
    y_l = _expert_ffn(w_gate, w_up, w_down, xrecv)              # partial over f
    # --- commit: a2a the f-PARTIAL outputs back, combine, then ONE psum on
    # the combined (T,d) tokens. §Perf hillclimb #3: psum-before-a2a reduced
    # the full (E, n_ep·C, d) dispatch buffer (top_k·cf ≈ 10x the token
    # bytes); psum-after-combine reduces only (T, d). The a2a is unchanged
    # (partials are the same size), total collective bytes drop ~2x and the
    # psum term ~10x. Mathematically identical: gather/weighted-sum commute
    # with the sum over f-shards. ---
    y_l = y_l.reshape(e_l, n_ep, cap, d).transpose(1, 0, 2, 3) \
             .reshape(n_ep * e_l, cap, d)
    ybuf = lax.all_to_all(y_l, ep_axis, split_axis=0, concat_axis=0,
                          tiled=True)                           # (E, C, d)
    y_assign = D.combine_from_slots(ybuf, keys, disp.queue_pos, disp.keep,
                                    gates.reshape(-1))
    y = y_assign.reshape(t, m.top_k, d).sum(1)
    if tp_axis is not None:
        y = lax.psum(y, tp_axis)
    y = y.reshape(b_l, s, d)
    return y.astype(x_blk.dtype), aux.reshape(1)


def moe_apply(cfg: ModelConfig, p: Params, x, policy: Policy
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d). Returns (y, aux_loss_scalar)."""
    b, s, d = x.shape
    if policy.mesh is None or policy.ep_axis is None:
        y, aux = _moe_local(cfg, p, x.reshape(b * s, d))
        return y.reshape(b, s, d), aux

    ep, tp = policy.ep_axis, policy.tp_axis
    dp = policy.dp_axes if len(policy.dp_axes) > 1 else policy.dp_axes[0]
    in_specs = (
        P(dp, None, None),                   # x
        P(None, None),                       # router (replicated)
        P(ep, None, tp), P(ep, None, tp),    # w_gate, w_up
        P(ep, tp, None),                     # w_down
    )
    out_specs = (P(dp, None, None), P(dp))
    body = partial(_moe_sharded_body, cfg, ep, tp)

    def f(x_, r_, wg_, wu_, wd_):
        return body(r_, wg_, wu_, wd_, x_)

    y, aux = shard_map(
        f, mesh=policy.mesh,
        in_specs=in_specs, out_specs=out_specs, check_vma=False)(
        x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux.mean()
