"""Unified model API: ``build(cfg)`` → Model with init / loss / serve entry
points, plus ``input_specs()`` (ShapeDtypeStruct stand-ins — weak-type
correct, shardable, no device allocation) for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import Policy
from repro.models import encdec as ED
from repro.models import transformer as TF

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- init ----
    def init(self, key) -> Params:
        if self.cfg.encoder is not None:
            return ED.init_params(self.cfg, key)
        return TF.init_params(self.cfg, key)

    # ---- training / prefill ----
    def hidden(self, params, batch: Dict[str, Any], policy: Policy):
        cfg = self.cfg
        if cfg.encoder is not None:
            return ED.forward(cfg, params, batch["tokens"],
                              batch["encoder_feats"], policy)
        return TF.forward(cfg, params, batch["tokens"], policy,
                          patch_embeds=batch.get("patch_embeds"))

    def loss(self, params, batch, policy: Policy
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        h, aux = self.hidden(params, batch, policy)
        loss, acc = TF.loss_fn(cfg, params, h, batch["labels"])
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_weight * aux
        return loss, {"loss": loss, "acc": acc, "aux": aux}

    def logits(self, params, batch, policy: Policy):
        h, _ = self.hidden(params, batch, policy)
        return TF.logits(self.cfg, params, h)

    # ---- serving ----
    def init_cache(self, batch: int, seq: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.compute_dtype)
        if cfg.encoder is not None:
            return ED.init_cache(cfg, batch, seq, dtype)
        return TF.init_cache(cfg, batch, seq, dtype)

    def prefill(self, params, batch, cache_len: int, policy: Policy):
        cfg = self.cfg
        if cfg.encoder is not None:
            return ED.prefill(cfg, params, batch["tokens"],
                              batch["encoder_feats"], cache_len, policy)
        return TF.prefill(cfg, params, batch["tokens"], cache_len, policy,
                          patch_embeds=batch.get("patch_embeds"))

    def decode_step(self, params, cache, tokens, pos, policy: Policy):
        cfg = self.cfg
        if cfg.encoder is not None:
            return ED.decode_step(cfg, params, cache, tokens, pos, policy)
        return TF.decode_step(cfg, params, cache, tokens, pos, policy)


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins) & synthetic batches (smoke tests)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    cdt = cfg.compute_dtype
    if shape.kind == "train":
        out = {"tokens": _sds((b, s), jnp.int32),
               "labels": _sds((b, s), jnp.int32)}
    elif shape.kind == "prefill":
        out = {"tokens": _sds((b, s), jnp.int32)}
    else:                                   # decode: one token + cache of s
        out = {"tokens": _sds((b, 1), jnp.int32),
               "pos": _sds((b,), jnp.int32)}
    if cfg.frontend == "audio":
        out["encoder_feats"] = _sds((b, cfg.encoder.seq_len, cfg.d_model), cdt)
    if cfg.frontend == "vlm" and shape.kind != "decode":
        out["patch_embeds"] = _sds((b, cfg.num_patches, cfg.d_model), cdt)
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    """ShapeDtypeStruct pytree for the decode cache of this cell."""
    model = build(cfg)
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    return cache_shape


def param_specs_shapes(cfg: ModelConfig) -> Params:
    """Abstract param pytree (eval_shape of init — no allocation)."""
    model = build(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def make_batch(cfg: ModelConfig, shape: ShapeSpec, key) -> Dict[str, Any]:
    """Concrete random batch (smoke tests / examples)."""
    b, s = shape.global_batch, shape.seq_len
    k1, k2, k3 = jax.random.split(key, 3)
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        toks = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
        out = {"tokens": toks,
               "labels": jnp.roll(toks, -1, axis=1)}
    elif shape.kind == "prefill":
        out = {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size)}
    else:
        out = {"tokens": jax.random.randint(k1, (b, 1), 0, cfg.vocab_size),
               "pos": jnp.full((b,), s // 2, jnp.int32)}
    if cfg.frontend == "audio":
        out["encoder_feats"] = jax.random.normal(
            k2, (b, cfg.encoder.seq_len, cfg.d_model), cdt) * 0.02
    if cfg.frontend == "vlm" and shape.kind != "decode":
        out["patch_embeds"] = jax.random.normal(
            k3, (b, cfg.num_patches, cfg.d_model), cdt) * 0.02
    return out
