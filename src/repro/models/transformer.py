"""Decoder-only LM assembly: heterogeneous block stacks under lax.scan.

Layers are grouped into *segments* — maximal runs where the block-pattern
unit repeats — and each segment's params are stacked along a leading axis
and consumed by ``lax.scan`` (O(1) HLO in depth: an 88-layer model compiles
the same graph size as a 2-layer one). recurrentgemma's (rglru,rglru,local)
unit scans as a super-block; MoE models scan dense and MoE segments
separately.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Policy
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MoE
from repro.models import rglru as RG
from repro.models import rwkv6 as RW

Params = Dict[str, Any]
LayerSig = Tuple[str, str]          # (mix_kind, ffn_kind)


# ---------------------------------------------------------------------------
# Layer planning
# ---------------------------------------------------------------------------

def layer_sigs(cfg: ModelConfig) -> List[LayerSig]:
    sigs = []
    for i, kind in enumerate(cfg.layer_kinds()):
        if kind == "rwkv":
            ffn = "rwkv_cm"
        elif cfg.moe is not None:
            ffn = "moe" if i >= cfg.moe.moe_layer_start else "dense"
        else:
            ffn = "mlp"
        sigs.append((kind, ffn))
    return sigs


def plan_segments(cfg: ModelConfig) -> List[Tuple[Tuple[LayerSig, ...], int]]:
    """[(unit, repeats), ...] — maximal cyclic runs."""
    sigs = layer_sigs(cfg)
    p = len(cfg.block_pattern)
    segs: List[Tuple[Tuple[LayerSig, ...], int]] = []
    i, n = 0, len(sigs)
    while i < n:
        if p > 1 and n - i >= p:
            unit = tuple(sigs[i: i + p])
            k = 1
            while i + (k + 1) * p <= n and tuple(sigs[i + k * p: i + (k + 1) * p]) == unit:
                k += 1
            if k > 1:
                segs.append((unit, k))
                i += k * p
                continue
        j = i
        while j < n and sigs[j] == sigs[i]:
            j += 1
        segs.append(((sigs[i],), j - i))
        i = j
    return segs


def mlp_kind(cfg: ModelConfig) -> str:
    if cfg.act == "silu":
        return "swiglu"
    return "geglu" if cfg.norm == "rmsnorm" else "gelu"


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------

def _ffn_init(key, cfg: ModelConfig, ffn: str, dtype) -> Params:
    mk = mlp_kind(cfg)
    if ffn == "mlp":
        return {"mlp": L.mlp_init(key, cfg.d_model, cfg.d_ff,
                                  "silu" if mk != "gelu" else "gelu", dtype)}
    if ffn == "dense":
        return {"mlp": L.mlp_init(key, cfg.d_model, cfg.moe.dense_d_ff,
                                  "silu", dtype)}
    if ffn == "moe":
        k1, k2 = L.split(key, 2)
        return {"moe": MoE.moe_init(k1, cfg, dtype),
                "shared": MoE.shared_init(k2, cfg, dtype)}
    if ffn == "rwkv_cm":
        return {"cm": RW.channel_mix_init(key, cfg, dtype)}
    raise ValueError(ffn)


def block_init(key, cfg: ModelConfig, sig: LayerSig, dtype) -> Params:
    mix, ffn = sig
    k1, k2 = L.split(key, 2)
    p: Params = {"norm1": L.norm_init(cfg.norm, cfg.d_model, dtype),
                 "norm2": L.norm_init(cfg.norm, cfg.d_model, dtype)}
    if mix == "attn" and cfg.attn_kind == "mla":
        p["attn"] = A.mla_init(k1, cfg, dtype)
    elif mix in ("attn", "local"):
        p["attn"] = A.gqa_init(k1, cfg, dtype)
    elif mix == "rglru":
        p["rglru"] = RG.rglru_init(k1, cfg, dtype)
    elif mix == "rwkv":
        p["rwkv"] = RW.time_mix_init(k1, cfg, dtype)
    p.update(_ffn_init(k2, cfg, ffn, dtype))
    return p


def _ffn_apply(cfg: ModelConfig, sig: LayerSig, p: Params, h, policy: Policy,
               shift_cm=None):
    """Returns (y, aux, new_shift_cm)."""
    mix, ffn = sig
    zero = jnp.zeros((), jnp.float32)
    if ffn == "moe":
        y, aux = MoE.moe_apply(cfg, p["moe"], h, policy)
        y = y + L.mlp_apply(p["shared"], h, "silu")
        return y, aux, None
    if ffn == "rwkv_cm":
        st = shift_cm if shift_cm is not None else jnp.zeros(
            (h.shape[0], h.shape[2]), jnp.float32)
        y, new_st = RW.channel_mix_apply(p["cm"], h, st.astype(h.dtype))
        return y, zero, new_st
    mk = mlp_kind(cfg)
    if mk == "geglu":
        return L.geglu_apply(p["mlp"], h), zero, None
    return L.mlp_apply(p["mlp"], h, "silu" if mk == "swiglu" else "gelu"), zero, None


def _sp(policy: Policy, x):
    """Sequence-parallel residual: keep (B,S,d) sharded over (dp, model, -).
    Per-token ops (norms, qkv/mlp matmuls) run on S-shards; the MLP double-
    shards (S x f) and reduces 1/tp-sized partials — replacing two full
    hidden-size all-reduces per layer with one 1/tp-sized one."""
    if policy.mesh is None or not policy.sequence_parallel:
        return x
    dp = policy.dp_axes if len(policy.dp_axes) > 1 else policy.dp_axes[0]
    return policy.constrain(x, dp, policy.tp_axis, None)


def apply_block(cfg: ModelConfig, sig: LayerSig, p: Params, x, positions,
                policy: Policy):
    """Full-sequence (train/prefill, state-free). Returns (x, aux)."""
    mix, _ = sig
    x = _sp(policy, x)
    h = L.norm_apply(cfg.norm, p["norm1"], x, cfg.norm_eps)
    b = x.shape[0]
    if mix == "attn" and cfg.attn_kind == "mla":
        a, _ = A.mla_apply(cfg, p["attn"], h, positions)
    elif mix == "attn":
        a, _ = A.gqa_apply(cfg, p["attn"], h, positions, causal=True,
                           policy=policy)
    elif mix == "local":
        a, _ = A.gqa_apply(cfg, p["attn"], h, positions, causal=True,
                           window=cfg.local_window, policy=policy)
    elif mix == "rglru":
        a, _ = RG.rglru_apply(cfg, p["rglru"], h, RG.state_init(cfg, b))
    elif mix == "rwkv":
        st = RW.state_init(cfg, b)
        a, _, _ = RW.time_mix_apply(cfg, p["rwkv"], h,
                                    st["shift_tm"].astype(h.dtype), st["wkv"])
    else:
        raise ValueError(mix)
    x = _sp(policy, x + a)
    h = L.norm_apply(cfg.norm, p["norm2"], x, cfg.norm_eps)
    y, aux, _ = _ffn_apply(cfg, sig, p, h, policy)
    return _sp(policy, x + y), aux


# ---------------------------------------------------------------------------
# Decode (stateful) block
# ---------------------------------------------------------------------------

def block_cache_init(cfg: ModelConfig, sig: LayerSig, batch: int, seq: int,
                     dtype) -> Params:
    mix, ffn = sig
    c: Params = {}
    if mix == "attn" and cfg.attn_kind == "mla":
        c["attn"] = A.mla_cache_init(cfg, batch, seq, dtype)
    elif mix == "attn":
        c["attn"] = A.gqa_cache_init(cfg, batch, seq, dtype)
    elif mix == "local":
        c["attn"] = A.gqa_cache_init(cfg, batch, min(cfg.local_window, seq), dtype)
    elif mix == "rglru":
        c["rglru"] = RG.state_init(cfg, batch)
    elif mix == "rwkv":
        c["rwkv"] = RW.state_init(cfg, batch)
    if ffn == "rwkv_cm":
        c["cm_shift"] = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return c


def apply_block_decode(cfg: ModelConfig, sig: LayerSig, p: Params, cache: Params,
                       x, pos, policy: Policy):
    """One-token step. x: (B,1,d); pos: (B,). Returns (x, new_cache)."""
    mix, ffn = sig
    h = L.norm_apply(cfg.norm, p["norm1"], x, cfg.norm_eps)
    newc: Params = {}
    if mix == "attn" and cfg.attn_kind == "mla":
        a, newc["attn"] = A.mla_decode(cfg, p["attn"], h, cache["attn"], pos)
    elif mix == "attn":
        a, newc["attn"] = A.gqa_decode(cfg, p["attn"], h, cache["attn"], pos)
    elif mix == "local":
        a, newc["attn"] = A.gqa_decode(cfg, p["attn"], h, cache["attn"], pos,
                                       window=cfg.local_window)
    elif mix == "rglru":
        a, newc["rglru"] = RG.rglru_decode(cfg, p["rglru"], h, cache["rglru"])
    elif mix == "rwkv":
        st = cache["rwkv"]
        a, new_shift, new_wkv = RW.time_mix_decode(
            cfg, p["rwkv"], h, st["shift_tm"].astype(h.dtype), st["wkv"])
        newc["rwkv"] = {"shift_tm": new_shift.astype(jnp.float32),
                        "shift_cm": st["shift_cm"], "wkv": new_wkv}
    x = x + a
    h = L.norm_apply(cfg.norm, p["norm2"], x, cfg.norm_eps)
    if ffn == "rwkv_cm":
        y, new_cm = RW.channel_mix_decode(
            p["cm"], h, newc["rwkv"]["shift_cm"].astype(h.dtype))
        newc["rwkv"] = dict(newc["rwkv"], shift_cm=new_cm.astype(jnp.float32))
        aux = None
    else:
        y, _, _ = _ffn_apply(cfg, sig, p, h, policy)
    return x + y, newc


def _fill_attn_cache(cache: Params, kv, window: int = 0) -> Params:
    """Write prefill K/V (B,S,KV,hd) into a fresh cache (ring-buffered for
    sliding-window layers)."""
    k, v = kv
    s = k.shape[1]
    s_cache = cache["k"].shape[1]
    if not window or s <= s_cache:
        if s <= s_cache and not window:
            return {"k": lax.dynamic_update_slice(
                        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
                    "v": lax.dynamic_update_slice(
                        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))}
    # ring buffer: keep the last s_cache positions at slot (pos % s_cache)
    import numpy as np
    take = min(s, s_cache)
    gpos = np.arange(s - take, s)
    slots = gpos % s_cache
    return {"k": cache["k"].at[:, slots].set(k[:, gpos].astype(cache["k"].dtype)),
            "v": cache["v"].at[:, slots].set(v[:, gpos].astype(cache["v"].dtype))}


def apply_block_prefill(cfg: ModelConfig, sig: LayerSig, p: Params,
                        cache: Params, x, positions, policy: Policy):
    """Full-sequence forward that also fills the decode cache."""
    mix, ffn = sig
    h = L.norm_apply(cfg.norm, p["norm1"], x, cfg.norm_eps)
    b, s, _ = x.shape
    newc: Params = {}
    if mix == "attn" and cfg.attn_kind == "mla":
        a, (ckv, krope) = A.mla_apply(cfg, p["attn"], h, positions)
        newc["attn"] = {
            "c_kv": lax.dynamic_update_slice(
                cache["attn"]["c_kv"], ckv.astype(cache["attn"]["c_kv"].dtype),
                (0, 0, 0)),
            "k_rope": lax.dynamic_update_slice(
                cache["attn"]["k_rope"],
                krope.astype(cache["attn"]["k_rope"].dtype), (0, 0, 0))}
    elif mix == "attn":
        a, kv = A.gqa_apply(cfg, p["attn"], h, positions, causal=True,
                            kv_out=True, policy=policy)
        newc["attn"] = _fill_attn_cache(cache["attn"], kv)
    elif mix == "local":
        a, kv = A.gqa_apply(cfg, p["attn"], h, positions, causal=True,
                            window=cfg.local_window, kv_out=True,
                            policy=policy)
        newc["attn"] = _fill_attn_cache(cache["attn"], kv, cfg.local_window)
    elif mix == "rglru":
        a, newc["rglru"] = RG.rglru_apply(cfg, p["rglru"], h,
                                          RG.state_init(cfg, b))
    elif mix == "rwkv":
        st = RW.state_init(cfg, b)
        a, shift, wkv = RW.time_mix_apply(cfg, p["rwkv"], h,
                                          st["shift_tm"].astype(h.dtype),
                                          st["wkv"])
        newc["rwkv"] = {"shift_tm": shift.astype(jnp.float32),
                        "shift_cm": jnp.zeros((b, cfg.d_model), jnp.float32),
                        "wkv": wkv}
    x = x + a
    h = L.norm_apply(cfg.norm, p["norm2"], x, cfg.norm_eps)
    if ffn == "rwkv_cm":
        y, new_cm = RW.channel_mix_apply(
            p["cm"], h, jnp.zeros((b, cfg.d_model), h.dtype))
        newc["rwkv"] = dict(newc["rwkv"], shift_cm=new_cm.astype(jnp.float32))
    else:
        y, _, _ = _ffn_apply(cfg, sig, p, h, policy)
    return x + y, newc


def prefill(cfg: ModelConfig, params: Params, tokens, cache_len: int,
            policy: Policy, cache_dtype=None, patch_embeds=None):
    """Process a prompt and return (hidden, filled cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    cache_dtype = cache_dtype or cdt
    b, s = tokens.shape
    cache = init_cache(cfg, b, cache_len, cache_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if cfg.frontend == "vlm" and patch_embeds is not None:
        p_ = min(patch_embeds.shape[1], x.shape[1])
        x = jnp.concatenate([patch_embeds[:, :p_].astype(cdt), x[:, p_:]],
                            axis=1)
    positions = jnp.arange(s)
    new_caches = []
    for (unit, _), seg_p, seg_c in zip(plan_segments(cfg), params["segments"],
                                       cache):
        def body(xx, xs):
            lp, lc = xs
            newc = {}
            for j, sig in enumerate(unit):
                xx, nc = apply_block_prefill(cfg, sig, lp[f"u{j}"],
                                             lc[f"u{j}"], xx, positions, policy)
                newc[f"u{j}"] = nc
            return xx, newc
        x, new_c = lax.scan(body, x, (seg_p, seg_c))
        new_caches.append(new_c)
    x = L.norm_apply(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    return x, new_caches


# ---------------------------------------------------------------------------
# Whole-model init / forward / decode
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    segs = plan_segments(cfg)
    k_embed, k_head, k_blocks = L.split(key, 3)
    params: Params = {"embed": L.embed_init(k_embed, cfg.vocab_size,
                                            cfg.d_model, dtype),
                      "final_norm": L.norm_init(cfg.norm, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_size,
                                         dtype)
    seg_params = []
    keys = L.split(k_blocks, len(segs))
    for (unit, repeats), sk in zip(segs, keys):
        rkeys = L.split(sk, repeats)

        def unit_init(k):
            uks = L.split(k, len(unit))
            return {f"u{j}": block_init(uks[j], cfg, unit[j], dtype)
                    for j in range(len(unit))}
        seg_params.append(jax.vmap(unit_init)(rkeys))
    params["segments"] = seg_params
    return params


def _constrain_layer_params(policy: Policy, lp: Params) -> Params:
    """Pin the per-layer param slice to its (FSDP-)sharded spec inside the
    scan body, so XLA re-gathers per layer instead of hoisting a full-stack
    all-gather out of the loop."""
    if policy.mesh is None or not policy.fsdp:
        return lp
    from repro.distributed import sharding as SH

    def leaf(path, x):
        spec = SH.spec_for(SH._path_str(path), x.shape, policy, stacked=False)
        return policy.constrain(x, *spec)
    return jax.tree_util.tree_map_with_path(leaf, lp)


def _seg_apply(cfg, unit, seg_p, x, positions, policy, remat: bool):
    def body(carry, lp):
        xx, aux = carry
        lp = _constrain_layer_params(policy, lp)
        for j, sig in enumerate(unit):
            xx, a = apply_block(cfg, sig, lp[f"u{j}"], xx, positions, policy)
            aux = aux + a
        return (xx, aux), None
    body_fn = jax.checkpoint(body, prevent_cse=False) if remat else body
    (x, aux), _ = lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), seg_p)
    return x, aux


def forward(cfg: ModelConfig, params: Params, tokens, policy: Policy,
            patch_embeds=None, positions=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B,S) -> (hidden (B,S,d), aux loss)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if cfg.frontend == "vlm" and patch_embeds is not None:
        p = min(patch_embeds.shape[1], x.shape[1])
        x = jnp.concatenate([patch_embeds[:, :p].astype(cdt), x[:, p:]], axis=1)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])
    if policy.mesh is not None:
        x = policy.constrain(x, *policy.batch_spec(3))   # (dp, None, None)
    aux_total = jnp.zeros((), jnp.float32)
    for (unit, _), seg_p in zip(plan_segments(cfg), params["segments"]):
        x, aux = _seg_apply(cfg, unit, seg_p, x, positions, policy,
                            cfg.parallel.remat)
        aux_total = aux_total + aux
    x = L.norm_apply(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    return x, aux_total


def logits(cfg: ModelConfig, params: Params, hidden) -> jnp.ndarray:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (hidden @ head.astype(hidden.dtype)).astype(jnp.float32)


def loss_fn(cfg: ModelConfig, params: Params, hidden, labels,
            chunk: int = 1024) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Streaming CE over SEQUENCE chunks — never materialises (B,S,V) fp32,
    and never re-partitions the dp-sharded batch dim (chunking the flattened
    token stream would all-gather the global batch)."""
    b, s, d = hidden.shape
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    c = min(chunk, s)
    pad = (-s) % c
    hp = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nch = hp.shape[1] // c
    hc = hp.reshape(b, nch, c, d).transpose(1, 0, 2, 3)     # (nch, B, c, d)
    lc = lp.reshape(b, nch, c).transpose(1, 0, 2)

    @partial(jax.checkpoint, prevent_cse=False)
    def chunk_loss(carry, xs):
        # checkpointed: backward recomputes the (B, c, V) logits instead of
        # stacking them across the scan (which would be O(S·V) fp32).
        hx, lx = xs
        lg = (hx @ head.astype(hx.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, jnp.clip(lx, 0)[..., None],
                                   axis=-1)[..., 0]
        mask = (lx >= 0).astype(jnp.float32)
        nll = ((lse - gold + 1e-4 * lse ** 2) * mask).sum()
        correct = ((jnp.argmax(lg, -1) == lx) * mask).sum()
        c0, c1, c2 = carry
        return (c0 + nll, c1 + correct, c2 + mask.sum()), None

    (nll, correct, denom), _ = lax.scan(
        chunk_loss, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (hc, lc))
    denom = jnp.maximum(denom, 1.0)
    return nll / denom, correct / denom


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype) -> List[Params]:
    caches = []
    for unit, repeats in plan_segments(cfg):
        def unit_cache(_):
            return {f"u{j}": block_cache_init(cfg, unit[j], batch, seq, dtype)
                    for j in range(len(unit))}
        caches.append(jax.vmap(unit_cache)(jnp.arange(repeats)))
    return caches


def decode_step(cfg: ModelConfig, params: Params, cache: List[Params],
                tokens, pos, policy: Policy):
    """tokens: (B,1); pos: (B,). Returns (logits (B,1,V) fp32, new_cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    new_caches = []
    for (unit, _), seg_p, seg_c in zip(plan_segments(cfg), params["segments"],
                                       cache):
        def body(xx, xs):
            lp, lc = xs
            newc = {}
            for j, sig in enumerate(unit):
                xx, nc = apply_block_decode(cfg, sig, lp[f"u{j}"],
                                            lc[f"u{j}"], xx, pos, policy)
                newc[f"u{j}"] = nc
            return xx, newc
        x, new_c = lax.scan(body, x, (seg_p, seg_c))
        new_caches.append(new_c)
    x = L.norm_apply(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    return logits(cfg, params, x), new_caches
