"""Whisper-style encoder-decoder.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, 1500, d) as the encoder input.
Decoder positions use sinusoidal embeddings (real whisper uses learned —
documented deviation, FLOP-neutral) so the same checkpoint serves any
decoder length.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Policy
from repro.models import attention as A
from repro.models import layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _enc_block_init(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = L.split(key, 2)
    return {"norm1": L.layernorm_init(cfg.d_model, dtype),
            "attn": A.cross_attn_init(k1, cfg, dtype),   # MHA layout (wq/wk/wv/wo)
            "norm2": L.layernorm_init(cfg.d_model, dtype),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, "gelu", dtype)}


def _dec_block_init(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = L.split(key, 3)
    return {"norm1": L.layernorm_init(cfg.d_model, dtype),
            "self_attn": A.gqa_init(k1, cfg, dtype),
            "norm_x": L.layernorm_init(cfg.d_model, dtype),
            "cross_attn": A.cross_attn_init(k2, cfg, dtype),
            "norm2": L.layernorm_init(cfg.d_model, dtype),
            "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, "gelu", dtype)}


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    e = cfg.encoder
    ks = L.split(key, 6)
    enc_keys = L.split(ks[0], e.num_layers)
    dec_keys = L.split(ks[1], cfg.num_layers)
    return {
        "pos_embed": (jax.random.normal(ks[2], (e.seq_len, cfg.d_model),
                                        jnp.float32) * 0.01).astype(dtype),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(enc_keys),
        "enc_norm": L.layernorm_init(cfg.d_model, dtype),
        "embed": L.embed_init(ks[3], cfg.vocab_size, cfg.d_model, dtype),
        "segments": [jax.vmap(lambda k: {"u0": _dec_block_init(k, cfg, dtype)}
                              )(dec_keys)],
        "final_norm": L.layernorm_init(cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: Params, feats, policy: Policy):
    """feats: (B, Se, d) precomputed frame embeddings (frontend stub)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = feats.astype(cdt) + params["pos_embed"].astype(cdt)[None]

    def body(xx, p):
        h = L.layernorm(p["norm1"], xx, cfg.norm_eps)
        b, s, _ = xx.shape
        hd, nh = cfg.resolved_head_dim, cfg.num_heads
        q = (h @ p["attn"]["wq"]).reshape(b, s, nh, hd)
        k = (h @ p["attn"]["wk"]).reshape(b, s, nh, hd)
        v = (h @ p["attn"]["wv"]).reshape(b, s, nh, hd)
        a = A.blocked_attention(q, k, v, causal=False)
        xx = xx + a.reshape(b, s, -1) @ p["attn"]["wo"]
        h = L.layernorm(p["norm2"], xx, cfg.norm_eps)
        return xx + L.mlp_apply(p["mlp"], h, "gelu"), None

    body_fn = jax.checkpoint(body) if cfg.parallel.remat else body
    x, _ = lax.scan(lambda c, p: (body_fn(c, p)[0], None), x,
                    params["enc_blocks"])
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder (train / prefill path)
# ---------------------------------------------------------------------------

def _dec_block(cfg, p, x, enc, positions, policy):
    h = L.layernorm(p["norm1"], x, cfg.norm_eps)
    a, _ = A.gqa_apply(cfg, p["self_attn"], h, positions, causal=True,
                       rope=False)
    x = x + a
    h = L.layernorm(p["norm_x"], x, cfg.norm_eps)
    a, _ = A.cross_attn_apply(cfg, p["cross_attn"], h, enc=enc)
    x = x + a
    h = L.layernorm(p["norm2"], x, cfg.norm_eps)
    return x + L.mlp_apply(p["mlp"], h, "gelu")


def forward(cfg: ModelConfig, params: Params, tokens, feats, policy: Policy):
    """tokens: (B,S) decoder input; feats: (B,Se,d). Returns (hidden, aux=0)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    enc = encode(cfg, params, feats, policy)
    b, s = tokens.shape
    pos_sin = L.sinusoidal_positions(s, cfg.d_model).astype(cdt)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt) + pos_sin[None]
    positions = jnp.arange(s)

    def body(xx, p):
        return _dec_block(cfg, p["u0"], xx, enc, positions, policy), None

    body_fn = jax.checkpoint(body) if cfg.parallel.remat else body
    x, _ = lax.scan(lambda c, p: (body_fn(c, p)[0], None), x,
                    params["segments"][0])
    x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Serving: prefill + decode with self-KV cache and precomputed cross-KV
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype) -> Params:
    hd, nh = cfg.resolved_head_dim, cfg.num_heads
    ld = cfg.num_layers
    se = cfg.encoder.seq_len
    return {
        "self": {"k": jnp.zeros((ld, batch, seq, cfg.num_kv_heads, hd), dtype),
                 "v": jnp.zeros((ld, batch, seq, cfg.num_kv_heads, hd), dtype)},
        "cross": {"k": jnp.zeros((ld, batch, se, nh, hd), dtype),
                  "v": jnp.zeros((ld, batch, se, nh, hd), dtype)},
    }


def prefill(cfg: ModelConfig, params: Params, tokens, feats, cache_len: int,
            policy: Policy, cache_dtype=None):
    """Encode audio, run the prompt through the decoder, fill caches."""
    cdt = jnp.dtype(cfg.compute_dtype)
    cache_dtype = cache_dtype or cdt
    enc = encode(cfg, params, feats, policy)
    b, s = tokens.shape
    cache = init_cache(cfg, b, cache_len, cache_dtype)
    pos_sin = L.sinusoidal_positions(s, cfg.d_model).astype(cdt)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt) + pos_sin[None]
    positions = jnp.arange(s)

    def body(xx, xs):
        p = xs["u0"] if "u0" in xs else xs
        h = L.layernorm(p["norm1"], xx, cfg.norm_eps)
        a, kv = A.gqa_apply(cfg, p["self_attn"], h, positions, causal=True,
                            rope=False, kv_out=True)
        xx = xx + a
        h = L.layernorm(p["norm_x"], xx, cfg.norm_eps)
        a, ckv = A.cross_attn_apply(cfg, p["cross_attn"], h, enc=enc)
        xx = xx + a
        h = L.layernorm(p["norm2"], xx, cfg.norm_eps)
        xx = xx + L.mlp_apply(p["mlp"], h, "gelu")
        return xx, {"self_k": kv[0].astype(cache_dtype),
                    "self_v": kv[1].astype(cache_dtype),
                    "cross_k": ckv[0].astype(cache_dtype),
                    "cross_v": ckv[1].astype(cache_dtype)}

    x, ys = lax.scan(body, x, params["segments"][0])
    cache["self"]["k"] = lax.dynamic_update_slice(
        cache["self"]["k"], ys["self_k"], (0, 0, 0, 0, 0))
    cache["self"]["v"] = lax.dynamic_update_slice(
        cache["self"]["v"], ys["self_v"], (0, 0, 0, 0, 0))
    cache["cross"]["k"] = ys["cross_k"]
    cache["cross"]["v"] = ys["cross_v"]
    x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    return x, cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params, tokens, pos,
                policy: Policy):
    """tokens: (B,1); pos: (B,). Cross-KV must be prefilled."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b = tokens.shape[0]
    hd, nh = cfg.resolved_head_dim, cfg.num_heads
    # sinusoidal position for the current step
    d = cfg.d_model
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((b, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(
        jnp.cos(ang[:, : (d + 1) // 2]))
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt) + \
        pe[:, None].astype(cdt)

    def body(xx, xs):
        p, sk, sv, ck, cv = xs
        p = p["u0"]
        h = L.layernorm(p["norm1"], xx, cfg.norm_eps)
        a, newc = A.gqa_decode(cfg, p["self_attn"], h, {"k": sk, "v": sv},
                               pos, rope=False)
        xx = xx + a
        h = L.layernorm(p["norm_x"], xx, cfg.norm_eps)
        q = (h @ p["cross_attn"]["wq"]).reshape(b, 1, nh, hd)
        a = A.decode_attention(q, ck, cv,
                               jnp.full((b,), ck.shape[1] - 1, jnp.int32))
        xx = xx + a.reshape(b, 1, -1) @ p["cross_attn"]["wo"]
        h = L.layernorm(p["norm2"], xx, cfg.norm_eps)
        xx = xx + L.mlp_apply(p["mlp"], h, "gelu")
        return xx, (newc["k"], newc["v"])

    x, (nk, nv) = lax.scan(body, x, (params["segments"][0],
                                     cache["self"]["k"], cache["self"]["v"],
                                     cache["cross"]["k"], cache["cross"]["v"]))
    new_cache = {"self": {"k": nk, "v": nv}, "cross": cache["cross"]}
    x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T  # whisper ties embeddings
    return (x @ head.astype(x.dtype)).astype(jnp.float32), new_cache
