from repro.models.model_zoo import Model, build, input_specs, make_batch

__all__ = ["Model", "build", "input_specs", "make_batch"]
