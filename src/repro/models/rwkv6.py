"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

The jnp model path uses the exact sequential recurrence (``lax.scan`` over
time — one HLO body regardless of S, exact for both train and decode). The
chunked-parallel formulation lives in ``repro.kernels.rwkv6_wkv`` (TPU hot
path) and is validated against this recurrence.

State per layer: token-shift (last input) for time-mix and channel-mix, and
the per-head wkv matrix S ∈ R^{hd×hd} — O(1) in sequence length, which is
why this arch runs the long_500k shape.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]
LORA_RANK = 64


def time_mix_init(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    hd = cfg.recurrent.head_dim
    h = d // hd
    ks = L.split(key, 10)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),
        "w_r": L.dense_init(ks[1], d, d, dtype),
        "w_k": L.dense_init(ks[2], d, d, dtype),
        "w_v": L.dense_init(ks[3], d, d, dtype),
        "w_g": L.dense_init(ks[4], d, d, dtype),
        "w_o": L.dense_init(ks[5], d, d, dtype),
        "w0": (jax.random.normal(ks[6], (d,), jnp.float32) - 5.0).astype(jnp.float32),
        "w_lora_a": L.dense_init(ks[7], d, LORA_RANK, dtype),
        "w_lora_b": L.dense_init(ks[8], LORA_RANK, d, dtype, scale=0.1),
        "u": (jax.random.normal(ks[9], (h, hd), jnp.float32) * 0.1).astype(jnp.float32),
        "gn_scale": jnp.ones((d,), dtype),
        "gn_bias": jnp.zeros((d,), dtype),
    }


def channel_mix_init(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = L.split(key, 3)
    return {
        "mu": jax.random.uniform(ks[0], (2, d), jnp.float32).astype(dtype),
        "w_k": L.dense_init(ks[1], d, f, dtype),
        "w_v": L.dense_init(ks[2], f, d, dtype),
        "w_r": L.dense_init(ks[0], d, d, dtype),
    }


def _shift(x, x_prev):
    """Token shift: value of the previous timestep. x: (B,S,d);
    x_prev: (B,d) carry from the previous segment/step."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u):
    """Exact wkv recurrence. r,k,v,w: (B,S,H,hd) fp32; u: (H,hd).
    Returns (out (B,S,H,hd), final_state (B,H,hd,hd))."""
    b, s, h, hd = r.shape

    def step(S, inp):
        rt, kt, vt, wt = inp                          # (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)      # outer product
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S_new = wt[..., None] * S + kv
        return S_new, out

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    S0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    S_fin, outs = lax.scan(step, S0, xs)
    return outs.transpose(1, 0, 2, 3), S_fin


def time_mix_apply(cfg: ModelConfig, p: Params, x, shift_state, wkv_state
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d). Returns (out, new_shift (B,d), new_wkv (B,H,hd,hd))."""
    b, s, d = x.shape
    hd = cfg.recurrent.head_dim
    h = d // hd
    xp = _shift(x, shift_state)
    mu = p["mu"].astype(x.dtype)
    mix = lambda i: x + (xp - x) * mu[i]
    r = (mix(0) @ p["w_r"]).reshape(b, s, h, hd).astype(jnp.float32)
    k = (mix(1) @ p["w_k"]).reshape(b, s, h, hd).astype(jnp.float32)
    v = (mix(2) @ p["w_v"]).reshape(b, s, h, hd).astype(jnp.float32)
    g = jax.nn.silu(mix(3) @ p["w_g"])
    # data-dependent decay (the "Finch" feature)
    wln = p["w0"] + (jnp.tanh(mix(4) @ p["w_lora_a"]) @ p["w_lora_b"]
                     ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wln)).reshape(b, s, h, hd)   # in (0,1)
    out, S_fin = _wkv_scan(r, k, v, w, p["u"])
    out = out.reshape(b, s, d).astype(x.dtype)
    out = L.groupnorm(out, p["gn_scale"], p["gn_bias"], num_groups=h)
    out = (out * g) @ p["w_o"]
    return out, x[:, -1], S_fin


def time_mix_decode(cfg: ModelConfig, p: Params, x, shift_state, wkv_state):
    """Single-token step. x: (B,1,d); wkv_state: (B,H,hd,hd) fp32."""
    b, _, d = x.shape
    hd = cfg.recurrent.head_dim
    h = d // hd
    xp = shift_state[:, None]
    mu = p["mu"].astype(x.dtype)
    mix = lambda i: x + (xp - x) * mu[i]
    r = (mix(0) @ p["w_r"]).reshape(b, h, hd).astype(jnp.float32)
    k = (mix(1) @ p["w_k"]).reshape(b, h, hd).astype(jnp.float32)
    v = (mix(2) @ p["w_v"]).reshape(b, h, hd).astype(jnp.float32)
    g = jax.nn.silu(mix(3) @ p["w_g"])
    wln = p["w0"] + (jnp.tanh(mix(4) @ p["w_lora_a"]) @ p["w_lora_b"]
                     ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wln)).reshape(b, h, hd)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    out = jnp.einsum("bhk,bhkv->bhv", r, wkv_state + p["u"][None, :, :, None] * kv)
    S_new = w[..., None] * wkv_state + kv
    out = out.reshape(b, 1, d).astype(x.dtype)
    out = L.groupnorm(out, p["gn_scale"], p["gn_bias"], num_groups=h)
    out = (out * g) @ p["w_o"]
    return out, x[:, -1], S_new


def channel_mix_apply(p: Params, x, shift_state):
    xp = _shift(x, shift_state)
    mu = p["mu"].astype(x.dtype)
    xk = x + (xp - x) * mu[0]
    xr = x + (xp - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"]), x[:, -1]


def channel_mix_decode(p: Params, x, shift_state):
    xp = shift_state[:, None]
    mu = p["mu"].astype(x.dtype)
    xk = x + (xp - x) * mu[0]
    xr = x + (xp - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"]), x[:, -1]


def state_init(cfg: ModelConfig, batch: int) -> Params:
    d = cfg.d_model
    hd = cfg.recurrent.head_dim
    h = d // hd
    return {"shift_tm": jnp.zeros((batch, d), jnp.float32),
            "shift_cm": jnp.zeros((batch, d), jnp.float32),
            "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32)}
