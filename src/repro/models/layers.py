"""Shared neural-net layers (pure JAX, explicit param pytrees).

Conventions
-----------
* Params are nested dicts of jnp arrays. Multi-layer stacks carry a leading
  layer axis and are consumed by ``lax.scan``.
* Math runs in ``compute_dtype`` (bf16 by default); norms, softmax and
  recurrent states run in fp32.
* No sharding in this module — sharding is applied by
  ``repro.distributed.sharding`` via param-path rules and an activation
  ``Policy`` object (see model_zoo).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / (d_in ** 0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def split(key, n: int):
    return jax.random.split(key, n)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def norm_init(kind: str, d: int, dtype) -> Params:
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm_apply(kind: str, p: Params, x, eps: float = 1e-5):
    return rmsnorm(p, x, eps) if kind == "rmsnorm" else layernorm(p, x, eps)


def groupnorm(x: jnp.ndarray, scale, bias, num_groups: int, eps: float = 64e-5):
    """GroupNorm over the last dim (rwkv6 output norm; eps follows rwkv)."""
    dt = x.dtype
    *lead, d = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(*lead, d)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, act: str, dtype) -> Params:
    if act == "silu":                     # gated (SwiGLU)
        k1, k2, k3 = split(key, 3)
        return {"w_gate": dense_init(k1, d, d_ff, dtype),
                "w_up": dense_init(k2, d, d_ff, dtype),
                "w_down": dense_init(k3, d_ff, d, dtype)}
    k1, k2 = split(key, 2)                # plain GELU MLP (whisper / gelu archs)
    return {"w_up": dense_init(k1, d, d_ff, dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": dense_init(k2, d_ff, d, dtype),
            "b_down": jnp.zeros((d,), dtype)}


def mlp_apply(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "silu":
        g = jax.nn.silu(x @ p["w_gate"])
        return (g * (x @ p["w_up"])) @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"], approximate=True)
    return h @ p["w_down"] + p["b_down"]


def geglu_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Gated GELU (recurrentgemma MLP) — reuses the silu param layout."""
    g = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    return (g * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(rot_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               rotary_frac: float = 1.0) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    rot = int(hd * rotary_frac)
    rot -= rot % 2
    if rot == 0:
        return x
    freqs = rope_frequencies(rot, theta)                       # (rot/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, rot/2)
    sin = jnp.sin(angles)[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]           # rotate-half layout
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x[..., rot:]], axis=-1)


def sinusoidal_positions(max_len: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    out = jnp.zeros((max_len, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang[:, : (d + 1) // 2]))
    return out


# ---------------------------------------------------------------------------
# Cross entropy
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 1e-4) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean token CE in fp32 with optional z-loss. labels == -1 is masked.

    Returns (loss, accuracy)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(
        lf, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse ** 2
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(lf, -1) == labels) * mask).sum() / denom
    return loss, acc
