"""``repro.analysis`` — static analysis & verification passes.

Three passes turn the repo's correctness folklore into enforced
checks, gated in CI via ``python -m repro.analysis --all``:

* ``model`` (:mod:`repro.analysis.model_check`) — explicit-state model
  checker: drives every registered protocol's plugin hooks over
  exhaustive interleavings of tiny configurations and enforces the
  protocol's declared :class:`~repro.core.protocols.base.Contract`
  (mutual exclusion, no lost wakeups, polling-/retry-freedom, queue
  conservation, watchdog-recovery soundness).
* ``trace`` (:mod:`repro.analysis.trace_safety`) — jaxpr auditor: the
  engine must trace to ONE scan with exactly the budgeted carries
  (optional features statically elided when off), bounded scatter
  counts in the hot body, and backend-parity of the output structure.
* ``range`` (:mod:`repro.analysis.int_range`) — integer-range proofs:
  the fused arbitration key's int32 guard is sound and tight (the PR 3
  wrap, locked as a theorem), backoff arithmetic is bounded, and the
  certification envelope matches the engine's validation bounds.

Programmatic entry points::

    from repro.analysis import run_passes
    reports = run_passes(["model", "trace", "range"])
    ok = all(r.ok for r in reports)
"""
from __future__ import annotations

from typing import List, Optional

from repro.analysis import int_range, model_check, trace_safety
from repro.analysis.report import (Finding, PassReport, all_findings,
                                   summarize)

PASSES = ("model", "trace", "range")


def run_passes(passes: Optional[List[str]] = None, quick: bool = False,
               protocols: Optional[List[str]] = None
               ) -> List[PassReport]:
    """Run the selected passes (default: all three) and return their
    reports; a report with findings means the gate fails."""
    sel = list(passes) if passes else list(PASSES)
    unknown = [p for p in sel if p not in PASSES]
    if unknown:
        raise ValueError(f"unknown pass(es) {unknown}; available: "
                         f"{', '.join(PASSES)}")
    reports: List[PassReport] = []
    if "model" in sel:
        reports += model_check.check_all(quick=quick, protocols=protocols)
    if "trace" in sel:
        reports += trace_safety.check_all(quick=quick, protocols=protocols)
    if "range" in sel:
        reports += int_range.check_all(quick=quick)
    return reports


__all__ = ["Finding", "PassReport", "PASSES", "run_passes",
           "all_findings", "summarize", "model_check", "trace_safety",
           "int_range"]
