"""CLI: ``python -m repro.analysis`` — run the static-analysis gate.

Examples::

    python -m repro.analysis --all            # every pass, full scope
    python -m repro.analysis model --quick    # fast model-check subset
    python -m repro.analysis model trace --protocol lrscwait
    python -m repro.analysis --all --json report.json

Exit status 0 = all checks green; 1 = findings (the CI gate).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.analysis import PASSES, run_passes
from repro.analysis.report import all_findings, fail_fast, summarize


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="protocol model checker, trace-safety auditor and "
                    "integer-range analyzer")
    ap.add_argument("passes", nargs="*", choices=[*PASSES, []],
                    help=f"passes to run ({', '.join(PASSES)}); "
                         f"default with --all: every pass")
    ap.add_argument("--all", action="store_true",
                    help="run every pass")
    ap.add_argument("--protocol", action="append", default=None,
                    metavar="NAME",
                    help="restrict model/trace passes to this protocol "
                         "(repeatable)")
    ap.add_argument("--quick", action="store_true",
                    help="small-scope subset (CI smoke / unit tests)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the full report as JSON")
    args = ap.parse_args(argv)
    sel = list(args.passes) or None
    if args.all or sel is None:
        sel = list(PASSES)

    t0 = time.perf_counter()
    reports = run_passes(sel, quick=args.quick, protocols=args.protocol)
    wall = time.perf_counter() - t0
    findings = all_findings(reports)

    print(f"repro.analysis: {', '.join(sel)}"
          + (" (quick)" if args.quick else ""))
    print(summarize(reports))
    states = sum(r.stats.get("states", 0) for r in reports)
    if states:
        print(f"  model: {states} states explored, "
              f"{sum(r.stats.get('transitions', 0) for r in reports)} "
              f"transitions")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"passes": sel, "quick": args.quick,
                       "wall_s": round(wall, 3),
                       "ok": not findings,
                       "reports": [r.to_dict() for r in reports]},
                      fh, indent=2)
        print(f"  report written to {args.json}")
    if findings:
        print(f"FAILED: {len(findings)} finding(s) in {wall:.1f}s")
        print(fail_fast(reports, limit=25))
        return 1
    print(f"OK: {len(reports)} reports, 0 findings in {wall:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
