"""Integer-range analyzer: symbolic bounds for the engine's int32
arithmetic over the certified Spec envelope (``sync.spec.
ANALYSIS_BOUNDS``).

The engine runs entirely in int32.  Two pieces of arithmetic can
plausibly wrap and each has a static guard; this pass turns "we believe
the guard is right" into a checked theorem:

* **Fused arbitration key** — the FIFO arbiter encodes (arrival cycle,
  rotation) as one key ``arr_cyc * (n + 1) + rot`` so a single
  segment-min picks each bank's winner.  The seed engine assumed the
  key always fit int32 — false at n=1024 past ~2M cycles (the PR 3
  wrap) — so ``sim.fused_key_fits_int32(cycles, n)`` now routes long
  horizons to the two-stage lexicographic arbiter.  This pass proves
  the guard **sound** (guard true ⇒ the interval of every reachable
  key stays below ``int32.max``) and **tight** (one more cycle than
  :func:`max_safe_cycles` overflows, so the fused fast path is never
  given up early) across the envelope's core counts.

* **Backoff timer** — ``(backoff << min(streak, exp_cap) - 1) + jitter``
  with ``jitter < 32``; bounded over the envelope
  (``backoff <= 2**20``, ``backoff_exp <= 8``) it stays far below
  ``int32.max``.

Rules: ``key-overflow`` (unsound guard), ``guard-not-tight`` (fused
path given up while provably safe, or taken when unsafe at the exact
threshold), ``backoff-overflow``, ``envelope`` (``ANALYSIS_BOUNDS``
drifted from the engine's own validation bounds).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

from repro.analysis.report import Finding, PassReport
from repro.core import sim
from repro.sync.spec import ANALYSIS_BOUNDS

INT32_MAX = 2**31 - 1


@dataclasses.dataclass(frozen=True)
class Interval:
    """Inclusive integer interval with conservative arithmetic (exact
    for the monotone non-negative operations the engine uses)."""
    lo: int
    hi: int

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    def __add__(self, o: "Interval") -> "Interval":
        o = _as_iv(o)
        return Interval(self.lo + o.lo, self.hi + o.hi)

    def __mul__(self, o: "Interval") -> "Interval":
        o = _as_iv(o)
        corners = [self.lo * o.lo, self.lo * o.hi,
                   self.hi * o.lo, self.hi * o.hi]
        return Interval(min(corners), max(corners))

    def shl(self, o: "Interval") -> "Interval":
        o = _as_iv(o)
        if self.lo < 0 or o.lo < 0:
            raise ValueError("shift bounds require non-negative operands")
        return Interval(self.lo << o.lo, self.hi << o.hi)

    def fits_int32(self) -> bool:
        return -(2**31) <= self.lo and self.hi <= INT32_MAX


def _as_iv(x) -> Interval:
    return x if isinstance(x, Interval) else Interval(int(x), int(x))


# ---- fused arbitration key ----------------------------------------------
def fused_key_interval(n: int, cycles: int) -> Interval:
    """Range of ``arr_cyc * (n + 1) + rot`` over one run: the engine
    stamps ``arr_cyc`` in ``[0, cycles - 1]`` and the rotation satisfies
    ``rot <= n`` (``rot = (core + shift) % n`` plus the ``n`` sentinel
    for no-winner lanes)."""
    return (Interval(0, cycles - 1) * Interval(n + 1, n + 1)
            + Interval(0, n))


def max_safe_cycles(n: int) -> int:
    """The exact largest horizon whose fused keys provably stay BELOW
    the engine's int32 no-winner sentinel for ``n`` cores (one cycle of
    headroom above the raw interval, so real keys always lose a min
    against the sentinel) — the regression lock for the PR 3 wrap: for
    n=1024 this is 2_095_104, i.e. the seed engine's silent wrap at
    "~2M cycles"."""
    return (INT32_MAX - n) // (n + 1)


#: core counts checked explicitly: envelope corners, powers of two
#: around the paper's scales, and the PR 3 bug's n=1024
_N_SAMPLES = (1, 2, 3, 7, 64, 256, 1023, 1024, 1025, 4096, 16_384)


def check_fused_key() -> PassReport:
    rep = PassReport(pass_name="range", subject="fused-arbitration-key")
    t0 = time.perf_counter()
    n_lo, n_hi = ANALYSIS_BOUNDS["n_cores"]
    cy_lo, cy_hi = ANALYSIS_BOUNDS["cycles"]
    thresholds = {}
    for n in _N_SAMPLES:
        if not (n_lo <= n <= n_hi):
            continue
        t = max_safe_cycles(n)
        thresholds[n] = t
        # soundness: every horizon the guard admits keeps the whole key
        # interval inside int32
        for cycles in (cy_lo, min(t, cy_hi)):
            if sim.fused_key_fits_int32(cycles, n) \
                    and not fused_key_interval(n, cycles).fits_int32():
                rep.findings.append(Finding(
                    "range", "key-overflow", "fused-arbitration-key",
                    f"guard admits n={n} cycles={cycles} but the key "
                    f"interval {fused_key_interval(n, cycles)} leaves "
                    f"int32"))
        # tightness, both ways: the guard must accept the exact
        # threshold (no premature fallback to the two-stage arbiter)
        # and reject one past it (no wrap on the fast path)
        if t <= cy_hi and not sim.fused_key_fits_int32(t, n):
            rep.findings.append(Finding(
                "range", "guard-not-tight", "fused-arbitration-key",
                f"guard rejects n={n} cycles={t} although the key "
                f"interval {fused_key_interval(n, t)} provably fits"))
        if t + 1 <= cy_hi and sim.fused_key_fits_int32(t + 1, n):
            rep.findings.append(Finding(
                "range", "key-overflow", "fused-arbitration-key",
                f"guard admits n={n} cycles={t + 1}, one past the "
                f"provable threshold {t} — the PR 3 wrap"))
    rep.stats["thresholds"] = thresholds
    rep.stats["n1024_threshold"] = max_safe_cycles(1024)
    rep.wall_s = time.perf_counter() - t0
    return rep


# ---- backoff timer ------------------------------------------------------
def backoff_interval(backoff_hi: int, backoff_exp_hi: int) -> Interval:
    """Range of ``(backoff << max(streak - 1, 0)) + jitter`` with
    ``streak <= exp_cap <= backoff_exp`` and ``jitter = hash % 32``."""
    shift = Interval(0, max(backoff_exp_hi - 1, 0))
    return Interval(0, backoff_hi).shl(shift) + Interval(0, 31)


def check_backoff() -> PassReport:
    rep = PassReport(pass_name="range", subject="backoff-timer")
    t0 = time.perf_counter()
    bo_hi = ANALYSIS_BOUNDS["backoff"][1]
    be_hi = ANALYSIS_BOUNDS["backoff_exp"][1]
    iv = backoff_interval(bo_hi, be_hi)
    rep.stats["interval"] = (iv.lo, iv.hi)
    if not iv.fits_int32():
        rep.findings.append(Finding(
            "range", "backoff-overflow", "backoff-timer",
            f"backoff timer interval {iv} leaves int32 inside the "
            f"envelope (backoff<={bo_hi}, backoff_exp<={be_hi})"))
    rep.wall_s = time.perf_counter() - t0
    return rep


# ---- envelope consistency ----------------------------------------------
def check_envelope() -> PassReport:
    """``ANALYSIS_BOUNDS`` must name real ``SimParams`` fields and its
    lower bounds must match the engine's own validation floor — the
    certificate is meaningless if it covers Specs the engine rejects
    (or misses values it accepts)."""
    rep = PassReport(pass_name="range", subject="analysis-envelope")
    t0 = time.perf_counter()
    fields = {f.name for f in dataclasses.fields(sim.SimParams)}
    engine_lo = dict(sim.SimParams._BOUNDS)
    for name, (lo, hi) in ANALYSIS_BOUNDS.items():
        if name not in fields:
            rep.findings.append(Finding(
                "range", "envelope", "analysis-envelope",
                f"{name!r} is not a SimParams field"))
            continue
        if lo > hi:
            rep.findings.append(Finding(
                "range", "envelope", "analysis-envelope",
                f"{name}: empty envelope [{lo}, {hi}]"))
        if name in engine_lo and lo < engine_lo[name]:
            rep.findings.append(Finding(
                "range", "envelope", "analysis-envelope",
                f"{name}: envelope floor {lo} is below the engine's "
                f"validation floor {engine_lo[name]} — certifying "
                f"values the engine rejects"))
    missing = [f for f, _ in sim.SimParams._BOUNDS
               if f not in ANALYSIS_BOUNDS]
    if missing:
        rep.findings.append(Finding(
            "range", "envelope", "analysis-envelope",
            f"engine-validated fields {missing} have no certification "
            f"envelope entry"))
    rep.wall_s = time.perf_counter() - t0
    return rep


def check_all(quick: bool = False) -> List[PassReport]:
    del quick                        # the range pass is always cheap
    return [check_fused_key(), check_backoff(), check_envelope()]
