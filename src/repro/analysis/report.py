"""Typed findings shared by the three analysis passes.

A :class:`Finding` is one violated obligation — a protocol contract the
model checker refuted, a scan carry the trace auditor did not expect, a
value range the integer analyzer could not prove safe.  Passes return
``(findings, stats)``; the CLI (``python -m repro.analysis``) renders
them and exits non-zero on any finding, which is what makes the CI step
a gate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated obligation, uniquely identified by (pass, rule,
    subject): ``where`` pins the config/state that witnessed it and
    ``detail`` is the human-readable evidence."""
    pass_name: str            # "model" | "trace" | "range"
    rule: str                 # e.g. "lost-wakeup", "carry-count"
    subject: str              # protocol name / params description
    detail: str
    where: str = ""           # config / witness description

    def render(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return (f"{self.pass_name}:{self.rule} {self.subject}{loc}: "
                f"{self.detail}")


@dataclasses.dataclass
class PassReport:
    """One pass over one subject (protocol or params grid)."""
    pass_name: str
    subject: str
    findings: List[Finding] = dataclasses.field(default_factory=list)
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)
    wall_s: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> Dict[str, Any]:
        return {
            "pass": self.pass_name,
            "subject": self.subject,
            "ok": self.ok,
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "stats": self.stats,
            "wall_s": round(self.wall_s, 3),
        }


def summarize(reports: List[PassReport]) -> str:
    """Fixed-width console summary: one row per (pass, subject)."""
    lines = []
    width = max([len(r.subject) for r in reports] + [8])
    for r in reports:
        verdict = "ok" if r.ok else f"{len(r.findings)} finding(s)"
        extra = ""
        if "states" in r.stats:
            extra = (f"  states={r.stats['states']:>6}"
                     f" transitions={r.stats.get('transitions', 0):>7}")
        lines.append(f"  {r.pass_name:<6} {r.subject:<{width}} "
                     f"{verdict:<14} {r.wall_s:7.2f}s{extra}")
    return "\n".join(lines)


def all_findings(reports: List[PassReport]) -> List[Finding]:
    return [f for r in reports for f in r.findings]


def fail_fast(reports: List[PassReport],
              limit: Optional[int] = None) -> str:
    """Render findings (up to ``limit``) for console output."""
    fs = all_findings(reports)
    shown = fs if limit is None else fs[:limit]
    body = "\n".join("  - " + f.render() for f in shown)
    if limit is not None and len(fs) > limit:
        body += f"\n  ... and {len(fs) - limit} more"
    return body
