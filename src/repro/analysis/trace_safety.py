"""Trace-safety auditor: jaxpr-level lint of the engine's single scan.

The engine's performance story rests on *static elision*: optional
features (windowed telemetry, fault injection, the event trace) are
Python-gated so that switched off they change NOTHING about the traced
computation — same single ``lax.scan``, same carry count, same outputs
(the PR 4 "carry cliff" lesson: one stray carry is a compile cliff).
Until now that discipline lived in hand-rolled jaxpr assertions inside
``tests/test_telemetry.py`` / ``tests/test_faults.py``.  This module is
the single implementation those tests (and the CI gate) call:

* ``scan_carry_count(p)`` — the actual ``num_carry`` of the engine's
  hot scan for ``SimParams`` ``p`` (asserting there IS exactly one);
* ``expected_scan_carries(p)`` — the budgeted count: the frozen
  27-entry engine carry contract (:data:`ENGINE_CARRY_KEYS`) + the
  protocol's bank/core state leaves + the feature deltas (+1 telemetry,
  +3 faults, +2 holder-kill mode, +3 watchdog, +1 hierarchical
  topology);
* ``scatter_count(p)`` — scatter-family ops inside the scan body,
  checked against each protocol's ``contract.max_hot_scatters`` budget
  (a regression reintroducing n-lane scatters into the hot path fails
  the audit, not a benchmark);
* ``audit_protocol(name)`` — the full rule set over one protocol's
  reference configs, including backend parity of the jaxpr-visible
  output structure between ``xla_cpu`` and ``pallas_interpret``.

Rules: ``single-scan``, ``carry-count``, ``ys-count``,
``scatter-budget``, ``backend-parity``, ``static-knob``.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.analysis.report import Finding, PassReport
from repro.core import sim
from repro.core import sweep
from repro.core.protocols import registry as proto_registry
from repro.core.topologies import registry as topo_registry
from repro.faults import FaultPlan

#: The engine's fixed carry contract: the top-level keys of the scan
#: state dict in ``core.sim.simulate`` that exist on EVERY config,
#: before protocol state and feature deltas.  Frozen here so a carry
#: regression is a named diff, not a bare count mismatch.
ENGINE_CARRY_KEYS: Tuple[str, ...] = (
    "st", "tmr", "addr", "phase", "pc", "bar_cnt", "nxt", "arr_cyc",
    "parked", "resp_prev", "opc", "streak", "ops", "acq_start",
    "msgs", "polls", "addr_ops", "sleep_cyc", "bar_cyc", "lat_hist",
    "lat_max", "backoff_cyc", "active_cyc", "bank_ops", "net_stall",
    "w_tmr", "w_served")

#: feature deltas (leaves added to the scan carry when the knob is on)
TELEMETRY_CARRIES = 1            # tele accumulator
FAULTS_CARRIES = 3               # faults_injected, halt_cyc, last_ret
HOLDER_KILL_CARRIES = 2          # kmask, kleft
WATCHDOG_CARRIES = 3             # wd_srv, wd_own, recoveries
TOPO_CARRIES = 1                 # hops counter (hierarchical topologies)

#: ys stacked per cycle when record_trace is on (step/wait/state/qlen)
TRACE_YS = 4

#: SimParams fields that change the traced computation (shapes, carry
#: structure, or the scan body itself) and therefore MUST be static
#: sweep axes — ``core.sweep`` re-traces per combination of these.
CARRY_AFFECTING_FIELDS: Tuple[str, ...] = (
    "protocol", "workload", "n_cores", "cycles", "q_slots", "n_groups",
    "record_trace", "unroll", "backend", "telemetry_windows", "faults",
    "topology", "clusters")


# ---- jaxpr plumbing -----------------------------------------------------
def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for x in vs:
            if hasattr(x, "jaxpr") and hasattr(x.jaxpr, "eqns"):
                yield x.jaxpr
            elif hasattr(x, "eqns"):
                yield x


def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from _walk_eqns(sub)


def engine_jaxpr(p: sim.SimParams):
    """Top-level jaxpr of one engine run of ``p``."""
    return jax.make_jaxpr(lambda: sim.simulate(p))()


def scan_eqns(p: sim.SimParams) -> List[Any]:
    return [e for e in _walk_eqns(engine_jaxpr(p).jaxpr)
            if e.primitive.name == "scan"]


def scan_carry_count(p: sim.SimParams) -> int:
    """``num_carry`` of the engine's hot scan.  Raises if the engine no
    longer traces to exactly one scan — that is itself the regression
    the auditor exists to catch, so callers treating this as a plain
    counter still fail loudly."""
    eqns = scan_eqns(p)
    if len(eqns) != 1:
        raise AssertionError(
            f"engine traced to {len(eqns)} scans (expected exactly 1) "
            f"for {p.protocol}")
    return int(eqns[0].params["num_carry"])


def expected_scan_carries(p: sim.SimParams) -> int:
    """The carry budget for ``p`` from the frozen engine contract plus
    the protocol's declared state and the feature gates — computed
    WITHOUT tracing the engine, so a drift between this formula and the
    real scan is always a reportable finding."""
    proto = proto_registry.get(p.protocol)
    n, a = p.n_cores, p.n_addrs
    q_cap = proto.q_cap(p, n)
    bank = proto.init_bank_state(p, a, n, q_cap)
    xc = proto.init_core_state(p, n)
    cnt = (len(ENGINE_CARRY_KEYS)
           + len(jax.tree_util.tree_leaves(bank))
           + len(jax.tree_util.tree_leaves(xc)))
    if p.telemetry_windows > 0:
        cnt += TELEMETRY_CARRIES
    if topo_registry.get(p.topology).levels:     # hierarchical: hops carry
        cnt += TOPO_CARRIES
    fp = p.faults
    if fp.enabled:
        cnt += FAULTS_CARRIES
        if fp.n_kill > 0 and fp.kill_holder == 1:
            cnt += HOLDER_KILL_CARRIES
        if fp.watchdog_cyc > 0 and proto.held(bank) is not None:
            cnt += WATCHDOG_CARRIES
    return cnt


_SCATTER_PREFIX = "scatter"


def scatter_count(p: sim.SimParams) -> int:
    """Scatter-family ops inside the hot scan body (recursing into
    nested jaxprs)."""
    eqns = scan_eqns(p)
    if len(eqns) != 1:
        raise AssertionError(f"expected exactly 1 scan, got {len(eqns)}")
    body = eqns[0].params["jaxpr"].jaxpr
    return sum(1 for e in _walk_eqns(body)
               if e.primitive.name.startswith(_SCATTER_PREFIX))


def _out_struct(p: sim.SimParams):
    return jax.eval_shape(lambda: sim.simulate(p))


# ---- the audit ----------------------------------------------------------
def reference_params(name: str, **kw: Any) -> sim.SimParams:
    """The auditor's reference config: small, dense-arbitration, CPU
    backend, all optional features off (overridable via ``kw``)."""
    base = dict(protocol=name, n_cores=16, cycles=400, n_addrs=4,
                backend="xla_cpu")
    base.update(kw)
    return sim.SimParams(**base)


def _variants(name: str) -> List[Tuple[str, sim.SimParams]]:
    return [
        ("base", reference_params(name)),
        ("telemetry", reference_params(name, telemetry_windows=8)),
        ("trace", reference_params(name, record_trace=True)),
        ("kill", reference_params(
            name, faults=FaultPlan(n_kill=1, kill_cyc=100))),
        ("kill+wd", reference_params(
            name, faults=FaultPlan(n_kill=1, kill_cyc=100,
                                   watchdog_cyc=200))),
        ("cluster2", reference_params(name, topology="cluster2",
                                      clusters=2)),
    ]


def audit_protocol(name: str, quick: bool = False,
                   backend_parity: bool = True) -> PassReport:
    """Audit one protocol: carry budget across the feature variants,
    ys count, single-scan shape, scatter budget, backend parity."""
    rep = PassReport(pass_name="trace", subject=name)
    t0 = time.perf_counter()
    proto = proto_registry.get(name)
    variants = _variants(name)[:1 if quick else None]
    carries: Dict[str, int] = {}
    for label, p in variants:
        eqns = scan_eqns(p)
        if len(eqns) != 1:
            rep.findings.append(Finding(
                "trace", "single-scan", name,
                f"{len(eqns)} scan ops traced (hot loop must be ONE "
                f"scan)", where=label))
            continue
        eqn = eqns[0]
        actual = int(eqn.params["num_carry"])
        expect = expected_scan_carries(p)
        carries[label] = actual
        if actual != expect:
            rep.findings.append(Finding(
                "trace", "carry-count", name,
                f"scan carries {actual} != budget {expect} (engine "
                f"contract {len(ENGINE_CARRY_KEYS)} + protocol state "
                f"+ feature deltas) — a stray carry is a compile "
                f"cliff", where=label))
        ys = len(eqn.outvars) - actual
        ys_expect = TRACE_YS if p.record_trace else 0
        if ys != ys_expect:
            rep.findings.append(Finding(
                "trace", "ys-count", name,
                f"scan stacks {ys} per-cycle outputs, expected "
                f"{ys_expect}", where=label))
    # scatter budget on the reference config
    budget = proto.contract.max_hot_scatters
    nsc = scatter_count(reference_params(name))
    rep.stats["hot_scatters"] = nsc
    rep.stats["scatter_budget"] = budget
    rep.stats["carries"] = carries
    if nsc > budget:
        rep.findings.append(Finding(
            "trace", "scatter-budget", name,
            f"{nsc} scatter ops in the hot scan body exceed the "
            f"contract budget of {budget}", where="base"))
    # backend parity: jaxpr-visible output structure must match across
    # the scan oracle and the Pallas kernel path
    if backend_parity and not quick:
        px = reference_params(name, backend="xla_cpu")
        pi = reference_params(name, backend="pallas_interpret")
        sx, si = _out_struct(px), _out_struct(pi)
        if jax.tree_util.tree_structure(sx) != \
                jax.tree_util.tree_structure(si):
            rep.findings.append(Finding(
                "trace", "backend-parity", name,
                "output tree structure differs between xla_cpu and "
                "pallas_interpret", where="base"))
        else:
            bad = [k for k in sx
                   if (sx[k].shape, sx[k].dtype)
                   != (si[k].shape, si[k].dtype)]
            if bad:
                rep.findings.append(Finding(
                    "trace", "backend-parity", name,
                    f"output avals differ across backends for {bad}",
                    where="base"))
    rep.wall_s = time.perf_counter() - t0
    return rep


def audit_static_fields() -> PassReport:
    """Every carry-affecting knob must be a static sweep axis: a knob
    that re-shapes the jaxpr but rides a dynamic sweep axis would
    silently produce wrong (shape-mismatched or retraced-per-point)
    sweeps."""
    rep = PassReport(pass_name="trace", subject="sweep.STATIC_FIELDS")
    t0 = time.perf_counter()
    missing = [f for f in CARRY_AFFECTING_FIELDS
               if f not in sweep.STATIC_FIELDS]
    if missing:
        rep.findings.append(Finding(
            "trace", "static-knob", "sweep.STATIC_FIELDS",
            f"carry-affecting SimParams fields {missing} are not "
            f"declared static sweep axes"))
    rep.stats["static_fields"] = list(sweep.STATIC_FIELDS)
    rep.wall_s = time.perf_counter() - t0
    return rep


def check_all(quick: bool = False,
              protocols: Optional[List[str]] = None) -> List[PassReport]:
    names = protocols or proto_registry.names()
    reps = [audit_protocol(nm, quick=quick) for nm in names]
    reps.append(audit_static_fields())
    return reps
