"""Protocol model checker: exhaustive small-scope exploration of the
plugin hooks against each protocol's declared :class:`Contract`.

The engine (``core.sim``) drives protocol plugins one arbitration
winner per bank per cycle, plus wake-timer fires and (under fault
plans) watchdog timeouts.  This checker re-drives the SAME hook surface
— ``on_access`` + its kernel-fusable twin ``fused_access``, ``on_wake``,
``held``/``on_timeout`` — over **every interleaving** of a tiny
configuration (2-4 cores, 1-2 banks, 1-2 ops per core), with the
engine's timing abstracted away: any pending request may be delivered
next, any pending wake may fire next.  Timing abstraction makes the
explored graph a superset of every real schedule, so a property that
holds here holds for all engine schedules of the small config.

Model per core: ``ACQ`` (acquire in flight) -> ``HOLD`` (granted,
release in flight) -> back to ``ACQ`` (ops left) or ``DONE``; a parked
core is ``SLEEP`` until a wake hands it ownership; the fault pass adds
``DEAD``.  Ghost state the checker tracks independently of the
protocol: per-bank owner, per-core ops-left.  Wake timers are
normalized to pending flags (the model fires a pending wake by setting
its bank's timer to 1 and every other pending bank's to 2, so one
``on_wake`` call fires exactly the chosen bank).

Checked rules (rule ids as reported):

==========================  ============================================
``handler-mismatch``        ``fused_access`` disagrees with ``on_access``
                            (bank state, per-core protocol state, or the
                            outcome code derived from the core writes)
``lane-discipline``         ``on_access`` wrote a non-winner core's state
``double-grant``            grant/wake while the bank has an owner
                            (``exclusive_grant``)
``foreign-release``         a release completed for a non-owner
``phantom-outcome``         no outcome for a delivered winner, or an
                            outcome illegal for the phase
``retry-free``              ``OUT_FAIL`` from a ``retry_free`` protocol
``fail-not-full``           ``OUT_FAIL`` with queue slots free
                            (``fail_requires_full``)
``unexpected-sleep``        ``OUT_SLEEP`` from a non-``wait_class``
                            protocol
``wake-corrupt``            a wake hit a core that was neither sleeping
                            nor the bank's owner
``queue-conservation``      ``queue_depth`` != sleepers (+ holder when
                            ``queue_counts_holder``)
``lost-wakeup``             terminal state with a live core asleep
``deadlock``                terminal state with live undone cores awake
``completion-unreachable``  a reachable state with NO path to all-done
``live-evict``              ``on_timeout`` evicted with every core live
                            (without ``evict_live_safe``)
``recovery-deadlock``       after a holder death, live cores cannot all
                            finish even with the watchdog
==========================  ============================================

The fault pass (``kill=True``) additionally branches a holder death at
every ownership acquisition and enables the watchdog event on held
banks with no live in-flight owner — the small-scope version of the
PR 8 stale-owner scenario.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocols import registry as proto_registry
from repro.core.protocols.base import (MOD, OUT_DONE, OUT_EVICT, OUT_FAIL,
                                       OUT_GRANT, OUT_NONE, OUT_SLEEP,
                                       P_ACQ, P_REL, REQ, RESP, SLEEP, WORK,
                                       NXT_BACKOFF, NXT_MOD, NXT_WORK_DONE,
                                       Ctx, FusedCtx)
from repro.analysis.report import Finding, PassReport

# model core modes
M_ACQ, M_HOLD, M_SLEEP, M_DONE, M_DEAD = 0, 1, 2, 3, 4
_MODE_CH = "AHSDX"

#: exploration safety valve — the tiny configs stay well under this
MAX_STATES = 250_000


@dataclasses.dataclass(frozen=True)
class Config:
    """One small-scope configuration: ``wa`` maps core -> home bank."""
    n: int
    a: int = 1
    ops: int = 2
    q_slots: int = 64
    n_groups: int = 2
    topology: str = "flat"
    clusters: int = 2

    @property
    def wa(self) -> Tuple[int, ...]:
        return tuple(c % self.a for c in range(self.n))

    def label(self) -> str:
        lbl = (f"n={self.n} a={self.a} ops={self.ops} q={self.q_slots}"
               f" g={self.n_groups}")
        if self.topology != "flat":
            lbl += f" topo={self.topology}/{self.clusters}"
        return lbl


class _P:
    """Static parameter namespace handed to the hooks (the model has no
    clock, so the latency knobs only have to be positive; topology-aware
    protocols like ``hw_event`` size their cluster queues from
    ``topology``/``clusters``)."""

    def __init__(self, cfg: Config):
        self.lat = 1
        self.work = 1
        self.modify = 1
        self.q_slots = cfg.q_slots
        self.n_groups = cfg.n_groups
        self.topology = cfg.topology
        self.clusters = cfg.clusters


def configs_for(name: str, quick: bool = False) -> List[Config]:
    """Small-scope grid per protocol.  ``lrscwait`` adds a q=1 config
    (the finite-queue FAIL path); ``colibri_hier`` adds a 4-core
    2-bank 2-group config (cross-bank queue aliasing is invisible with
    a single bank — the PR 6 lesson).  ``hw_event`` runs 2-cluster
    ``cluster2`` configs where every bank is shared across clusters, so
    a cross-cluster wakeup delivered to the wrong cluster queue (or a
    per-cluster queue aliased across banks) reaches a checked state;
    ``nb_feb`` adds the same 2-cluster shape to certify the FEB
    invariant is topology-independent."""
    if name == "colibri_hier":
        cfgs = [Config(n=3, a=1, ops=2, n_groups=2),
                Config(n=4, a=2, ops=1, n_groups=2)]
        return cfgs[:1] if quick else cfgs
    if name == "hw_event":
        # block placement puts cores {0,1} / {2,3} in clusters 0 / 1;
        # with wa = c % a every bank then serves both clusters, so the
        # cross-cluster handoff and the intra-cluster wakeup broadcast
        # both fire, and the a=2 config additionally interleaves two
        # banks' per-cluster queues (the aliasing scope)
        cfgs = [Config(n=3, a=1, ops=2, n_groups=2),
                Config(n=4, a=1, ops=1, topology="cluster2", clusters=2),
                Config(n=4, a=2, ops=1, topology="cluster2", clusters=2)]
        return cfgs[:1] if quick else cfgs
    base = [Config(n=2, a=1, ops=2), Config(n=3, a=1, ops=2),
            Config(n=3, a=2, ops=1)]
    if name == "lrscwait":
        base.insert(1, Config(n=2, a=1, ops=2, q_slots=1))
        return [base[0], base[1]] if quick else base
    if name == "nb_feb":
        base.append(Config(n=4, a=2, ops=1, topology="cluster2",
                           clusters=2))
        return base[:1] if quick else base
    return base[:1] if quick else base


@dataclasses.dataclass
class _State:
    modes: Tuple[int, ...]
    ops: Tuple[int, ...]
    owner: Tuple[int, ...]           # per bank; -1 = none
    bank: Dict[str, np.ndarray]
    xc: Dict[str, np.ndarray]

    def key(self) -> bytes:
        parts = [bytes(self.modes), bytes(o % 256 for o in self.ops),
                 bytes((o + 1) % 256 for o in self.owner)]
        for k in sorted(self.bank):
            parts.append(self.bank[k].tobytes())
        for k in sorted(self.xc):
            parts.append(self.xc[k].tobytes())
        return b"|".join(parts)

    def label(self) -> str:
        return ("cores=" + "".join(_MODE_CH[m] for m in self.modes)
                + " ops=" + "".join(str(o) for o in self.ops)
                + " owner=" + ",".join(str(o) for o in self.owner))


def _normalize(bank: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Wake timers carry delays in the engine; the model only cares
    whether a wake is pending."""
    if "wake_tmr" in bank:
        bank = dict(bank)
        bank["wake_tmr"] = (bank["wake_tmr"] > 0).astype(np.int32)
    return bank


def _get(tree):
    return jax.tree_util.tree_map(np.asarray, jax.device_get(tree))


class _Kernels:
    """Jitted hook drivers for one (protocol, config) pair.  Compiled
    once; every explored transition is then a sub-millisecond call."""

    def __init__(self, proto, cfg: Config):
        self.proto, self.cfg = proto, cfg
        p = _P(cfg)
        n, a = cfg.n, cfg.a
        q_cap = proto.q_cap(p, n)
        self.p, self.q_cap = p, q_cap
        wa = jnp.asarray(cfg.wa, jnp.int32)
        wc = jnp.arange(n, dtype=jnp.int32)
        ba = jnp.arange(a, dtype=jnp.int32)
        self.init_bank = _normalize(_get(proto.init_bank_state(p, a, n,
                                                               q_cap)))
        self.init_xc = _get(proto.init_core_state(p, n))
        xc_keys = tuple(self.init_xc)

        def _cs(st, xc):
            cs = dict(st=st.astype(jnp.int32),
                      tmr=jnp.zeros((n,), jnp.int32),
                      nxt=jnp.full((n,), -1, jnp.int32),
                      polls=jnp.zeros((), jnp.int32),
                      msgs=jnp.zeros((), jnp.int32))
            cs.update(xc)
            return cs

        def _ctx(is_acq, is_rel, win, acq_b, rel_b):
            return Ctx(p=p, n=n, a=a, q_cap=q_cap, is_acq=is_acq,
                       is_rel=is_rel, wa=wa, wc=wc, ba=ba, win_core=win,
                       acq_b=acq_b, rel_b=rel_b,
                       mod_dur=jnp.ones((n,), jnp.int32))

        def deliver(bank, xc, st, c, phase):
            onehot = wc == c
            is_acq = onehot & (phase == P_ACQ)
            is_rel = onehot & (phase == P_REL)
            b = wa[c]
            hit = ba == b
            win = jnp.where(hit, c, n).astype(jnp.int32)
            acq_b = hit & (phase == P_ACQ)
            rel_b = hit & (phase == P_REL)
            cs = _cs(jnp.where(onehot, REQ, st), xc)
            cs2, bank2 = self.proto.on_access(
                _ctx(is_acq, is_rel, win, acq_b, rel_b), dict(cs),
                dict(bank))
            stc, nxtc = cs2["st"][c], cs2["nxt"][c]
            out = jnp.where(
                stc == SLEEP, OUT_SLEEP,
                jnp.where((stc == RESP) & (nxtc == NXT_MOD), OUT_GRANT,
                jnp.where((stc == RESP) & (nxtc == NXT_WORK_DONE), OUT_DONE,
                jnp.where((stc == RESP) & (nxtc == NXT_BACKOFF), OUT_FAIL,
                          OUT_NONE)))).astype(jnp.int32)
            off = ~onehot
            touched = jnp.any(off & (cs2["st"] != cs["st"])) \
                | jnp.any(off & (cs2["nxt"] != -1)) \
                | jnp.any(off & (cs2["tmr"] != 0))
            for k in xc_keys:
                touched = touched | jnp.any(off & (cs2[k] != xc[k]))
            # fused twin on the same pre-state
            fcore = {k: xc[k][jnp.minimum(win, n - 1)]
                     for k in self.proto.fused_core_fields}
            bank3, fo = self.proto.fused_access(
                FusedCtx(p=p, n=n, a=a, q_cap=q_cap, win=win,
                         acq_b=acq_b, rel_b=rel_b, core=fcore),
                dict(bank))
            xc3 = dict(xc)
            for k, (vals, msk) in fo.xset.items():
                xc3[k] = xc3[k].at[jnp.where(msk, win, n)].set(
                    vals, mode="drop")
            agree = jnp.asarray(True)
            for k in bank:
                agree = agree & jnp.all(bank2[k] == bank3[k])
            for k in xc_keys:
                agree = agree & jnp.all(cs2[k] == xc3[k])
            agree = agree & (out == fo.kind[b])
            xc2 = {k: cs2[k] for k in xc_keys}
            return bank2, xc2, out, fo.kind[b], agree, touched

        def wake(bank, xc, st, b):
            pend = bank["wake_tmr"] > 0
            bank_in = dict(bank, wake_tmr=jnp.where(
                ba == b, 1, jnp.where(pend, 2, 0)).astype(jnp.int32))
            z = jnp.zeros((n,), bool)
            zb = jnp.zeros((a,), bool)
            cs = _cs(st, xc)
            cs2, bank2, _ = self.proto.on_wake(
                _ctx(z, z, jnp.full((a,), n, jnp.int32), zb, zb),
                dict(cs), bank_in)
            woken = cs2["st"] == MOD
            return bank2, {k: cs2[k] for k in xc_keys}, woken

        def timeout(bank, xc, st, stuck_b, killed, owner_arr):
            z = jnp.zeros((n,), bool)
            zb = jnp.zeros((a,), bool)
            cs = _cs(st, xc)
            cs2, bank2, kind = self.proto.on_timeout(
                _ctx(z, z, jnp.full((a,), n, jnp.int32), zb, zb),
                dict(cs), dict(bank), stuck_b, killed, owner_arr)
            return bank2, {k: cs2[k] for k in xc_keys}, kind

        self.deliver = jax.jit(deliver)
        self.wake = jax.jit(wake)
        self.timeout = jax.jit(timeout)
        self.has_wake = "wake_tmr" in self.init_bank
        self.has_held = proto.held(
            jax.tree_util.tree_map(jnp.asarray, self.init_bank)) is not None

    def held_np(self, bank) -> np.ndarray:
        h = self.proto.held(jax.tree_util.tree_map(jnp.asarray, bank))
        return np.asarray(_get(h))

    def qdepth_np(self, bank) -> Optional[np.ndarray]:
        qd = self.proto.queue_depth(
            jax.tree_util.tree_map(jnp.asarray, bank))
        return None if qd is None else np.asarray(_get(qd))


def _st_in(modes: Tuple[int, ...]) -> np.ndarray:
    return np.asarray([SLEEP if m == M_SLEEP else WORK for m in modes],
                      np.int32)


class _Explorer:
    """BFS over the interleaving graph of one (protocol, config)."""

    def __init__(self, proto, cfg: Config, kill: bool,
                 kernels: Optional[_Kernels] = None):
        self.proto, self.cfg, self.kill = proto, cfg, kill
        self.kn = kernels or _Kernels(proto, cfg)
        self.contract = proto.contract
        self.findings: Dict[str, Finding] = {}
        self.counts: Dict[str, int] = {}
        self.transitions = 0
        self._probed: set = set()

    # ---- findings --------------------------------------------------------
    def _flag(self, rule: str, detail: str, state: _State) -> None:
        self.counts[rule] = self.counts.get(rule, 0) + 1
        if rule not in self.findings:
            mode = "fault pass" if self.kill else "normal pass"
            self.findings[rule] = Finding(
                pass_name="model", rule=rule, subject=self.proto.name,
                detail=detail,
                where=f"{self.cfg.label()} ({mode}) at {state.label()}")

    # ---- invariants ------------------------------------------------------
    def _check_state(self, s: _State) -> None:
        qd = self.kn.qdepth_np(s.bank)
        if qd is not None:
            for b in range(self.cfg.a):
                exp = sum(1 for c in range(self.cfg.n)
                          if s.modes[c] == M_SLEEP and self.cfg.wa[c] == b)
                if self.contract.queue_counts_holder and s.owner[b] >= 0:
                    exp += 1
                if int(qd[b]) != exp:
                    self._flag("queue-conservation",
                               f"bank {b}: queue_depth={int(qd[b])} but "
                               f"{exp} cores are accounted for (sleepers"
                               + (" + holder" if
                                  self.contract.queue_counts_holder else "")
                               + ")", s)
        # live-owner watchdog probe (non-mutating, deduped by bank state)
        if self.kn.has_held and not self.contract.evict_live_safe:
            bkey = b"".join(s.bank[k].tobytes() for k in sorted(s.bank))
            if bkey not in self._probed:
                self._probed.add(bkey)
                held = self.kn.held_np(s.bank)
                if held.any():
                    owner_arr = np.asarray(
                        [o if o >= 0 else self.cfg.n for o in s.owner],
                        np.int32)
                    _, _, kind = _get(self.kn.timeout(
                        s.bank, s.xc, _st_in(s.modes), jnp.asarray(held),
                        jnp.zeros((self.cfg.n,), bool),
                        jnp.asarray(owner_arr)))
                    if (np.asarray(kind) == OUT_EVICT).any():
                        self._flag(
                            "live-evict",
                            "on_timeout returned OUT_EVICT with every core "
                            "alive — the watchdog would evict a live owner "
                            "(declare evict_live_safe only if that is safe "
                            "by construction, like lrsc slot expiry)", s)

    # ---- transitions -----------------------------------------------------
    def _apply_deliver(self, s: _State, c: int, phase: int
                       ) -> Optional[_State]:
        kn, cfg, ct = self.kn, self.cfg, self.contract
        b = cfg.wa[c]
        bank2, xc2, out, kind, agree, touched = _get(kn.deliver(
            s.bank, s.xc, _st_in(s.modes), jnp.int32(c), jnp.int32(phase)))
        out, kind = int(out), int(kind)
        if not bool(agree):
            self._flag("handler-mismatch",
                       f"core {c} phase {'acq' if phase == P_ACQ else 'rel'}"
                       f": on_access outcome {out} / fused_access kind "
                       f"{kind} or diverging state", s)
        if bool(touched):
            self._flag("lane-discipline",
                       f"on_access for winner {c} wrote another core's "
                       f"state", s)
        modes, ops, owner = list(s.modes), list(s.ops), list(s.owner)
        if out == OUT_NONE:
            self._flag("phantom-outcome",
                       f"delivered winner {c} got no outcome", s)
            return None
        if phase == P_ACQ:
            if out == OUT_GRANT:
                if ct.exclusive_grant and owner[b] >= 0:
                    self._flag("double-grant",
                               f"core {c} granted bank {b} while core "
                               f"{owner[b]} still owns it", s)
                owner[b] = c
                modes[c] = M_HOLD
            elif out == OUT_DONE:       # single-access commit (amo)
                if ct.exclusive_grant and owner[b] >= 0:
                    self._flag("double-grant",
                               f"core {c} committed at bank {b} while core "
                               f"{owner[b]} owns it", s)
                ops[c] -= 1
                modes[c] = M_ACQ if ops[c] > 0 else M_DONE
            elif out == OUT_SLEEP:
                if not ct.wait_class:
                    self._flag("unexpected-sleep",
                               f"non-wait protocol parked core {c}", s)
                modes[c] = M_SLEEP
            elif out == OUT_FAIL:
                if ct.retry_free:
                    self._flag("retry-free",
                               f"retry-free protocol failed core {c}'s "
                               f"acquire (a poll)", s)
                elif ct.fail_requires_full:
                    occupied = sum(
                        1 for k in range(cfg.n)
                        if s.modes[k] == M_SLEEP and cfg.wa[k] == b)
                    if ct.queue_counts_holder and s.owner[b] >= 0:
                        occupied += 1
                    if occupied < kn.q_cap:
                        self._flag(
                            "fail-not-full",
                            f"core {c} rejected at bank {b} with only "
                            f"{occupied}/{kn.q_cap} queue slots used", s)
                # retry: the model redelivers later
            else:
                self._flag("phantom-outcome",
                           f"acquire outcome {out} for core {c}", s)
        else:
            if out == OUT_DONE:
                if ct.exclusive_grant and owner[b] != c:
                    self._flag("foreign-release",
                               f"core {c} completed a release on bank {b} "
                               f"owned by {owner[b]}", s)
                if owner[b] == c:
                    owner[b] = -1
                ops[c] -= 1
                modes[c] = M_ACQ if ops[c] > 0 else M_DONE
            elif out == OUT_FAIL:        # failed SC: full retry
                if ct.retry_free:
                    self._flag("retry-free",
                               f"retry-free protocol failed core {c}'s "
                               f"release", s)
                modes[c] = M_ACQ
            else:
                self._flag("phantom-outcome",
                           f"release outcome {out} for core {c}", s)
        return _State(tuple(modes), tuple(ops), tuple(owner),
                      _normalize(bank2), xc2)

    def _apply_wake(self, s: _State, b: int) -> Optional[_State]:
        cfg, ct = self.cfg, self.contract
        bank2, xc2, woken = _get(self.kn.wake(s.bank, s.xc,
                                              _st_in(s.modes),
                                              jnp.int32(b)))
        woken = np.asarray(woken)
        modes, ops, owner = list(s.modes), list(s.ops), list(s.owner)
        for c in np.nonzero(woken)[0]:
            c = int(c)
            wb = cfg.wa[c]
            if s.modes[c] == M_SLEEP:
                if ct.exclusive_grant and owner[wb] >= 0:
                    self._flag("double-grant",
                               f"wake handed bank {wb} to core {c} while "
                               f"core {owner[wb]} owns it", s)
                owner[wb] = c
                modes[c] = M_HOLD
            elif s.owner[wb] == c:
                pass                     # redelivered wake to the owner
            elif s.modes[c] == M_DEAD:
                owner[wb] = c            # wake reached a dead sleeper
            else:
                self._flag("wake-corrupt",
                           f"wake of bank {b} hit core {c} "
                           f"({_MODE_CH[s.modes[c]]}) which was neither "
                           f"asleep nor bank {wb}'s owner", s)
        return _State(tuple(modes), tuple(ops), tuple(owner),
                      _normalize(bank2), xc2)

    def _apply_watchdog(self, s: _State, b: int) -> Optional[_State]:
        cfg = self.cfg
        killed = np.asarray([m == M_DEAD for m in s.modes], bool)
        owner_arr = np.asarray([o if o >= 0 else cfg.n for o in s.owner],
                               np.int32)
        stuck = np.zeros((cfg.a,), bool)
        stuck[b] = True
        bank2, xc2, kind = _get(self.kn.timeout(
            s.bank, s.xc, _st_in(s.modes), jnp.asarray(stuck),
            jnp.asarray(killed), jnp.asarray(owner_arr)))
        kind = np.asarray(kind)
        modes, ops, owner = list(s.modes), list(s.ops), list(s.owner)
        if int(kind[b]) == OUT_EVICT:
            # for evict_live_safe protocols (lrsc slot expiry) the ghost
            # owner is the last grantee, not the resource holder, so the
            # live-owner attribution below would be unsound
            if (not self.contract.evict_live_safe
                    and owner[b] >= 0 and s.modes[owner[b]] != M_DEAD):
                self._flag("live-evict",
                           f"watchdog evicted bank {b}'s live owner "
                           f"{owner[b]}", s)
            owner[b] = -1
        return _State(tuple(modes), tuple(ops), tuple(owner),
                      _normalize(bank2), xc2)

    # ---- events ----------------------------------------------------------
    def _events(self, s: _State) -> List[Tuple]:
        evs: List[Tuple] = []
        for c in range(self.cfg.n):
            if s.modes[c] == M_ACQ:
                evs.append(("deliver", c, P_ACQ))
            elif s.modes[c] == M_HOLD:
                evs.append(("deliver", c, P_REL))
        if self.kn.has_wake:
            for b in np.nonzero(s.bank["wake_tmr"] > 0)[0]:
                evs.append(("wake", int(b)))
        if self.kill:
            died = any(m == M_DEAD for m in s.modes)
            if not died:
                for c in range(self.cfg.n):
                    if s.modes[c] == M_HOLD:
                        evs.append(("die", c))
            elif self.kn.has_held:
                held = self.kn.held_np(s.bank)
                for b in range(self.cfg.a):
                    if not held[b]:
                        continue
                    live_inflight = any(
                        s.modes[c] == M_HOLD and self.cfg.wa[c] == b
                        for c in range(self.cfg.n))
                    if not live_inflight:
                        evs.append(("watchdog", b))
        return evs

    def _apply(self, s: _State, ev: Tuple) -> Optional[_State]:
        if ev[0] == "deliver":
            return self._apply_deliver(s, ev[1], ev[2])
        if ev[0] == "wake":
            return self._apply_wake(s, ev[1])
        if ev[0] == "die":
            modes = list(s.modes)
            modes[ev[1]] = M_DEAD
            return _State(tuple(modes), s.ops, s.owner, s.bank, s.xc)
        return self._apply_watchdog(s, ev[1])

    # ---- main loop -------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        init = _State(tuple([M_ACQ] * self.cfg.n),
                      tuple([self.cfg.ops] * self.cfg.n),
                      tuple([-1] * self.cfg.a),
                      dict(self.kn.init_bank), dict(self.kn.init_xc))
        seen: Dict[bytes, _State] = {init.key(): init}
        succs: Dict[bytes, List[bytes]] = {}
        frontier = deque([init.key()])
        self._check_state(init)
        while frontier and not self.findings:
            k = frontier.popleft()
            s = seen[k]
            out: List[bytes] = []
            for ev in self._events(s):
                self.transitions += 1
                s2 = self._apply(s, ev)
                if s2 is None:
                    continue
                k2 = s2.key()
                if k2 == k:
                    continue
                out.append(k2)
                if k2 not in seen:
                    if len(seen) >= MAX_STATES:
                        raise RuntimeError(
                            f"{self.proto.name}/{self.cfg.label()}: state "
                            f"space exceeded {MAX_STATES}")
                    seen[k2] = s2
                    self._check_state(s2)
                    frontier.append(k2)
            succs[k] = out
            if not out and not self._all_done(s):
                asleep = [c for c in range(self.cfg.n)
                          if s.modes[c] == M_SLEEP]
                rule = ("recovery-deadlock" if self.kill and
                        any(m == M_DEAD for m in s.modes)
                        else "lost-wakeup" if asleep else "deadlock")
                self._flag(rule,
                           "terminal state with live unfinished cores"
                           + (f" (cores {asleep} asleep, no wake pending)"
                              if asleep else ""), s)
        if not self.findings:
            self._reverse_check(seen, succs)
        return dict(states=len(seen), transitions=self.transitions,
                    findings=list(self.findings.values()),
                    counts=dict(self.counts))

    def _all_done(self, s: _State) -> bool:
        return all(m in (M_DONE, M_DEAD) for m in s.modes)

    def _reverse_check(self, seen, succs) -> None:
        """Every reachable state must have SOME path on which all live
        cores finish — the liveness half of no-lost-wakeup / recovery."""
        rev: Dict[bytes, List[bytes]] = {k: [] for k in seen}
        for k, outs in succs.items():
            for k2 in outs:
                rev[k2].append(k)
        good = deque(k for k, s in seen.items() if self._all_done(s))
        ok = set(good)
        while good:
            for pk in rev[good.popleft()]:
                if pk not in ok:
                    ok.add(pk)
                    good.append(pk)
        bad = [k for k in seen if k not in ok]
        if bad:
            rule = "recovery-deadlock" if self.kill \
                else "completion-unreachable"
            self._flag(rule,
                       f"{len(bad)} of {len(seen)} reachable states have "
                       f"no path to completion", seen[bad[0]])


def check_protocol(proto, quick: bool = False, kill: bool = True,
                   configs: Optional[List[Config]] = None) -> PassReport:
    """Model-check one protocol (a registered name or a ``Protocol``
    instance) over its small-scope configs; the fault pass runs too
    unless ``kill=False`` or the protocol has no held state."""
    if isinstance(proto, str):
        proto = proto_registry.get(proto)
    rep = PassReport(pass_name="model", subject=proto.name)
    t0 = time.perf_counter()
    states = transitions = 0
    counts: Dict[str, int] = {}
    for cfg in (configs if configs is not None
                else configs_for(proto.name, quick)):
        kn = _Kernels(proto, cfg)
        passes = [False] + ([True] if kill and kn.has_held else [])
        for kmode in passes:
            r = _Explorer(proto, cfg, kmode, kernels=kn).run()
            states += r["states"]
            transitions += r["transitions"]
            rep.findings.extend(r["findings"])
            for rule, cnt in r["counts"].items():
                counts[rule] = counts.get(rule, 0) + cnt
    rep.stats = dict(states=states, transitions=transitions,
                     violation_counts=counts)
    rep.wall_s = time.perf_counter() - t0
    return rep


def check_all(quick: bool = False, kill: bool = True,
              protocols: Optional[List[str]] = None) -> List[PassReport]:
    names = protocols or proto_registry.names()
    return [check_protocol(nm, quick=quick, kill=kill) for nm in names]
