"""``Result`` — the typed view of one simulation point.

Replaces the raw ``Dict[str, np.ndarray]`` the engine returns: the
paper's metric triple and the latency percentiles are named accessors,
every raw counter stays reachable under :attr:`Result.stats` (and via
``result["key"]`` for incremental porting), and the benchmark-row /
JSON serialization that used to be copy-pasted across 11 benchmark
modules lives here once (:meth:`to_row` / :meth:`to_json`).

A ``Result`` always carries the :class:`~repro.sync.Spec` that produced
it, so streamed points (``Study.stream()`` yields results in
chunk-completion order, not input order) identify themselves.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, Iterator, Mapping, Optional

import numpy as np

from repro.core import metrics as _metrics
from repro.core import workloads as _workloads
from repro.sync.spec import Spec

#: scalar metrics serialized by ``to_json`` and carried by every row
_METRIC_KEYS = ("throughput", "jain_fairness", "energy_pj_per_op",
                "lat_p50", "lat_p95", "lat_max",
                "fairness_min", "fairness_max", "fairness_span")

#: fault/recovery metrics (repro.faults) — present only when the spec
#: ran with an enabled FaultPlan, so fault-free reports stay unchanged
_FAULT_KEYS = ("faults_injected", "recoveries", "stalled_cores",
               "progress_ok", "halt_cyc",
               "survivor_throughput", "survivor_jain")


def _scalar(v: Any) -> Any:
    """Plain-Python, JSON-safe scalar: numpy scalars unwrap, non-finite
    floats map to ``None`` (the starved-core ``fairness_span``)."""
    if isinstance(v, (np.generic, np.ndarray)):
        v = v.item()
    if isinstance(v, float) and not math.isfinite(v):
        return None
    return v


@dataclasses.dataclass(frozen=True, eq=False)
class Result:
    """One simulation point: the producing :class:`Spec` plus the raw
    metric-annotated engine result dict under :attr:`stats`."""
    spec: Spec
    stats: Mapping[str, Any] = dataclasses.field(repr=False)

    # ---- the paper's metric triple --------------------------------------
    @property
    def throughput(self) -> float:
        """Completed ops per cycle (enq+deq pairs for ``ms_queue``, ...)."""
        return float(self.stats["throughput"])

    @property
    def jain_fairness(self) -> float:
        """Jain's index over per-core completed ops (1.0 = uniform)."""
        return float(self.stats["jain_fairness"])

    @property
    def energy_pj_per_op(self) -> float:
        """pJ per completed op (Table II-calibrated event-energy model)."""
        return float(self.stats["energy_pj_per_op"])

    # ---- latency percentiles --------------------------------------------
    @property
    def lat_p50(self) -> float:
        return float(self.stats["lat_p50"])

    @property
    def lat_p95(self) -> float:
        return float(self.stats["lat_p95"])

    @property
    def lat_max(self) -> float:
        return float(self.stats["lat_max"])

    # ---- fairness family ------------------------------------------------
    @property
    def fairness_min(self) -> float:
        """Slowest core's ops/cycle."""
        return float(self.stats["fairness_min"])

    @property
    def fairness_max(self) -> float:
        """Fastest core's ops/cycle."""
        return float(self.stats["fairness_max"])

    @property
    def fairness_span(self) -> float:
        """Fastest/slowest ratio; ``inf`` once a core starves."""
        return float(self.stats["fairness_span"])

    # ---- counters -------------------------------------------------------
    @property
    def polls(self) -> int:
        """Failed attempts (retries) — 0 for polling-free protocols."""
        return int(np.asarray(self.stats["polls"]))

    @property
    def msgs(self) -> int:
        return int(np.asarray(self.stats["msgs"]))

    @property
    def ops_total(self) -> int:
        """Completed ops summed over cores (workers excluded by slice)."""
        return int(np.asarray(self.stats["ops"]).sum())

    @property
    def atomics_total(self) -> int:
        """Completed atomic accesses (micro-ops), summed over cores."""
        return int(np.asarray(self.stats["opc"]).sum())

    @property
    def atomics_per_cycle(self) -> float:
        return self.atomics_total / self.spec.costs.cycles

    @property
    def worker_rate(self) -> Optional[float]:
        """Fig. 5 streaming-worker service rate, or ``None`` when the
        spec has no workers."""
        v = self.stats.get("worker_rate")
        return None if v is None else float(v)

    # ---- fault tolerance (repro.faults) ---------------------------------
    @property
    def ok(self) -> bool:
        """``False`` when this point is a sweep-isolation error record
        (its chunk raised and the bisected retry failed too)."""
        return "error" not in self.stats

    @property
    def error(self) -> Optional[str]:
        """The isolated failure (``"ExcType: message"``) or ``None``."""
        v = self.stats.get("error")
        return None if v is None else str(v)

    @property
    def progress_ok(self) -> Optional[bool]:
        """Liveness verdict under fault injection: ``True`` if the
        forward-progress watchdog never flagged a halt, ``False`` for a
        detected livelock/deadlock, ``None`` when the spec ran without
        faults enabled."""
        v = self.stats.get("progress_ok")
        return None if v is None else bool(v)

    @property
    def faults_injected(self) -> int:
        return int(np.asarray(self.stats.get("faults_injected", 0)))

    @property
    def recoveries(self) -> int:
        """Watchdog-driven recovery actions (evictions + redeliveries)."""
        return int(np.asarray(self.stats.get("recoveries", 0)))

    # ---- observability views (repro.obs) --------------------------------
    def timeseries(self):
        """The windowed telemetry of this point as a typed
        :class:`repro.obs.Timeseries` (per-window core-state counts,
        outcome rates, queue depths, NoC traffic).  Requires the spec to
        have run with ``telemetry_windows > 0``; raises ``ValueError``
        otherwise."""
        from repro.obs.timeseries import Timeseries
        return Timeseries.from_result(self)

    def events(self):
        """The event traces of this point as a typed
        :class:`repro.obs.EventLog` (per-core state spans, retirement
        completions, per-bank queue-depth trace) — the input of
        ``repro.obs.perfetto.export``.  Requires ``record_trace=True``;
        raises ``ValueError`` otherwise."""
        from repro.obs.events import EventLog
        return EventLog.from_result(self)

    # ---- raw access (porting aid) ---------------------------------------
    def __getitem__(self, key: str) -> Any:
        return self.stats[key]

    def __contains__(self, key: str) -> bool:
        return key in self.stats

    def get(self, key: str, default: Any = None) -> Any:
        return self.stats.get(key, default)

    def keys(self) -> Iterator[str]:
        return self.stats.keys()

    # ---- serialization --------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        """The named scalar metrics as a plain JSON-safe dict."""
        out: Dict[str, Any] = {k: _scalar(self.stats[k])
                               for k in _METRIC_KEYS if k in self.stats}
        if "polls" in self.stats:
            out["polls"] = self.polls
        if "msgs" in self.stats:
            out["msgs"] = self.msgs
        if "ops" in self.stats:
            out["ops"] = self.ops_total
        if "opc" in self.stats:                  # raw engine result
            out["atomics"] = self.atomics_total
        elif "atomics" in self.stats:            # from_json round trip
            out["atomics"] = int(self.stats["atomics"])
        if self.worker_rate is not None:
            out["worker_rate"] = self.worker_rate
        for k in _FAULT_KEYS:
            if k in self.stats:
                out[k] = _scalar(self.stats[k])
        if "error" in self.stats:
            out["error"] = str(self.stats["error"])
            if "error_stage" in self.stats:
                out["error_stage"] = str(self.stats["error_stage"])
        return out

    def to_row(self, **extra: Any) -> Dict[str, Any]:
        """One flat JSON-safe benchmark-report row: spec identifiers +
        the full metric set, with ``extra`` entries overriding/extending
        (figure name, axis labels, derived ratios...).  Non-finite
        floats become ``None`` (strict-JSON reports)."""
        row: Dict[str, Any] = {
            "protocol": self.spec.protocol.name,
            "workload": self.spec.workload.name,
            "topology": self.spec.topology.name,
            "cores": self.spec.topology.n_cores,
        }
        row.update(self.metrics())
        row.update(extra)
        return {k: _scalar(v) for k, v in row.items()}

    def to_json(self, **dumps_kw: Any) -> str:
        """Spec + named metrics as JSON; :meth:`from_json` restores a
        metrics-only ``Result`` (raw per-core arrays are not shipped)."""
        return json.dumps({"spec": self.spec.to_dict(),
                           "metrics": self.metrics()}, **dumps_kw)

    @classmethod
    def from_json(cls, s: str) -> "Result":
        d = json.loads(s)
        stats = {}
        for k, v in d["metrics"].items():
            if v is None:
                # ``fairness_span`` is the one metric whose None encodes
                # a real value (inf, a starved core) — restore it so the
                # accessor and a re-serialization keep working
                if k == "fairness_span":
                    stats[k] = math.inf
                continue
            stats[k] = v
        return cls(spec=Spec.from_dict(d["spec"]), stats=stats)

    # ---- workload validation / energy refits ----------------------------
    def check(self) -> Dict[str, Any]:
        """Run the producing workload's conservation-law validator
        (queue pops ⊆ pushes, stack LIFO, histogram mass, ...) on this
        result; exact linearizability screens when the spec recorded a
        trace."""
        wl = _workloads.get(self.spec.workload.name)
        return wl.check(self.spec.to_params(), self.stats,
                        self.stats.get("trace_step"))

    def energy_stats(self) -> Dict[str, float]:
        """The billable stat totals (the ``costmodel.fit_energy`` /
        ``energy_per_op`` input contract)."""
        return _metrics.energy_stats(self.stats)
