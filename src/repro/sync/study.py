"""``Study`` — a declarative multi-point experiment over the sweep engine.

A study is a base :class:`~repro.sync.Spec` plus a chain of axis
*blocks*::

    Study(Spec(workload="ms_queue")) \\
        .grid(lat=[1, 4, 16], n_cores=[8, 64, 256]) \\
        .zip(seed=range(4))

``grid`` multiplies the current point set by the cartesian product of
its axes (last axis fastest, like the legacy ``sweep_grid``); ``zip``
multiplies by equal-length axes varied in lockstep.  Axis names are any
flat Spec field — *including* ``protocol``/``workload`` names and
static engine shapes like ``n_cores`` (the sweep runner fingerprints
and batches whatever can share a compile; everything else just
compiles per group).  Irregular point sets (figure benchmarks with
special-cased lines) skip the builder: :meth:`Study.from_specs` takes
an explicit spec list.

Execution compiles the point list onto the fingerprint-grouped vmapped
sweep runner (``repro.core.sweep``):

* :meth:`run` — all points, as a list of typed
  :class:`~repro.sync.Result`, in point order;
* :meth:`stream` — a generator yielding each ``Result`` as its sweep
  chunk materializes (chunk-completion order, NOT point order — each
  result's ``.spec`` identifies it), so figure scripts consume early
  points while later chunks are still in flight.

Studies are immutable: ``grid``/``zip`` return extended copies, so a
partial study can be shared and forked.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Iterator, List, Optional, Sequence

from repro.core import sweep as _sweep
from repro.sync.result import Result
from repro.sync.spec import Spec


def _as_spec(base: Any, flat: Dict[str, Any]) -> Spec:
    if base is None:
        return Spec(**flat)
    if isinstance(base, dict):
        base = Spec.from_dict(base)
    if not isinstance(base, Spec):
        raise ValueError(f"Study base must be a Spec, a dict of Spec "
                         f"fields, or None (got {base!r})")
    return base.replace(**flat) if flat else base


class Study:
    """Declarative experiment: base spec × axis blocks.  See the module
    docstring; construct as ``Study(spec)``, ``Study(protocol="lrsc",
    n_cores=64)`` (flat Spec fields), or :meth:`Study.from_specs`."""

    def __init__(self, base: Any = None, **flat: Any):
        self._bases: List[Spec] = [_as_spec(base, flat)]
        self._blocks: List[List[Dict[str, Any]]] = []

    @classmethod
    def from_specs(cls, specs: Iterable[Any]) -> "Study":
        """A study over an explicit point list (specs or spec-dicts).
        ``grid``/``zip`` still compose: each axis block multiplies every
        listed point."""
        self = cls.__new__(cls)
        self._bases = [s if isinstance(s, Spec) else Spec.from_dict(s)
                       for s in specs]
        if not self._bases:
            raise ValueError("Study.from_specs needs at least one spec")
        self._blocks = []
        return self

    # ---- builders (immutable: each returns an extended copy) ------------
    def _extend(self, blocks: List[List[Dict[str, Any]]]) -> "Study":
        out = Study.__new__(Study)
        out._bases = self._bases
        out._blocks = self._blocks + blocks
        return out

    def grid(self, **axes: Sequence[Any]) -> "Study":
        """Multiply the point set by the cartesian product of ``axes``
        (last axis fastest).  Values are flat Spec field values;
        ``protocol=``/``workload=`` take name strings."""
        if not axes:
            return self
        mat = {name: list(vals) for name, vals in axes.items()}
        for name, vals in mat.items():
            if not vals:
                raise ValueError(f"grid axis {name!r} is empty")
        return self._extend([[{name: v} for v in vals]
                             for name, vals in mat.items()])

    def zip(self, **axes: Sequence[Any]) -> "Study":
        """Multiply the point set by equal-length axes varied in
        lockstep (one point per position, not a product)."""
        if not axes:
            return self
        names = list(axes)
        cols = [list(axes[n]) for n in names]
        lengths = {n: len(c) for n, c in zip(names, cols)}
        if len(set(lengths.values())) != 1:
            raise ValueError(f"zip axes must have equal lengths, got "
                             f"{lengths}")
        if lengths[names[0]] == 0:
            raise ValueError("zip axes are empty")
        return self._extend([[dict(zip(names, vals))
                              for vals in zip(*cols)]])

    # ---- the compiled point list ----------------------------------------
    def specs(self) -> List[Spec]:
        """Every point of the study, in order (bases outermost, then
        each axis block, last block fastest)."""
        overrides: List[Dict[str, Any]] = [{}]
        for block in self._blocks:
            overrides = [{**o, **delta} for o in overrides
                         for delta in block]
        return [base.replace(**o) if o else base
                for base in self._bases for o in overrides]

    def __len__(self) -> int:
        n = len(self._bases)
        for block in self._blocks:
            n *= len(block)
        return n

    # ---- execution ------------------------------------------------------
    def run(self, max_batch: Optional[int] = None, energy_fit=None,
            report=None) -> List[Result]:
        """All points through the fingerprint-grouped vmapped sweep;
        one typed :class:`Result` per point, in :meth:`specs` order.

        ``report`` (a :class:`repro.obs.RunReport`) collects per-chunk
        compile/execute instrumentation; an enclosing
        ``repro.obs.collect()`` block works too."""
        specs = self.specs()
        raw = _sweep.sweep_params([s.to_params() for s in specs],
                                  max_batch=max_batch,
                                  energy_fit=energy_fit, report=report)
        return [Result(spec=s, stats=r) for s, r in zip(specs, raw)]

    def stream(self, max_batch: Optional[int] = None, energy_fit=None,
               report=None) -> Iterator[Result]:
        """Yield each point's :class:`Result` as its sweep chunk
        materializes (chunk-completion order; ``result.spec`` identifies
        the point).  Same results as :meth:`run`, different order.
        ``report`` instruments like :meth:`run`."""
        specs = self.specs()
        for i, r in _sweep.sweep_iter([s.to_params() for s in specs],
                                      max_batch=max_batch,
                                      energy_fit=energy_fit,
                                      report=report):
            yield Result(spec=specs[i], stats=r)
