"""``repro.sync`` — the stable, typed, declarative public API of the
LRSCwait/Colibri reproduction.

Everything a benchmark, figure script, or downstream user needs lives
here; the engine underneath (``repro.core.sim`` / ``repro.core.sweep``)
is an implementation detail whose legacy entry points now emit
``DeprecationWarning``.

Three nouns:

* :class:`Spec` — a frozen, validated description of one simulation
  point (protocol / workload / topology / costs sub-groups; built from
  kwargs, dicts, or JSON; bad names and impossible values raise at
  construction with the registries' available names).
* :class:`Result` — the typed result of one point: named accessors for
  the paper's metric triple (``throughput`` / ``jain_fairness`` /
  ``energy_pj_per_op``) and latency percentiles, raw counters under
  ``.stats``, shared row/JSON serialization (``to_row`` / ``to_json``).
* :class:`Study` — a declarative multi-point experiment
  (``Study(base).grid(lat=[1, 4, 16]).zip(seed=range(4))``) compiled
  onto the fingerprint-grouped vmapped sweep runner, with batch
  (:meth:`Study.run`) and streaming (:meth:`Study.stream`) execution.

Quickstart::

    from repro.sync import Spec, Study, run

    r = run(Spec(protocol="colibri", workload="ms_queue",
                 n_cores=64, n_addrs=2))
    print(r.throughput, r.jain_fairness, r.energy_pj_per_op, r.polls)

    study = Study(Spec(workload="zipf_histogram", n_addrs=64)) \\
        .grid(protocol=["colibri", "lrsc"], zipf_skew=[0, 100, 200])
    for res in study.stream():
        print(res.spec.protocol.name, res.to_row())

Results are **bit-identical** to the legacy ``sim.run`` /
``sweep.sweep`` surface (same engine, same derivation layer) —
``tests/test_sync_api.py`` locks that in across the full
protocol × workload grid.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

from repro.core import protocols as _protocols
from repro.core import workloads as _workloads
from repro.core import sim as _sim
from repro.core.metrics import METRIC_TRIPLE
from repro.core.sweep import enable_persistent_cache
from repro.sync.result import Result
from repro.sync.spec import Costs, Protocol, Spec, Topology, Workload
from repro.sync.study import Study

__all__ = ["Spec", "Result", "Study", "run",
           "Protocol", "Workload", "Topology", "Costs",
           "protocols", "workloads", "scenario",
           "METRIC_TRIPLE", "enable_persistent_cache"]


def run(spec: Optional[Spec] = None, *, energy_fit=None,
        **flat: Any) -> Result:
    """Run ONE simulation point and return its typed :class:`Result`.

    Accepts a :class:`Spec` (or spec dict), or flat Spec fields
    directly: ``run(protocol="colibri", n_addrs=1)``.  ``energy_fit``
    overrides the frozen Table II calibration behind
    ``energy_pj_per_op``.
    """
    if spec is None:
        spec = Spec(**flat)
    else:
        if isinstance(spec, dict):
            spec = Spec.from_dict(spec)
        if flat:
            spec = spec.replace(**flat)
    return Result(spec=spec,
                  stats=_sim.execute(spec.to_params(),
                                     energy_fit=energy_fit))


def protocols() -> Tuple[str, ...]:
    """Names of every registered synchronization protocol."""
    return _protocols.names()


def workloads() -> Tuple[str, ...]:
    """Names of every registered concurrent-algorithm workload."""
    return _workloads.names()


def scenario(workload: str) -> dict:
    """A workload's canonical Spec overrides (hot-word count, modify
    time, skew, ...) — merge into a :class:`Spec` instead of re-stating
    workload parameters per figure:
    ``Spec(workload="ms_queue", **scenario("ms_queue"))``."""
    return dict(_workloads.get(workload).scenario)
