"""``Spec`` — the frozen, validated, declarative simulation spec.

A :class:`Spec` is the public description of ONE simulation point,
organised into five sub-groups instead of the engine's flat
``SimParams``:

=============  ==========================================================
``protocol``   which synchronization protocol owns the banks, plus its
               policy knobs (queue capacity, cluster count, backoff)
``workload``   which concurrent-algorithm program each core runs, plus
               its knobs (Zipf skew, Fig. 5 streaming-worker count)
``topology``   the machine: cores, contended addresses/banks, network
               bandwidth, head-of-line blocking factor
``costs``      cycle costs and execution: network latency, local work,
               modify time, horizon, seed, scan unroll, backend, trace
               flag
``faults``     fault injection & recovery (:class:`repro.faults.
               FaultPlan`): core kills/stalls, message drops, bank
               stalls, the reservation watchdog and the forward-
               progress detector; all-zero = off and statically elided
=============  ==========================================================

Construction is deliberately forgiving about *shape* and strict about
*content*:

* flat kwargs — ``Spec(protocol="lrsc", n_cores=64, lat=3)`` routes
  each field to its group automatically;
* per-group dicts — ``Spec(protocol={"name": "lrscwait", "q_slots": 8})``
  (unnamed fields keep their defaults);
* plain dicts / JSON — :meth:`Spec.from_dict` / :meth:`Spec.from_json`
  accept either shape (and round-trip :meth:`to_dict` / :meth:`to_json`);
* group instances — ``Spec(topology=Topology(n_cores=1024))``.

Every constructor path validates at construction time: an unknown
protocol/workload name raises a ``ValueError`` listing the registry's
available names, and impossible field values (``n_cores <= 0``,
``cycles <= 0``, ``n_addrs`` below the workload's minimum, ...) raise
immediately — never deep inside a jit trace.  Validation lives in ONE
place (``SimParams.__post_init__``): a ``Spec`` lowers onto the
engine's ``SimParams`` via :meth:`to_params`, and constructing that
``SimParams`` eagerly at ``Spec`` construction is what validates it.

Specs are frozen, hashable and equality-comparable, so they work as
dict keys (streamed :class:`~repro.sync.Result` points identify
themselves by their spec).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Mapping

from repro.core.sim import SimParams
from repro.faults import FaultPlan


@dataclasses.dataclass(frozen=True)
class Protocol:
    """Synchronization protocol choice + policy knobs."""
    name: str = "colibri"
    q_slots: int = 256        # lrscwait queue capacity (>= n_cores = ideal)
    n_groups: int = 4         # colibri_hier: clusters of cores
    backoff: int = 160        # retry backoff base (paper: fixed 128)
    backoff_exp: int = 2      # exponential doublings cap (1 = fixed)


@dataclasses.dataclass(frozen=True)
class Workload:
    """Concurrent-algorithm program choice + its knobs."""
    name: str = "rmw_loop"
    zipf_skew: int = 100      # 100*s for ADDR_ZIPF streams (s = 1.0)
    n_workers: int = 0        # Fig. 5: cores streaming a matmul instead


@dataclasses.dataclass(frozen=True)
class Topology:
    """The simulated machine: size, NoC shape, and network knobs."""
    name: str = "flat"        # NoC topology (core.topologies registry):
    #                           flat single crossbar, or hierarchical
    #                           cluster2/cluster3 with per-level extra
    #                           latency and cross-cluster link budgets
    n_cores: int = 256
    n_addrs: int = 1          # contended addresses (fewer = hotter)
    net_bw: int = 64          # network acceptances per cycle
    hol_block: int = 16       # parked reqs per occupied net slot (0 = off)
    clusters: int = 4         # leaf clusters (hierarchical topologies)


@dataclasses.dataclass(frozen=True)
class Costs:
    """Cycle costs and execution knobs."""
    lat: int = 5              # one-way network latency (cycles)
    work: int = 10            # local work between atomics
    modify: int = 4           # cycles between load and store
    cycles: int = 20_000      # simulated horizon
    seed: int = 0
    unroll: int = 1           # lax.scan unroll (pure compile knob)
    backend: str = "auto"     # engine backend (sim.BACKENDS): auto picks
    #                           the Pallas kernel on accelerators, the
    #                           XLA scan path on CPU — bit-identical
    record_trace: bool = False  # exact per-completion latency trace
    #                           plus per-cycle state/queue-depth traces
    #                           (Result.events() / obs.perfetto.export)
    telemetry_windows: int = 0  # windowed in-scan telemetry: > 0 records
    #                           an (n_windows, k) timeseries of core
    #                           states / outcomes / queue depths / NoC
    #                           traffic (Result.timeseries()); 0 = off,
    #                           bit-identical to the untelemetered engine


#: Certified field envelope for the static analyses
#: (``repro.analysis``): inclusive (lo, hi) bounds per Spec field,
#: consumed by the integer-range pass to prove the engine's arbitration
#: and backoff arithmetic int32-safe over every Spec inside the
#: envelope (lower bounds mirror ``SimParams._BOUNDS``; upper bounds
#: are the certification scale — n_cores covers 4x the demonstrated
#: 4096-core runs).  A Spec outside the envelope still RUNS (the
#: engine's own static fallbacks apply); it is just not covered by the
#: certificate, and ``python -m repro.analysis range`` reports the
#: exact thresholds where each fallback must engage.
ANALYSIS_BOUNDS: Dict[str, tuple] = {
    "n_cores": (1, 16_384),
    "cycles": (1, 2**31 - 1),
    "n_addrs": (1, 16_384),
    "lat": (0, 2**16),
    "work": (0, 2**16),
    "modify": (0, 2**16),
    "backoff": (0, 2**20),
    "backoff_exp": (1, 8),
    "q_slots": (1, 16_384),
    "net_bw": (1, 2**20),
    "hol_block": (0, 2**20),
    "n_workers": (0, 16_384),
    "n_groups": (1, 16_384),
    "zipf_skew": (0, 10_000),
    "telemetry_windows": (0, 2**16),
    "unroll": (1, 64),
    "clusters": (1, 4_096),
}

#: (spec attribute, group class) in declaration order.  ``faults`` is
#: special in ONE way: it lowers onto a single ``SimParams.faults``
#: field instead of being flattened (see ``_lower``).
_GROUPS = (("protocol", Protocol), ("workload", Workload),
           ("topology", Topology), ("costs", Costs),
           ("faults", FaultPlan))

#: flat field name -> owning group attribute ("protocol"/"workload"
#: route to the group's ``name``; every other field name is unique)
_FLAT_TO_GROUP: Dict[str, str] = {}
for _gname, _gcls in _GROUPS:
    for _f in dataclasses.fields(_gcls):
        if _f.name != "name":
            _FLAT_TO_GROUP[_f.name] = _gname


def _build_group(gname: str, gcls, value, flat: Dict[str, Any]):
    """One group instance from (group value or None) + routed flat kwargs."""
    if isinstance(value, gcls):
        base = dataclasses.asdict(value)
    elif isinstance(value, str) and gname in ("protocol", "workload",
                                              "topology"):
        base = {"name": value}
    elif isinstance(value, Mapping):
        base = dict(value)
    elif value is None:
        base = {}
    else:
        raise ValueError(
            f"Spec {gname} must be a {gcls.__name__}, a dict"
            + (", a name string" if gname in ("protocol", "workload",
                                              "topology")
               else "") + f" or None (got {value!r})")
    known = {f.name for f in dataclasses.fields(gcls)}
    unknown = set(base) - known
    if unknown:
        raise ValueError(
            f"unknown {gname} field(s) {sorted(unknown)}; "
            f"{gcls.__name__} fields: {sorted(known)}")
    base.update(flat)
    return gcls(**base)


@dataclasses.dataclass(frozen=True, init=False)
class Spec:
    """One frozen, validated simulation point.  See the module docstring
    for the accepted construction shapes."""
    protocol: Protocol
    workload: Workload
    topology: Topology
    costs: Costs
    faults: FaultPlan

    def __init__(self, protocol=None, workload=None, topology=None,
                 costs=None, faults=None, **flat: Any):
        routed: Dict[str, Dict[str, Any]] = {g: {} for g, _ in _GROUPS}
        for k, v in flat.items():
            g = _FLAT_TO_GROUP.get(k)
            if g is None:
                raise ValueError(
                    f"unknown Spec field {k!r}; known fields: "
                    f"{', '.join(sorted(_FLAT_TO_GROUP))} (plus the "
                    f"groups protocol/workload/topology/costs/faults)")
            routed[g][k] = v
        given = {"protocol": protocol, "workload": workload,
                 "topology": topology, "costs": costs, "faults": faults}
        for gname, gcls in _GROUPS:
            object.__setattr__(self, gname, _build_group(
                gname, gcls, given[gname], routed[gname]))
        # eager lowering doubles as validation: SimParams.__post_init__
        # owns every name/bound check, so Spec and the legacy surface
        # can never drift apart on what is legal
        object.__setattr__(self, "_params", self._lower())

    # ---- lowering -------------------------------------------------------
    def _lower(self) -> SimParams:
        kw: Dict[str, Any] = {"protocol": self.protocol.name,
                              "workload": self.workload.name,
                              "topology": self.topology.name,
                              "faults": self.faults}
        for gname, gcls in _GROUPS:
            if gname == "faults":          # one engine field, not flattened
                continue
            g = getattr(self, gname)
            for f in dataclasses.fields(gcls):
                if f.name != "name":
                    kw[f.name] = getattr(g, f.name)
        return SimParams(**kw)

    def to_params(self) -> SimParams:
        """The engine-level ``SimParams`` this spec lowers to."""
        return self._params

    @classmethod
    def from_params(cls, p: SimParams) -> "Spec":
        """Lift an engine-level ``SimParams`` into a ``Spec``."""
        kw = {f.name: getattr(p, f.name) for f in dataclasses.fields(p)}
        return cls(**kw)

    # ---- dict / JSON ----------------------------------------------------
    def to_dict(self) -> Dict[str, Dict[str, Any]]:
        """Nested plain dict (one sub-dict per group); JSON-ready and
        accepted back by :meth:`from_dict`."""
        return {g: dataclasses.asdict(getattr(self, g)) for g, _ in _GROUPS}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Spec":
        """Build from a plain dict — nested (group sub-dicts), flat
        (engine field names), or any mix."""
        return cls(**dict(d))

    def to_json(self, **dumps_kw: Any) -> str:
        return json.dumps(self.to_dict(), **dumps_kw)

    @classmethod
    def from_json(cls, s: str) -> "Spec":
        return cls.from_dict(json.loads(s))

    # ---- derivation -----------------------------------------------------
    def replace(self, **changes: Any) -> "Spec":
        """A new ``Spec`` with ``changes`` applied: flat field names,
        ``protocol=``/``workload=`` name strings, or *partial* group
        dicts (``topology={"n_cores": 1024}`` keeps the other topology
        fields).  Validates like any construction."""
        merged = self.to_dict()
        # group-level changes first, flat fields second, so a flat field
        # always lands on top of a whole-group replacement regardless of
        # the kwarg order (replace(seed=5, costs=Costs(...)) keeps seed=5)
        for k, v in changes.items():
            if k not in merged:
                continue
            if isinstance(v, str) and k in ("protocol", "workload",
                                            "topology"):
                merged[k]["name"] = v
            elif isinstance(v, Mapping):
                merged[k].update(v)
            elif dataclasses.is_dataclass(v) and not isinstance(v, type):
                merged[k] = dataclasses.asdict(v)
            else:
                merged[k] = v            # invalid; _build_group reports it
        for k, v in changes.items():
            if k in merged:
                continue
            g = _FLAT_TO_GROUP.get(k)
            if g is None:
                raise ValueError(
                    f"unknown Spec field {k!r}; known fields: "
                    f"{', '.join(sorted(_FLAT_TO_GROUP))}")
            merged[g][k] = v
        return Spec(**merged)
