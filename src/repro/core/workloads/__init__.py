"""Concurrent-algorithm workload plugins for the cycle-level engine.

Importing this package registers every built-in workload:

================  =====================================================
``rmw_loop``      the seed engine's work→RMW loop (bit-identical)
``ms_queue``      enqueue/dequeue as two linked atomics on head/tail
``treiber_stack`` push/pop CAS pairs on one top-of-stack word
``zipf_histogram`` histogram updates over a Zipf-skewed address stream
``barrier_phases`` compute → barrier → compute (arXiv:2307.10248)
================  =====================================================

Workloads are orthogonal to protocols: every registered protocol runs
every registered workload through the same engine, so the benchmark
grid (``benchmarks/bench_workloads.py``) is the cartesian product.

New workloads: subclass :class:`~repro.core.workloads.base.Workload`,
decorate with :func:`~repro.core.workloads.registry.register`, and
import the module here.
"""
from repro.core.workloads import (barrier_phases, ms_queue, rmw_loop,
                                  treiber_stack, zipf_histogram)
from repro.core.workloads.base import (ADDR_FIXED, ADDR_UNIFORM, ADDR_ZIPF,
                                       K_ATOMIC, K_BARRIER, Program,
                                       Workload, zipf_index)
from repro.core.workloads.registry import get, names, register

__all__ = ["ADDR_FIXED", "ADDR_UNIFORM", "ADDR_ZIPF", "K_ATOMIC",
           "K_BARRIER", "Program", "Workload", "zipf_index",
           "get", "names", "register",
           "barrier_phases", "ms_queue", "rmw_loop", "treiber_stack",
           "zipf_histogram"]
