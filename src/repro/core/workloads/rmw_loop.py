"""``rmw_loop`` — the seed engine's behaviour as a one-step program.

local work (``work`` cycles) → one atomic RMW on a uniform
pseudo-random address (``modify`` cycles between load and store) →
repeat.  This compiles to the table ``[work·LOCAL_WORK,
ATOMIC(uniform, modify)]`` whose interpretation is **bit-identical** to
the pre-workload engine for every protocol: ``tests/test_protocols.py``
golden values and every existing figure stay locked.
"""
from __future__ import annotations

import numpy as np

from repro.core.workloads.base import (ADDR_UNIFORM, K_ATOMIC, Program,
                                       Workload)
from repro.core.workloads.registry import register


@register
class RmwLoop(Workload):
    name = "rmw_loop"

    def program(self, p) -> Program:
        return Program(kind=(K_ATOMIC,),
                       pre_mult=(1,), pre_add=(0,),
                       addr_mode=(ADDR_UNIFORM,), addr_arg=(0,),
                       mod_mult=(1,), mod_add=(0,))

    def check(self, p, res, trace=None):
        out = super().check(p, res, trace)
        # one-step program: completed ops == completed atomics, per core
        assert np.array_equal(np.asarray(res["ops"]), np.asarray(res["opc"]))
        return out
