"""``zipf_histogram`` — histogram updates over a skewed address stream.

Same one-atomic-per-op shape as ``rmw_loop``, but the address stream is
a bounded power law (:func:`~repro.core.workloads.base.zipf_index`)
with skew ``SimParams.zipf_skew / 100`` instead of the uniform counter
hash.  ``zipf_skew`` is a traced sweep axis, so a whole skew ladder
(uniform 0.0 → Zipf 1.0 → heavy 2.0+) batches through one engine
compilation — the contention knob real histogram kernels actually
experience (word frequencies, degree distributions) rather than the
uniform-bins idealization.

``zipf_skew=0`` is the exact uniform limit over ``n_addrs`` bins (the
figure-3 histogram scenario, modulo the hash→inverse-CDF stream
change).  ``check`` asserts mass conservation (bin totals == completed
updates) and, for skewed streams, that the hot bin carries at least its
uniform share.
"""
from __future__ import annotations

import numpy as np

from repro.core.workloads.base import (ADDR_ZIPF, K_ATOMIC, Program,
                                       Workload)
from repro.core.workloads.registry import register


@register
class ZipfHistogram(Workload):
    name = "zipf_histogram"
    scenario = {"n_addrs": 64, "zipf_skew": 100}

    def program(self, p) -> Program:
        return Program(kind=(K_ATOMIC,),
                       pre_mult=(1,), pre_add=(0,),
                       addr_mode=(ADDR_ZIPF,), addr_arg=(0,),
                       mod_mult=(1,), mod_add=(0,))

    def check(self, p, res, trace=None):
        out = super().check(p, res, trace)       # bin totals == atomics
        addr_ops = np.asarray(res["addr_ops"])[:p.n_addrs]
        total = max(int(addr_ops.sum()), 1)
        out["hot_share"] = float(addr_ops.max()) / total
        if p.zipf_skew > 0 and p.n_addrs > 1 and total > 16:
            assert out["hot_share"] >= 1.0 / p.n_addrs, \
                "skewed stream lost its hot bin"
        return out
