"""``treiber_stack`` — Treiber-stack traffic: push/pop CAS pairs on one
top-of-stack word.

Every op is a push RMW on the ``top`` pointer (address 0) followed, one
dependent-load gap later, by a pop RMW on the same word: the classic
single-hot-word concurrent object, maximally contended (unlike
``ms_queue`` there is no head/tail split to spread load over banks).

``check`` validates per-core LIFO order from the completion trace:
each core strictly alternates push→pop, so every pop removes that
core's most recent un-popped push — the per-core LIFO law the stack
guarantees without tracking values.
"""
from __future__ import annotations

import numpy as np

from repro.core.workloads.base import (ADDR_FIXED, K_ATOMIC, Program,
                                       Workload)
from repro.core.workloads.registry import register

TOP = 0
PUSH, POP = 0, 1
DEP_GAP = 2


@register
class TreiberStack(Workload):
    name = "treiber_stack"
    scenario = {"n_addrs": 1}                    # one top-of-stack word

    def program(self, p) -> Program:
        return Program(kind=(K_ATOMIC, K_ATOMIC),
                       pre_mult=(1, 0), pre_add=(0, DEP_GAP),
                       addr_mode=(ADDR_FIXED, ADDR_FIXED),
                       addr_arg=(TOP, TOP),
                       mod_mult=(1, 1), mod_add=(0, 0))

    def check(self, p, res, trace=None):
        out = super().check(p, res, trace)
        if trace is None:
            return out
        trace = np.asarray(trace)
        pushes = int((trace == PUSH).sum())
        pops = int((trace == POP).sum())
        assert pops <= pushes, "more pops than pushes"
        # per-core LIFO: strict push→pop alternation means each pop
        # matches the core's latest outstanding push
        for c, seq in self._per_core_steps(trace):
            want = np.arange(len(seq)) % 2
            assert np.array_equal(seq, want), f"core {c} broke LIFO order"
        out["pushes"], out["pops"] = pushes, pops
        return out
