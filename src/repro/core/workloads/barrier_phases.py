"""``barrier_phases`` — bulk-synchronous compute/barrier phases (the
1024-core barrier study scenario, arXiv:2307.10248).

Each op is one phase: a compute segment (``COMPUTE_MULT × work``
cycles), then a barrier — an arrival atomic (fetch-and-increment, 1
cycle in the bank) on the barrier counter word issued *through the
active protocol*, after which the core parks in ``BARWAIT`` until every
participating core has arrived and the engine broadcasts the release
(one message per waiter, one response latency).

The protocol therefore owns exactly what the barrier papers measure:
the arrival contention on one hot word.  Retry-based protocols (LRSC,
spin locks) storm the counter as core counts grow; queue-based arrivals
(LRSCwait/Colibri/Mwait) stay polling-free, so barrier latency scales
with the serialized bank service rate instead of the retry traffic.

``check`` asserts the bulk-synchronous laws: no core is ever a full
phase ahead (per-core completed phases span ≤ 1) and arrivals balance
(`bar_cnt` equals completed atomics per core).
"""
from __future__ import annotations

import numpy as np

from repro.core.workloads.base import (ADDR_FIXED, K_BARRIER, Program,
                                       Workload)
from repro.core.workloads.registry import register

BARRIER_ADDR = 0
COMPUTE_MULT = 4           # compute segment = 4 × the `work` scalar


@register
class BarrierPhases(Workload):
    name = "barrier_phases"
    scenario = {"n_addrs": 1}                    # one arrival counter

    def program(self, p) -> Program:
        return Program(kind=(K_BARRIER,),
                       pre_mult=(COMPUTE_MULT,), pre_add=(0,),
                       addr_mode=(ADDR_FIXED,), addr_arg=(BARRIER_ADDR,),
                       mod_mult=(0,), mod_add=(1,))

    def check(self, p, res, trace=None):
        out = super().check(p, res, trace)
        nw = min(p.n_workers, p.n_cores)
        ops = np.asarray(res["ops"])[nw:]
        bar = np.asarray(res["bar_cnt"])[nw:]
        if ops.size:
            span = int(ops.max()) - int(ops.min())
            assert span <= 1, f"barrier let a core run {span} phases ahead"
            assert np.array_equal(bar, np.asarray(res["opc"])[nw:]), \
                "arrival count out of sync with completed atomics"
            out["phases"] = int(ops.min())
        return out
