"""String-keyed registry of workload plugins — the algorithm twin of
``protocols.registry``.

Adding a workload is one module: subclass ``base.Workload``, decorate
the class (or call ``register`` on an instance), import it from
``workloads/__init__``.  The engine, sweep runner, and benchmarks all
resolve workloads by name through ``get``.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.workloads.base import Workload

_REGISTRY: Dict[str, Workload] = {}


def register(wl):
    """Register a Workload subclass or instance under its ``name``."""
    inst = wl() if isinstance(wl, type) else wl
    if not inst.name:
        raise ValueError(f"workload {wl!r} has no name")
    if inst.name in _REGISTRY:
        raise ValueError(f"duplicate workload name: {inst.name}")
    _REGISTRY[inst.name] = inst
    return wl


def get(name: str) -> Workload:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown workload {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
