"""``ms_queue`` — Michael–Scott-style concurrent queue traffic.

Each op is an enqueue/dequeue pair of *linked* atomics on the queue's
two hot words: the enqueue RMW swings the **tail** pointer (address 1,
link-update ``modify`` window), then after a short dependent-load gap
the dequeue RMW advances the **head** (address 0).  This is the Fig. 6
scenario expressed as an actual two-atomic program instead of the
former ``n_addrs=2`` parameter approximation: head and tail contend in
their own banks, and a core can never have its dequeue overtake its own
enqueue (program order).

Host-side ``check`` replays the completion trace as a linearizability
/ conservation screen: at every cycle prefix pops ⊑ pushes, per-core
ops strictly alternate enqueue→dequeue, and head-bank commits are
totally ordered (≤ 1 pop retires per cycle — the single-ported bank is
the linearization point).
"""
from __future__ import annotations

import numpy as np

from repro.core.workloads.base import (ADDR_FIXED, K_ATOMIC, Program,
                                       Workload)
from repro.core.workloads.registry import register

HEAD, TAIL = 0, 1
ENQ, DEQ = 0, 1            # step ids in the program / trace
DEP_GAP = 2                # dependent-load gap between the linked atomics


@register
class MsQueue(Workload):
    name = "ms_queue"
    min_addrs = 2
    #: the Fig. 6 queue scenario: head+tail words, link-update modify
    scenario = {"n_addrs": 2, "modify": 8}

    def program(self, p) -> Program:
        return Program(kind=(K_ATOMIC, K_ATOMIC),
                       pre_mult=(1, 0), pre_add=(0, DEP_GAP),
                       addr_mode=(ADDR_FIXED, ADDR_FIXED),
                       addr_arg=(TAIL, HEAD),
                       mod_mult=(1, 1), mod_add=(0, 0))

    def check(self, p, res, trace=None):
        out = super().check(p, res, trace)
        if trace is None:
            return out
        trace = np.asarray(trace)
        pushes = (trace == ENQ).sum(axis=1)
        pops = (trace == DEQ).sum(axis=1)
        # pops ⊆ pushes at every prefix: every dequeue is covered by an
        # earlier enqueue (each core's deq is program-ordered after its enq)
        lead = np.cumsum(pushes) - np.cumsum(pops)
        assert lead.min() >= 0, f"pop overtook push (deficit {lead.min()})"
        # FIFO per-bank order: the head bank serves at most one dequeue
        # per cycle, so pop order is a total order
        assert pops.max(initial=0) <= 1, "two pops retired in one cycle"
        # per-core program order: strict enq→deq alternation
        for c, seq in self._per_core_steps(trace):
            want = np.arange(len(seq)) % 2
            assert np.array_equal(seq, want), f"core {c} broke enq/deq order"
        out["pushes"] = int(pushes.sum())
        out["pops"] = int(pops.sum())
        return out
