"""Workload plugin interface for the cycle-level engine (``core.sim``).

A *workload* is the algorithm analogue of a synchronization protocol
plugin: where a :class:`~repro.core.protocols.base.Protocol` owns what
happens when a request reaches its bank, a :class:`Workload` owns what
each core *runs* — a small per-core **program** of micro-ops that the
engine interprets with a per-core program counter instead of its former
fixed work→RMW loop.

Program model
-------------
A :class:`Program` is a static table of ``length`` micro-op steps.  Each
step is an atomic phase::

    <pre_mult*work + pre_add cycles of local work>
    ATOMIC(addr_mode, addr_arg)          # kind = K_ATOMIC
        with mod_mult*modify + mod_add cycles between load and store
  or
    BARRIER-arrival atomic, then wait    # kind = K_BARRIER

Durations are expressed as ``(mult, add)`` pairs against the engine's
``work``/``modify`` scalars so programs stay valid when those scalars
are *traced* sweep axes (``core.sweep``).  Address streams:

=============  =========================================================
ADDR_UNIFORM   counter-hash uniform over ``n_addrs`` (the seed engine's
               stream — bit-identical to the pre-workload engine)
ADDR_FIXED     ``addr_arg % n_addrs`` (queue head/tail, stack top,
               barrier counter)
ADDR_ZIPF      bounded power-law (Zipf-like) over ``n_addrs`` with
               skew ``zipf_skew/100`` (:func:`zipf_index`)
=============  =========================================================

A ``K_BARRIER`` step issues its arrival atomic through the active
protocol (so arrival cost and retry behaviour are protocol-specific),
then parks the core in ``BARWAIT`` until every participating core has
arrived; the engine then releases all waiters with one broadcast
message each and one response latency.

Completing the last step wraps the program counter and counts one
completed *op* (so ``rmw_loop``'s single-step program keeps today's
``ops`` semantics exactly).

Workloads are pure *compilers* — they emit the table host-side; the
engine's scan body stays the single interpreter.  ``check`` gives each
workload a host-side validator for its defining conservation laws
(queue pops ⊆ pushes, stack per-core LIFO, histogram mass balance, ...)
run by ``tests/test_workloads.py`` over every registered protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

# micro-op kinds
K_ATOMIC, K_BARRIER = 0, 1
# address-stream modes
ADDR_UNIFORM, ADDR_FIXED, ADDR_ZIPF = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class Program:
    """Static per-core micro-op table (tuples of ints, one entry per
    step).  Hashable, so it can live inside the jitted engine's static
    configuration."""
    kind: Tuple[int, ...]
    pre_mult: Tuple[int, ...]       # local work = pre_mult*work + pre_add
    pre_add: Tuple[int, ...]
    addr_mode: Tuple[int, ...]
    addr_arg: Tuple[int, ...]
    mod_mult: Tuple[int, ...]       # modify  = mod_mult*modify + mod_add
    mod_add: Tuple[int, ...]

    def __post_init__(self):
        L = len(self.kind)
        if L < 1:
            raise ValueError("empty program")
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if len(v) != L:
                raise ValueError(f"field {f.name} has length {len(v)} != {L}")
        for k, m in zip(self.kind, self.addr_mode):
            if k not in (K_ATOMIC, K_BARRIER):
                raise ValueError(f"unknown micro-op kind {k}")
            if k == K_BARRIER and m != ADDR_FIXED:
                raise ValueError("barrier steps need a FIXED address")
            if m not in (ADDR_UNIFORM, ADDR_FIXED, ADDR_ZIPF):
                raise ValueError(f"unknown address mode {m}")

    @property
    def length(self) -> int:
        return len(self.kind)

    def tables(self) -> Dict[str, jnp.ndarray]:
        """The table as int32 device constants for the scan body."""
        return {f.name: jnp.asarray(getattr(self, f.name), jnp.int32)
                for f in dataclasses.fields(self)}


def zipf_index(h24, n_addrs, skew_pct):
    """Map a 24-bit hash to a Zipf-like address in ``[0, n_addrs)``.

    Inverse CDF of the bounded continuous power law with density
    ∝ x^(-s) on [1, n+1): ``x = (1 + u*((n+1)^(1-s) - 1))^(1/(1-s))``
    with the log-uniform limit ``(n+1)^u`` near s = 1; address =
    ``floor(x) - 1`` so bin k carries the [k+1, k+2) mass.  ``skew_pct``
    is ``100*s`` (an int, so the skew can ride the int32 vmapped sweep
    axes); s = 0 is the exact uniform limit, s ≈ 1 classic Zipf, s > 1
    concentrates mass on address 0.  ``n_addrs`` and ``skew_pct`` may be
    traced scalars.
    """
    u = h24.astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    top = jnp.asarray(n_addrs).astype(jnp.float32) + 1.0
    s = jnp.asarray(skew_pct).astype(jnp.float32) * jnp.float32(0.01)
    om = 1.0 - s                                  # 1 - s
    near1 = jnp.abs(om) < 1e-3
    safe_om = jnp.where(near1, 1.0, om)
    x_gen = (1.0 + u * (top ** safe_om - 1.0)) ** (1.0 / safe_om)
    x = jnp.where(near1, top ** u, x_gen)
    hi = jnp.asarray(n_addrs).astype(jnp.int32) - 1
    return jnp.clip(jnp.floor(x).astype(jnp.int32) - 1, 0, hi)


class Workload:
    """Base workload plugin.  Subclasses compile a :class:`Program` from
    the static ``SimParams`` and validate results host-side."""

    name: str = ""
    #: smallest static ``n_addrs`` (bank allocation) the program needs to
    #: keep its fixed addresses distinct; the engine rejects smaller
    #: allocations.  (A *traced* sweep n_addrs below it only folds the
    #: fixed addresses together, which stays legal.)
    min_addrs: int = 1
    #: canonical ``SimParams`` overrides for this workload's scenario
    #: (hot-word count, link-update modify, skew...).  Benchmarks merge
    #: these instead of re-stating workload parameters per figure.
    scenario: Dict[str, int] = {}

    def program(self, p) -> Program:
        raise NotImplementedError

    # ---- host-side conservation laws ----
    def check(self, p, res: Dict[str, Any],
              trace: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """Assert this workload's invariants on a result dict from
        ``sim.run`` (and, if available, a ``record_trace=True`` event
        trace of shape (cycles, n) holding completed step ids or -1).

        The base law holds for every workload: each completed atomic
        retired on exactly one address, so the per-address completion
        histogram carries exactly the total atomic count.
        """
        addr_ops = np.asarray(res["addr_ops"])
        atomics = int(np.asarray(res["opc"]).sum())
        assert int(addr_ops.sum()) == atomics, \
            f"address histogram mass {int(addr_ops.sum())} != {atomics}"
        # the completion-latency histogram is accumulated bank-side at
        # grant time (core.sim); its mass must still equal the retired
        # atomic count exactly, for every protocol's grant pattern
        if "lat_hist" in res:
            lat_mass = int(np.asarray(res["lat_hist"]).sum())
            assert lat_mass == atomics, \
                f"latency histogram mass {lat_mass} != {atomics}"
        return {"atomics": atomics, "ops": int(np.asarray(res["ops"]).sum())}

    # ---- trace helpers for subclasses ----
    @staticmethod
    def _per_core_steps(trace: np.ndarray):
        """Yield (core, step-id sequence in completion order)."""
        for c in range(trace.shape[1]):
            col = trace[:, c]
            yield c, col[col >= 0]
