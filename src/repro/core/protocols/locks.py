"""Spin-lock baselines protecting the bin: ``amo_lock``, ``lrsc_lock``,
``ticket_lock``.

* ``amo_lock``    — test&set via a single AMO; failed attempts back off
                    with the paper's fixed 128-cycle policy and re-poll.
* ``lrsc_lock``   — the same lock built from an LR/SC pair: two round
                    trips per acquire attempt and double the messages.
* ``ticket_lock`` — FIFO spin lock: the first attempt draws a ticket from
                    the bank's dispenser; re-polls re-check ``serving``
                    against the core's held ticket.  Still polling-based
                    (retry traffic like ``amo_lock``) but grants strictly
                    in ticket order — the classic fairness/polling
                    trade-off point between test&set and Mwait queues.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.protocols.base import (NXT_BACKOFF, NXT_MOD, NXT_WORK_DONE,
                                       OUT_DONE, OUT_EVICT, OUT_FAIL,
                                       OUT_GRANT, OUT_NONE, RESP, Contract,
                                       FusedOut, Protocol)
from repro.core.protocols.registry import register


class SpinLock(Protocol):
    fixed_backoff = True
    lr_pair = False          # lrsc_lock: LR+SC = two round trips per attempt
    # test&set semantics: the lock grant is exclusive, but losers poll
    # (OUT_FAIL → backoff → retry) — the paper's retry-traffic baseline
    contract = Contract(exclusive_grant=True, retry_free=False,
                        wait_class=False, max_hot_scatters=2)

    def init_bank_state(self, p, a, n, q_cap):
        return dict(lock=jnp.zeros((a,), bool))

    def on_access(self, ctx, cs, bank):
        p, wa = ctx.p, ctx.wa
        is_acq, is_rel = ctx.is_acq, ctx.is_rel
        lock = bank["lock"]
        acq_rt = 2 * p.lat if self.lr_pair else p.lat
        free = ~lock[wa]
        got = is_acq & free
        fail = is_acq & ~free
        cs["st"] = jnp.where(is_acq, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_acq, acq_rt, cs["tmr"])
        cs["nxt"] = jnp.where(got, NXT_MOD,
                              jnp.where(fail, NXT_BACKOFF, cs["nxt"]))
        cs["polls"] = cs["polls"] + fail.sum()
        if self.lr_pair:
            cs["msgs"] = cs["msgs"] + 2 * is_acq.sum()
        cs["st"] = jnp.where(is_rel, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_rel, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(is_rel, NXT_WORK_DONE, cs["nxt"])
        # dense bank update: a winner is either acq or rel, never both
        bank["lock"] = (lock | (ctx.acq_b & ~lock)) & ~ctx.rel_b
        return cs, bank

    def fused_access(self, fx, bank):
        lock = bank["lock"]
        got_b = fx.acq_b & ~lock
        fail_b = fx.acq_b & lock
        kind = jnp.where(
            got_b, OUT_GRANT,
            jnp.where(fail_b, OUT_FAIL,
                      jnp.where(fx.rel_b, OUT_DONE, OUT_NONE))
        ).astype(jnp.int32)
        acq_rt = 2 * fx.p.lat if self.lr_pair else fx.p.lat
        tmr = jnp.where(fx.acq_b, acq_rt, fx.p.lat).astype(jnp.int32)
        msgs = (2 * fx.acq_b.astype(jnp.int32)) if self.lr_pair else None
        bank = dict(bank, lock=(lock | got_b) & ~fx.rel_b)
        return bank, FusedOut(kind=kind, tmr=tmr, msgs=msgs)

    # ---- fault recovery (repro.faults): timeout-and-retry ---------------
    # a lock held with no release for watchdog_cyc whose holder is
    # permanently dead is force-freed; the spinners' normal re-polls
    # then take it (retry-based recovery, no wake path needed)
    def held(self, bank):
        return bank["lock"]

    def on_timeout(self, ctx, cs, bank, stuck_b, killed, owner):
        own_dead = (owner < ctx.n) & killed[jnp.clip(owner, 0, ctx.n - 1)]
        free_b = stuck_b & own_dead
        bank["lock"] = bank["lock"] & ~free_b
        return cs, bank, jnp.where(free_b, OUT_EVICT,
                                   OUT_NONE).astype(jnp.int32)


@register
class AmoLock(SpinLock):
    name = "amo_lock"


@register
class LrscLock(SpinLock):
    name = "lrsc_lock"
    lr_pair = True


@register
class TicketLock(Protocol):
    name = "ticket_lock"
    fixed_backoff = True
    # polling like the spin locks (re-polls fail until `serving`
    # matches), but grants are exclusive and strictly ticket-ordered
    contract = Contract(exclusive_grant=True, retry_free=False,
                        wait_class=False, max_hot_scatters=2)

    def init_bank_state(self, p, a, n, q_cap):
        return dict(
            next_tkt=jnp.zeros((a,), jnp.int32),
            serving=jnp.zeros((a,), jnp.int32),
        )

    def init_core_state(self, p, n):
        return dict(tkt=jnp.full((n,), -1, jnp.int32))

    def on_access(self, ctx, cs, bank):
        p, wa = ctx.p, ctx.wa
        is_acq, is_rel = ctx.is_acq, ctx.is_rel
        next_tkt, serving = bank["next_tkt"], bank["serving"]
        # first attempt draws a ticket; re-polls keep the one they hold
        draw = is_acq & (cs["tkt"] < 0)
        my_tkt = jnp.where(draw, next_tkt[wa], cs["tkt"])
        wcs = jnp.minimum(ctx.win_core, ctx.n - 1)   # gather-safe
        draw_b = ctx.acq_b & (cs["tkt"][wcs] < 0)
        next_tkt = next_tkt + draw_b                 # dense dispenser bump
        cs["tkt"] = jnp.where(is_acq, my_tkt, cs["tkt"])
        got = is_acq & (my_tkt == serving[wa])
        fail = is_acq & ~got
        cs["st"] = jnp.where(is_acq, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_acq, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(got, NXT_MOD,
                              jnp.where(fail, NXT_BACKOFF, cs["nxt"]))
        cs["polls"] = cs["polls"] + fail.sum()
        # release: advance the serving counter, drop the ticket
        serving = serving + ctx.rel_b                # dense
        cs["tkt"] = jnp.where(is_rel, -1, cs["tkt"])
        cs["st"] = jnp.where(is_rel, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_rel, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(is_rel, NXT_WORK_DONE, cs["nxt"])
        bank["next_tkt"], bank["serving"] = next_tkt, serving
        return cs, bank

    # the winner's held ticket is the one per-core value the bank needs,
    # and the drawn/dropped ticket is the one per-core value it writes
    fused_core_fields = ("tkt",)
    fused_xset_fields = ("tkt",)

    def fused_access(self, fx, bank):
        next_tkt, serving = bank["next_tkt"], bank["serving"]
        tkt_w = fx.core["tkt"]                    # winner's held ticket
        draw_b = fx.acq_b & (tkt_w < 0)
        my_tkt_b = jnp.where(draw_b, next_tkt, tkt_w)
        next_tkt = next_tkt + draw_b
        got_b = fx.acq_b & (my_tkt_b == serving)
        kind = jnp.where(
            got_b, OUT_GRANT,
            jnp.where(fx.acq_b, OUT_FAIL,
                      jnp.where(fx.rel_b, OUT_DONE, OUT_NONE))
        ).astype(jnp.int32)
        tmr = jnp.full_like(kind, fx.p.lat)
        serving = serving + fx.rel_b
        bank = dict(bank, next_tkt=next_tkt, serving=serving)
        # acquires record their (kept or drawn) ticket; releases drop it
        xset = {"tkt": (jnp.where(fx.rel_b, -1, my_tkt_b).astype(jnp.int32),
                        fx.acq_b | fx.rel_b)}
        return bank, FusedOut(kind=kind, tmr=tmr, xset=xset)

    # ---- fault recovery (repro.faults): skip the dead ticket ------------
    def held(self, bank):
        return bank["serving"] < bank["next_tkt"]

    def on_timeout(self, ctx, cs, bank, stuck_b, killed, owner):
        own_dead = (owner < ctx.n) & killed[jnp.clip(owner, 0, ctx.n - 1)]
        skip_b = stuck_b & own_dead
        # advance the serving counter past the dead holder's ticket; the
        # next waiter's re-poll matches and takes the lock
        bank["serving"] = bank["serving"] + skip_b
        return cs, bank, jnp.where(skip_b, OUT_EVICT,
                                   OUT_NONE).astype(jnp.int32)
