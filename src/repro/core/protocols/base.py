"""Protocol plugin interface for the cycle-level engine (``core.sim``).

The engine owns everything protocol-agnostic: per-core timers and state
transitions, the backoff policy, worker traffic, network acceptance with
head-of-line blocking, and per-bank FIFO arbitration.  A ``Protocol``
owns only what happens when an arbitrated request reaches its bank:

* ``init_bank_state``  — the per-bank pytree (reservation slots, queues,
  lock bits, ...) carried through the ``lax.scan``.
* ``init_core_state``  — optional per-core protocol state (e.g. a ticket).
* ``on_access``        — handle this cycle's bank winners (at most one per
  bank, guaranteed by the engine's arbitration), split into acquire
  (``ctx.is_acq``) and release (``ctx.is_rel``) lanes.
* ``on_wake``          — queue-based protocols: fire wake-up timers and
  move sleeping cores back to their critical section.

Handlers are pure: they take the mutable dicts (``cs`` for per-core state
+ message/poll counters, ``bank`` for bank state) and return updated
copies.  All protocol logic stays inside masked vectorized updates over
the full core/bank arrays — a handler is exactly one of the former
``step()`` branches, lifted into a module.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax.numpy as jnp

# core states (BARWAIT: parked at a workload barrier, polling-free)
WORK, REQ, SLEEP, MOD, BACKOFF, RESP, BARWAIT = 0, 1, 2, 3, 4, 5, 6
# request phases
P_ACQ, P_REL = 0, 1
# resp_next codes
NXT_WORK_DONE, NXT_MOD, NXT_BACKOFF = 0, 1, 2


def mset(arr, idx, mask, val):
    """Masked scatter-set: only lanes with mask write; others dropped
    (out-of-bounds index). Avoids duplicate-index races."""
    oob = jnp.full_like(idx, arr.shape[0])
    return arr.at[jnp.where(mask, idx, oob)].set(val, mode="drop")


@dataclasses.dataclass
class Ctx:
    """Per-cycle view handed to protocol handlers.

    ``p`` is the resolved parameter namespace — fields may be traced
    scalars when running under the vmapped sweep (``core.sweep``), so
    handlers must treat them as jax values, never as Python ints for
    shapes.  ``n``/``a``/``q_cap`` are always static.
    """
    p: Any                   # resolved SimParams-like namespace
    n: int                   # cores (static)
    a: int                   # banks allocated (static upper bound)
    q_cap: int               # queue slots per bank (static)
    is_acq: jnp.ndarray      # (n,) bool — this cycle's acquire winners
    is_rel: jnp.ndarray      # (n,) bool — this cycle's release winners
    wa: jnp.ndarray          # (n,) int32 — each core's target bank
    wc: jnp.ndarray          # (n,) int32 — arange(n) core ids
    ba: jnp.ndarray = None   # (a,) int32 — arange(a) bank ids (hoisted
    #                          once per trace; handlers reuse it instead
    #                          of building a fresh iota every cycle)
    #: (a,) int32 — each bank's winning core this cycle, or ``n`` when
    #: the bank has no winner.  The engine guarantees at most one winner
    #: per bank, so protocols can update bank-side state *densely* —
    #: ``jnp.where(acq_b, f(win_core), state)`` — instead of scattering
    #: n core lanes into a-sized arrays; that turns every bank-state
    #: write from an n-lane scatter into O(a) vector ops (the dominant
    #: cost of queue protocols on CPU).  Gathering core-side values at
    #: ``jnp.minimum(win_core, n - 1)`` is safe; mask with acq_b/rel_b.
    win_core: jnp.ndarray = None
    acq_b: jnp.ndarray = None   # (a,) bool — bank winner is an acquire
    rel_b: jnp.ndarray = None   # (a,) bool — bank winner is a release
    #: (n,) int32 — each core's *current micro-op* modify duration.  The
    #: engine interprets workload programs (``core.workloads``), so the
    #: cycles between load and store are a per-step property, not the
    #: global ``p.modify``; wake paths must grant with this value.
    mod_dur: jnp.ndarray = None


class Protocol:
    """Base protocol plugin. Subclasses override the hooks they need."""

    name: str = ""
    #: queue-based protocols get the engine's wake pass and their wake-up
    #: responses counted against next cycle's network budget.
    uses_queue: bool = False
    #: lock-style protocols use the paper's FIXED backoff (exp cap 1);
    #: bare retry protocols use the calibrated exponential policy.
    fixed_backoff: bool = False

    # ---- static sizing ----
    def q_cap(self, p, n: int) -> int:
        """Queue slots per bank (static). Default: one per core."""
        return n

    # ---- state ----
    def init_bank_state(self, p, a: int, n: int, q_cap: int) -> Dict:
        return {}

    def init_core_state(self, p, n: int) -> Dict:
        return {}

    # ---- handlers ----
    def on_access(self, ctx: Ctx, cs: Dict, bank: Dict
                  ) -> Tuple[Dict, Dict]:
        raise NotImplementedError

    def on_wake(self, ctx: Ctx, cs: Dict, bank: Dict
                ) -> Tuple[Dict, Dict, jnp.ndarray]:
        """Fire wake-up timers; return (cs, bank, wake_load) where
        ``wake_load`` is the number of wake responses that will occupy
        network slots next cycle.  Default implementation: a single FIFO
        queue per bank (lrscwait / colibri / mwait_lock)."""
        wake_tmr = bank["wake_tmr"]
        fire = wake_tmr == 1
        wake_tmr = jnp.maximum(wake_tmr - 1, 0)
        ba = ctx.ba if ctx.ba is not None else jnp.arange(ctx.a)
        head_core = bank["qbuf"][ba, bank["qhead"]]
        # wake the head core of each firing queue
        fire_core = jnp.where(fire & (bank["qlen"] > 0), head_core, ctx.n)
        woken = jnp.zeros((ctx.n,), bool).at[fire_core].set(True, mode="drop")
        cs["st"] = jnp.where(woken, MOD, cs["st"])
        cs["tmr"] = jnp.where(woken, ctx.mod_dur, cs["tmr"])
        bank["wake_tmr"] = wake_tmr
        return cs, bank, (wake_tmr == 1).sum()
