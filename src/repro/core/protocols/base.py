"""Protocol plugin interface for the cycle-level engine (``core.sim``).

The engine owns everything protocol-agnostic: per-core timers and state
transitions, the backoff policy, worker traffic, network acceptance with
head-of-line blocking, and per-bank FIFO arbitration.  A ``Protocol``
owns only what happens when an arbitrated request reaches its bank:

* ``init_bank_state``  — the per-bank pytree (reservation slots, queues,
  lock bits, ...) carried through the ``lax.scan``.
* ``init_core_state``  — optional per-core protocol state (e.g. a ticket).
* ``on_access``        — handle this cycle's bank winners (at most one per
  bank, guaranteed by the engine's arbitration), split into acquire
  (``ctx.is_acq``) and release (``ctx.is_rel``) lanes.
* ``on_wake``          — queue-based protocols: fire wake-up timers and
  move sleeping cores back to their critical section.

Handlers are pure: they take the mutable dicts (``cs`` for per-core state
+ message/poll counters, ``bank`` for bank state) and return updated
copies.  All protocol logic stays inside masked vectorized updates over
the full core/bank arrays — a handler is exactly one of the former
``step()`` branches, lifted into a module.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax.numpy as jnp

# core states (BARWAIT: parked at a workload barrier, polling-free)
WORK, REQ, SLEEP, MOD, BACKOFF, RESP, BARWAIT = 0, 1, 2, 3, 4, 5, 6
# request phases
P_ACQ, P_REL = 0, 1
# resp_next codes
NXT_WORK_DONE, NXT_MOD, NXT_BACKOFF = 0, 1, 2

# per-bank outcome codes emitted by the kernel-fusable form
# (``fused_access``): what happens to this bank's winning core.  The
# engine maps them back onto the per-core (st, nxt) writes the masked
# ``on_access`` form performs directly — OUT_GRANT -> RESP/NXT_MOD,
# OUT_DONE -> RESP/NXT_WORK_DONE (and one latency-histogram sample),
# OUT_FAIL -> RESP/NXT_BACKOFF (and one poll), OUT_SLEEP -> SLEEP with
# the timer untouched, OUT_NONE -> no winner / no core-side effect.
OUT_NONE, OUT_GRANT, OUT_DONE, OUT_FAIL, OUT_SLEEP = 0, 1, 2, 3, 4
# recovery outcome codes emitted by ``on_timeout`` (the reservation
# watchdog, repro.faults): OUT_EVICT — a dead owner was evicted and the
# resource handed on; OUT_REDELIVER — a lost wakeup was re-sent to a
# live sleeper.  Both are bank-side events (no per-core apply; the
# evicted core is dead and the redelivered one wakes through the normal
# on_wake path), counted into the ``recoveries`` stat by the engine.
OUT_EVICT, OUT_REDELIVER = 5, 6


@dataclasses.dataclass(frozen=True)
class Contract:
    """Machine-checkable protocol contract, consumed by the static-
    analysis subsystem (``repro.analysis``).

    Every registered protocol declares one.  The model checker
    (``repro.analysis.model_check``) drives the protocol's hooks over
    exhaustive interleavings of tiny configurations and enforces the
    rules the flags enable; the trace auditor
    (``repro.analysis.trace_safety``) enforces the scatter budget.
    These are the paper's claims (polling-freedom, retry-freedom, no
    lost wakeups) stated per protocol as checkable obligations instead
    of repo folklore.
    """
    #: OUT_GRANT (or a wake) hands EXCLUSIVE ownership: at most one
    #: core may hold a bank at any time, and only the holder's release
    #: may complete.  False for bare LR/SC, where every LR is answered
    #: and non-owners only discover failure at the SC.
    exclusive_grant: bool = True
    #: retry-free: OUT_FAIL is unreachable when queues are sized for
    #: the core count (colibri's unbounded queue, amo's single access).
    retry_free: bool = False
    #: wait-class: contenders are parked with OUT_SLEEP and woken by
    #: the protocol (polling-free) instead of polling via OUT_FAIL.
    wait_class: bool = False
    #: OUT_FAIL is legal ONLY when the bank's queue is full — the
    #: lrscwait finite-q capacity collapse.  Checked against the model
    #: checker's independently tracked waiter count.
    fail_requires_full: bool = False
    #: ``on_timeout`` may act on a bank whose owner is LIVE (lrsc's
    #: unconditional reservation expiry is safe by construction: a live
    #: owner just sees its SC fail and retries).  Protocols without
    #: this flag must never return OUT_EVICT for a live owner — that is
    #: the stale-owner class of bug (PR 8).
    evict_live_safe: bool = False
    #: ``queue_depth`` counts the current holder as well as the
    #: sleepers (lrscwait/colibri/mwait grantees enqueue and are popped
    #: at release; colibri_hier grantees bypass the local queues).
    #: Only meaningful for queue protocols.
    queue_counts_holder: bool = True
    #: trace-safety budget: scatter-family ops allowed in the hot scan
    #: body on the reference config (xla_cpu, dense arbitration, no
    #: faults/telemetry/trace).  A regression that reintroduces n-lane
    #: scatters into the hot path fails the audit, not a benchmark.
    max_hot_scatters: int = 0


def mset(arr, idx, mask, val):
    """Masked scatter-set: only lanes with mask write; others dropped
    (out-of-bounds index). Avoids duplicate-index races."""
    oob = jnp.full_like(idx, arr.shape[0])
    return arr.at[jnp.where(mask, idx, oob)].set(val, mode="drop")


@dataclasses.dataclass
class Ctx:
    """Per-cycle view handed to protocol handlers.

    ``p`` is the resolved parameter namespace — fields may be traced
    scalars when running under the vmapped sweep (``core.sweep``), so
    handlers must treat them as jax values, never as Python ints for
    shapes.  ``n``/``a``/``q_cap`` are always static.
    """
    p: Any                   # resolved SimParams-like namespace
    n: int                   # cores (static)
    a: int                   # banks allocated (static upper bound)
    q_cap: int               # queue slots per bank (static)
    is_acq: jnp.ndarray      # (n,) bool — this cycle's acquire winners
    is_rel: jnp.ndarray      # (n,) bool — this cycle's release winners
    wa: jnp.ndarray          # (n,) int32 — each core's target bank
    wc: jnp.ndarray          # (n,) int32 — arange(n) core ids
    ba: jnp.ndarray = None   # (a,) int32 — arange(a) bank ids (hoisted
    #                          once per trace; handlers reuse it instead
    #                          of building a fresh iota every cycle)
    #: (a,) int32 — each bank's winning core this cycle, or ``n`` when
    #: the bank has no winner.  The engine guarantees at most one winner
    #: per bank, so protocols can update bank-side state *densely* —
    #: ``jnp.where(acq_b, f(win_core), state)`` — instead of scattering
    #: n core lanes into a-sized arrays; that turns every bank-state
    #: write from an n-lane scatter into O(a) vector ops (the dominant
    #: cost of queue protocols on CPU).  Gathering core-side values at
    #: ``jnp.minimum(win_core, n - 1)`` is safe; mask with acq_b/rel_b.
    win_core: jnp.ndarray = None
    acq_b: jnp.ndarray = None   # (a,) bool — bank winner is an acquire
    rel_b: jnp.ndarray = None   # (a,) bool — bank winner is a release
    #: (n,) int32 — each core's *current micro-op* modify duration.  The
    #: engine interprets workload programs (``core.workloads``), so the
    #: cycles between load and store are a per-step property, not the
    #: global ``p.modify``; wake paths must grant with this value.
    mod_dur: jnp.ndarray = None


@dataclasses.dataclass
class FusedCtx:
    """Bank-centric view handed to :meth:`Protocol.fused_access` — the
    kernel-fusable twin of :class:`Ctx`.

    Everything is **block-local and dense over banks**: the arrays are
    ``(a,)``-shaped for the bank block being processed (the whole bank
    range on the reference path, one tile of it inside the Pallas
    ``engine_step`` kernel), and there are NO ``(n,)``-shaped core
    arrays to write — per-core effects are *returned* as outcome codes
    and scattered by the engine.  A conforming ``fused_access``:

    * reads/writes bank state arrays sliced to this block (every bank
      array's leading dim is ``m * a`` for some per-protocol ``m``, so
      blocks slice cleanly);
    * indexes banks with a **local** iota (``jnp.arange(a)``), never a
      global bank id;
    * touches per-core state only through ``core`` (values the engine
      gathered at the winning core) and the returned ``xset`` writes;
    * treats ``p`` fields as possibly-traced scalars (inside the kernel
      they arrive through the scalar operand, not a Python closure).
    """
    p: Any                   # resolved params namespace (lat, ... traced ok)
    n: int                   # cores (static)
    a: int                   # banks in THIS block (static)
    q_cap: int               # queue slots per bank (static)
    win: jnp.ndarray         # (a,) int32 winning core id, or n if none
    acq_b: jnp.ndarray       # (a,) bool — winner is an acquire
    rel_b: jnp.ndarray       # (a,) bool — winner is a release
    #: per-core values gathered at ``min(win, n-1)`` for the fields the
    #: protocol listed in ``fused_core_fields`` (mask with acq_b/rel_b)
    core: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class FusedOut:
    """Per-bank outputs of :meth:`Protocol.fused_access`.

    ``kind`` drives the engine's generic core-side apply (see the
    ``OUT_*`` codes); ``tmr`` is the response timer for the RESP-kind
    outcomes (``OUT_GRANT``/``OUT_DONE``/``OUT_FAIL``); ``msgs`` counts
    protocol side-messages beyond the engine's 2-per-winner; ``xset``
    maps a per-core state field name to ``(values, mask)`` pairs the
    engine scatters to the winning cores (e.g. the ticket lock's drawn
    ticket).  Polls are derived: every ``OUT_FAIL`` is one poll.
    """
    kind: jnp.ndarray        # (a,) int32 OUT_* code
    tmr: jnp.ndarray         # (a,) int32 response timer for RESP kinds
    msgs: jnp.ndarray = None          # (a,) int32 extra messages (or None)
    xset: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = \
        dataclasses.field(default_factory=dict)


class Protocol:
    """Base protocol plugin. Subclasses override the hooks they need."""

    name: str = ""
    #: machine-checkable contract (see :class:`Contract`) enforced by
    #: ``python -m repro.analysis``; subclasses override.
    contract: Contract = Contract()
    #: queue-based protocols get the engine's wake pass and their wake-up
    #: responses counted against next cycle's network budget.
    uses_queue: bool = False
    #: lock-style protocols use the paper's FIXED backoff (exp cap 1);
    #: bare retry protocols use the calibrated exponential policy.
    fixed_backoff: bool = False
    #: per-core state fields ``fused_access`` needs gathered at the
    #: winning core (handed back as ``FusedCtx.core``)
    fused_core_fields: Tuple[str, ...] = ()
    #: per-core state fields ``fused_access`` may write via
    #: ``FusedOut.xset`` (static: sizes the kernel's output pytree)
    fused_xset_fields: Tuple[str, ...] = ()

    # ---- static sizing ----
    def q_cap(self, p, n: int) -> int:
        """Queue slots per bank (static). Default: one per core."""
        return n

    # ---- state ----
    def init_bank_state(self, p, a: int, n: int, q_cap: int) -> Dict:
        return {}

    def queue_depth(self, bank: Dict):
        """(a,) per-bank reservation-queue occupancy, or ``None`` for
        queueless protocols — the engine's telemetry/trace layers
        (``repro.obs``) read it once per cycle.  Default: the single
        FIFO queue's ``qlen``; hierarchical protocols override to sum
        their per-bank lanes."""
        return bank.get("qlen")

    def init_core_state(self, p, n: int) -> Dict:
        return {}

    # ---- handlers ----
    def on_access(self, ctx: Ctx, cs: Dict, bank: Dict
                  ) -> Tuple[Dict, Dict]:
        raise NotImplementedError

    def fused_access(self, fx: FusedCtx, bank: Dict
                     ) -> Tuple[Dict, FusedOut]:
        """Kernel-fusable dense bank update: the bank-state side of
        :meth:`on_access`, restated so the Pallas ``engine_step`` kernel
        (``repro.kernels.engine_step``) can trace it over one bank tile
        — block-local, dense over banks, per-core effects returned as
        ``OUT_*`` outcome codes instead of written.  Must be
        behaviourally identical to ``on_access`` + the engine's generic
        outcome apply; ``tests/test_engine_backend.py`` pins the two
        paths bit-identical across the full protocol × workload grid.
        """
        raise NotImplementedError(
            f"protocol {self.name!r} does not provide the kernel-fusable "
            f"fused_access form required by the pallas backends")

    def on_wake(self, ctx: Ctx, cs: Dict, bank: Dict
                ) -> Tuple[Dict, Dict, jnp.ndarray]:
        """Fire wake-up timers; return (cs, bank, wake_load) where
        ``wake_load`` is the number of wake responses that will occupy
        network slots next cycle.  Default implementation: a single FIFO
        queue per bank (lrscwait / colibri / mwait_lock)."""
        wake_tmr = bank["wake_tmr"]
        fire = wake_tmr == 1
        wake_tmr = jnp.maximum(wake_tmr - 1, 0)
        ba = ctx.ba if ctx.ba is not None else jnp.arange(ctx.a)
        head_core = bank["qbuf"][ba, bank["qhead"]]
        # wake the head core of each firing queue
        fire_core = jnp.where(fire & (bank["qlen"] > 0), head_core, ctx.n)
        woken = jnp.zeros((ctx.n,), bool).at[fire_core].set(True, mode="drop")
        cs["st"] = jnp.where(woken, MOD, cs["st"])
        cs["tmr"] = jnp.where(woken, ctx.mod_dur, cs["tmr"])
        bank["wake_tmr"] = wake_tmr
        return cs, bank, (wake_tmr == 1).sum()

    # ---- fault recovery (repro.faults) ----------------------------------
    def held(self, bank: Dict):
        """(a,) bool — which banks are currently *held* (a reservation,
        lock or turn is outstanding, so a dead owner wedges the bank).
        ``None`` (the default) means the protocol has no held state and
        can never get stuck — the engine then skips the watchdog
        entirely (amo: every access commits at the bank)."""
        return None

    def on_timeout(self, ctx: Ctx, cs: Dict, bank: Dict,
                   stuck_b: jnp.ndarray, killed: jnp.ndarray,
                   owner: jnp.ndarray) -> Tuple[Dict, Dict, jnp.ndarray]:
        """Reservation-watchdog recovery: called once per cycle (only
        when the plan arms ``watchdog_cyc``) with ``stuck_b`` (a,) —
        banks held with no service progress for ``watchdog_cyc`` cycles
        — the permanent-kill mask ``killed`` (n,) and the engine-tracked
        last grantee ``owner`` (a,; ``n`` = unknown).  Returns
        ``(cs, bank, kind)`` with ``kind`` (a,) an OUT_EVICT /
        OUT_REDELIVER / OUT_NONE code per bank.  Default: no recovery
        (the watchdog observes but cannot act)."""
        return cs, bank, jnp.zeros((ctx.a,), jnp.int32)


class FifoQueueRecovery:
    """``on_timeout`` for the single-FIFO sleep protocols (lrscwait /
    colibri / mwait_lock), where the queue head IS the current owner:
    a stuck bank whose head core is permanently dead is evicted (head
    advances; the reservation passes to the next waiter via a normal
    wake), and a stuck bank whose head is alive but asleep had its
    wakeup lost — re-send it.  Mixin over :class:`Protocol` subclasses
    exposing ``qbuf``/``qhead``/``qlen``/``wake_tmr`` bank state and a
    ``wake_delay(p)`` policy."""

    def held(self, bank):
        return bank["qlen"] > 0

    def on_timeout(self, ctx, cs, bank, stuck_b, killed, owner):
        q_cap, n = ctx.q_cap, ctx.n
        qhead, qlen = bank["qhead"], bank["qlen"]
        head = bank["qbuf"][ctx.ba, qhead]
        head_dead = (head >= 0) & killed[jnp.clip(head, 0, n - 1)]
        evict_b = stuck_b & head_dead
        qhead = jnp.where(evict_b, (qhead + 1) % q_cap, qhead)
        qlen = qlen - evict_b
        redeliver_b = stuck_b & ~head_dead
        # hand the reservation to the new head / re-send the lost wake
        wake_b = (evict_b | redeliver_b) & (qlen > 0)
        bank["wake_tmr"] = jnp.where(wake_b, self.wake_delay(ctx.p),
                                     bank["wake_tmr"])
        cs["msgs"] = cs["msgs"] + 2 * wake_b.sum()   # wake round trip
        bank.update(qhead=qhead, qlen=qlen)
        kind = jnp.where(evict_b, OUT_EVICT,
                         jnp.where(redeliver_b & wake_b, OUT_REDELIVER,
                                   OUT_NONE)).astype(jnp.int32)
        return cs, bank, kind
