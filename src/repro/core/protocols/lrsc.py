"""``lrsc`` — MemPool-style LR/SC with ONE reservation slot per bank.

An LR takes the slot only if free; otherwise it still gets the value but
its SC is doomed (the "sacrificed non-blocking property").  Failed SC →
backoff → full LRSC retry: the retry storm the paper measures.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.protocols.base import (NXT_BACKOFF, NXT_MOD, NXT_WORK_DONE,
                                       OUT_DONE, OUT_EVICT, OUT_FAIL,
                                       OUT_GRANT, OUT_NONE, RESP, Contract,
                                       FusedOut, Protocol)
from repro.core.protocols.registry import register


@register
class Lrsc(Protocol):
    name = "lrsc"
    # every LR is answered (a taken slot only dooms the SC), so grants
    # are NOT exclusive and the doomed-SC retry loop is expected; the
    # watchdog's unconditional slot expiry is safe for live owners
    # (their SC fails and retries — that IS the lrsc recovery path)
    contract = Contract(exclusive_grant=False, retry_free=False,
                        wait_class=False, evict_live_safe=True,
                        max_hot_scatters=2)

    def init_bank_state(self, p, a, n, q_cap):
        return dict(
            resv_core=jnp.full((a,), -1, jnp.int32),
            resv_valid=jnp.zeros((a,), bool),
        )

    def on_access(self, ctx, cs, bank):
        p, wa = ctx.p, ctx.wa
        is_acq, is_rel = ctx.is_acq, ctx.is_rel
        acq_b, rel_b, win = ctx.acq_b, ctx.rel_b, ctx.win_core
        resv_core, resv_valid = bank["resv_core"], bank["resv_valid"]
        # bank state updates are dense over banks: the engine guarantees
        # at most one winner per bank, and a bank's winner is either an
        # acquire or a release, so the acquire- and release-side writes
        # never touch the same bank this cycle
        got_resv_b = acq_b & ~resv_valid
        resv_core = jnp.where(got_resv_b, win, resv_core)
        cs["st"] = jnp.where(is_acq, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_acq, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(is_acq, NXT_MOD, cs["nxt"])
        # SC: succeeds iff holding the reservation; owner's SC releases it
        owner_b = rel_b & resv_valid & (resv_core == win)
        owner = is_rel & owner_b[wa]
        fail = is_rel & ~owner
        resv_valid = (resv_valid | got_resv_b) & ~owner_b
        cs["st"] = jnp.where(is_rel, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_rel, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(owner, NXT_WORK_DONE,
                              jnp.where(fail, NXT_BACKOFF, cs["nxt"]))
        cs["polls"] = cs["polls"] + fail.sum()
        bank["resv_core"], bank["resv_valid"] = resv_core, resv_valid
        return cs, bank

    def fused_access(self, fx, bank):
        resv_core, resv_valid = bank["resv_core"], bank["resv_valid"]
        # LR: always answered (a taken slot just dooms the later SC)
        got_resv_b = fx.acq_b & ~resv_valid
        resv_core = jnp.where(got_resv_b, fx.win, resv_core)
        # SC: succeeds iff holding the reservation; owner's SC releases it
        owner_b = fx.rel_b & resv_valid & (resv_core == fx.win)
        resv_valid = (resv_valid | got_resv_b) & ~owner_b
        kind = jnp.where(
            fx.acq_b, OUT_GRANT,
            jnp.where(owner_b, OUT_DONE,
                      jnp.where(fx.rel_b, OUT_FAIL, OUT_NONE))
        ).astype(jnp.int32)
        tmr = jnp.full_like(kind, fx.p.lat)
        bank = dict(bank, resv_core=resv_core, resv_valid=resv_valid)
        return bank, FusedOut(kind=kind, tmr=tmr)

    # ---- fault recovery (repro.faults): expire the stale slot -----------
    # hardware reservations time out; a slot pinned with no successful
    # SC for watchdog_cyc is expired unconditionally — safe by
    # construction (a live owner just sees its SC fail and retries,
    # which IS the lrsc recovery path), and it un-wedges the doomed-SC
    # livelock a dead reservation holder otherwise causes forever
    def held(self, bank):
        return bank["resv_valid"]

    def on_timeout(self, ctx, cs, bank, stuck_b, killed, owner):
        bank["resv_valid"] = bank["resv_valid"] & ~stuck_b
        return cs, bank, jnp.where(stuck_b, OUT_EVICT,
                                   OUT_NONE).astype(jnp.int32)
