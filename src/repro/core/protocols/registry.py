"""String-keyed registry of synchronization protocol plugins.

Adding a protocol is one module: subclass ``base.Protocol``, decorate the
class (or call ``register`` on an instance), import it from
``protocols/__init__``.  The engine, sweep runner, and benchmarks all
resolve protocols by name through ``get``.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.protocols.base import Protocol

_REGISTRY: Dict[str, Protocol] = {}


def register(proto):
    """Register a Protocol subclass or instance under its ``name``."""
    inst = proto() if isinstance(proto, type) else proto
    if not inst.name:
        raise ValueError(f"protocol {proto!r} has no name")
    if inst.name in _REGISTRY:
        raise ValueError(f"duplicate protocol name: {inst.name}")
    _REGISTRY[inst.name] = inst
    return proto


def get(name: str) -> Protocol:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown protocol {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
