"""``lrscwait`` — LRwait/SCwait with q reservation slots per bank.

Linearizes contending RMWs at the LR: an LRwait to a non-empty queue
enqueues and the core sleeps (no polling); the SCwait always succeeds and
wakes the next head.  With q ≥ N this is LRSCwait_ideal; an LRwait to a
FULL queue fails immediately and falls back to retry traffic (the
capacity collapse of Fig. 3's ``LRSCwait_q`` lines).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.protocols.base import (NXT_BACKOFF, NXT_MOD, NXT_WORK_DONE,
                                       OUT_DONE, OUT_FAIL, OUT_GRANT,
                                       OUT_NONE, OUT_SLEEP, RESP, SLEEP,
                                       Contract, FifoQueueRecovery, FusedOut,
                                       Protocol)
from repro.core.protocols.registry import register


@register
class LrscWait(FifoQueueRecovery, Protocol):
    # the FIFO watchdog recovery applies directly: the queue head IS the
    # reservation owner (grantees enqueue too), so evicting a dead head
    # hands the reservation to the next waiter (repro.faults)
    name = "lrscwait"
    uses_queue = True
    # wait-class: contenders sleep in the bank queue.  OUT_FAIL exists
    # but ONLY at a full queue (the finite-q capacity collapse of
    # Fig. 3) — the model checker verifies every FAIL against its own
    # waiter count.  Grantees enqueue too, so queue_depth counts the
    # holder.
    contract = Contract(exclusive_grant=True, wait_class=True,
                        fail_requires_full=True, queue_counts_holder=True,
                        max_hot_scatters=4)
    #: colibri: SuccessorUpdate on enqueue-behind + WakeUpRequest round trip
    successor_updates = False

    def q_cap(self, p, n):
        return min(p.q_slots, n)

    def wake_delay(self, p):
        return p.lat

    def init_bank_state(self, p, a, n, q_cap):
        return dict(
            qbuf=jnp.full((a, q_cap), -1, jnp.int32),
            qhead=jnp.zeros((a,), jnp.int32),
            qlen=jnp.zeros((a,), jnp.int32),
            wake_tmr=jnp.zeros((a,), jnp.int32),
        )

    def on_access(self, ctx, cs, bank):
        p, wa, q_cap = ctx.p, ctx.wa, ctx.q_cap
        is_acq, is_rel = ctx.is_acq, ctx.is_rel
        acq_b, rel_b, win = ctx.acq_b, ctx.rel_b, ctx.win_core
        qbuf, qhead, qlen = bank["qbuf"], bank["qhead"], bank["qlen"]
        empty = qlen[wa] == 0
        full = qlen[wa] >= q_cap
        grant = is_acq & empty
        enq = is_acq & ~empty & ~full
        rej = is_acq & full                  # finite-q immediate fail
        # bank-side queue updates are dense: at most one winner per bank
        # (either an acquire or a release), so enqueue/dequeue never
        # race within a cycle and the scatters collapse to vector ops
        put_b = acq_b & (qlen < q_cap)
        slot_b = (qhead + qlen) % q_cap
        qbuf = qbuf.at[jnp.where(put_b, ctx.ba, ctx.a), slot_b].set(
            win, mode="drop")
        cs["st"] = jnp.where(grant, RESP, jnp.where(enq, SLEEP, cs["st"]))
        cs["tmr"] = jnp.where(grant, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(grant, NXT_MOD, cs["nxt"])
        cs["st"] = jnp.where(rej, RESP, cs["st"])
        cs["tmr"] = jnp.where(rej, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(rej, NXT_BACKOFF, cs["nxt"])
        cs["polls"] = cs["polls"] + rej.sum()
        # colibri SuccessorUpdate traffic on enqueue-behind
        if self.successor_updates:
            cs["msgs"] = cs["msgs"] + 2 * enq.sum()
        # SCwait: always valid (only the head ever gets a response)
        qhead = jnp.where(rel_b, (qhead + 1) % q_cap, qhead)
        qlen = qlen + put_b - rel_b
        cs["st"] = jnp.where(is_rel, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_rel, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(is_rel, NXT_WORK_DONE, cs["nxt"])
        pend_b = rel_b & (qlen > 0)
        bank["wake_tmr"] = jnp.where(pend_b, self.wake_delay(p),
                                     bank["wake_tmr"])
        if self.successor_updates:
            cs["msgs"] = cs["msgs"] + 2 * pend_b.sum()  # WakeUpReq + resp
        bank["qbuf"], bank["qhead"], bank["qlen"] = qbuf, qhead, qlen
        return cs, bank

    def fused_access(self, fx, bank):
        q_cap = fx.q_cap
        qbuf, qhead, qlen = bank["qbuf"], bank["qhead"], bank["qlen"]
        ba = jnp.arange(qbuf.shape[0], dtype=jnp.int32)   # block-local
        empty_b = qlen == 0
        full_b = qlen >= q_cap
        grant_b = fx.acq_b & empty_b
        enq_b = fx.acq_b & ~empty_b & ~full_b
        rej_b = fx.acq_b & full_b                # finite-q immediate fail
        put_b = fx.acq_b & ~full_b
        slot_b = (qhead + qlen) % q_cap
        qbuf = qbuf.at[jnp.where(put_b, ba, qbuf.shape[0]), slot_b].set(
            fx.win, mode="drop")
        kind = jnp.where(
            grant_b, OUT_GRANT,
            jnp.where(enq_b, OUT_SLEEP,
                      jnp.where(rej_b, OUT_FAIL,
                                jnp.where(fx.rel_b, OUT_DONE, OUT_NONE)))
        ).astype(jnp.int32)
        tmr = jnp.full_like(kind, fx.p.lat)
        # SCwait: always valid (only the head ever gets a response)
        qhead = jnp.where(fx.rel_b, (qhead + 1) % q_cap, qhead)
        qlen = qlen + put_b - fx.rel_b
        pend_b = fx.rel_b & (qlen > 0)
        wake_tmr = jnp.where(pend_b, self.wake_delay(fx.p),
                             bank["wake_tmr"])
        msgs = None
        if self.successor_updates:               # SuccUpdate + WakeUpReq RTs
            msgs = 2 * (enq_b.astype(jnp.int32) + pend_b.astype(jnp.int32))
        bank = dict(bank, qbuf=qbuf, qhead=qhead, qlen=qlen,
                    wake_tmr=wake_tmr)
        return bank, FusedOut(kind=kind, tmr=tmr, msgs=msgs)
