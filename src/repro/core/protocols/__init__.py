"""Synchronization protocol plugins for the cycle-level engine.

Importing this package registers every built-in protocol:

=============  ==========================================================
``amo``        single-instruction atomic add (roofline)
``lrsc``       MemPool LR/SC, one sticky reservation slot (retry storms)
``lrscwait``   q reservation slots, linearized at the LR
``colibri``    LRSCwait with an unbounded distributed queue
``colibri_hier``  two-level Colibri: cluster-local queues + global queue
``amo_lock``   test&set spin lock with backoff
``lrsc_lock``  spin lock from an LR/SC pair (two round trips/attempt)
``ticket_lock``  FIFO spin lock (ticket dispenser; polling but fair)
``mwait_lock`` MCS queue lock, waiters sleep via Mwait (polling-free)
``hw_event``   per-cluster hardware event unit: clock-gated wait,
               1-cycle intra-cluster wakeup, tree combine across levels
``nb_feb``     full/empty-bit atomics (retry-free universal primitive)
=============  ==========================================================

New protocols: subclass :class:`~repro.core.protocols.base.Protocol`,
decorate with :func:`~repro.core.protocols.registry.register`, and import
the module here.  The engine (``core.sim``), the vmapped sweep runner
(``core.sweep``), and the benchmarks resolve plugins by name.
"""
from repro.core.protocols import (amo, colibri, colibri_hier, hw_event,
                                  locks, lrsc, lrscwait, mwait, nb_feb)
from repro.core.protocols.base import Ctx, Protocol
from repro.core.protocols.registry import get, names, register

__all__ = ["Ctx", "Protocol", "get", "names", "register",
           "amo", "colibri", "colibri_hier", "hw_event", "locks", "lrsc",
           "lrscwait", "mwait", "nb_feb"]
