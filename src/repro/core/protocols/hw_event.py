"""``hw_event`` — per-cluster hardware barrier/event unit (Glaser et
al., arXiv:2004.06662) over a hierarchical topology.

Each cluster owns a dedicated synchronization unit next to its cores: a
waiter registers with its **local** event unit and clock-gates (the
unit holds the wait line; registering costs no NoC traffic beyond the
request itself, and the parked core burns sleep-rate energy).  When the
resource frees inside the cluster, the unit raises the wakeup line — a
**single-cycle intra-cluster broadcast**, an order of magnitude below
the ``lat``-cycle NoC round trip of a message-based wake.  Across
clusters the units form a combining tree: a cluster with waiters
asserts one upward combine signal (1 message — a line toggle, not a
round trip), and a releasing cluster whose local waiters drained hands
the resource to the next registered cluster over the NoC
(``lat + 1`` — the cross-cluster wire plus the receiving unit's
broadcast cycle).

Structurally this is ``colibri_hier`` with the reservation Qnodes
replaced by hardware event units — the cluster-local queues and the
global FIFO of clusters survive, but there is **no turn budget**: a
hardware unit serves its cluster until the local wait set drains
(service order inside a cluster is FIFO; cross-cluster fairness is
FIFO over *drain epochs*, the documented behaviour of a wired event
unit, which has no counter to meter turns with).  Retry-free and
polling-free by construction; grantees bypass the local queues, so
``queue_depth`` counts sleepers only (``queue_counts_holder=False``).

The natural host is a hierarchical topology (``Spec(topology=
"cluster2", ...)``): the unit's cluster is then exactly the cluster
the NoC routes the core through (same block placement), so local wakes
really are the messages the topology's link model keeps off the
cross-cluster links.  On the flat topology the plugin still runs (the
event tree degenerates to ``n_groups`` units on one crossbar).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.protocols.base import (MOD, NXT_MOD, NXT_WORK_DONE, OUT_DONE,
                                       OUT_EVICT, OUT_GRANT, OUT_NONE,
                                       OUT_REDELIVER, OUT_SLEEP, RESP, SLEEP,
                                       Contract, FusedOut, Protocol)
from repro.core.protocols.registry import register


@register
class HwEvent(Protocol):
    name = "hw_event"
    uses_queue = True
    local_delay = 1          # single-cycle intra-cluster wakeup broadcast
    contract = Contract(exclusive_grant=True, wait_class=True,
                        retry_free=True, queue_counts_holder=False,
                        max_hot_scatters=10)  # measured 8 (+2 headroom)

    @staticmethod
    def _geom(p, n):
        """(units, cluster_size, local queue capacity) — all static.
        One event unit per topology cluster when the machine is
        hierarchical; one per ``n_groups`` on the flat crossbar."""
        knob = (p.clusters if getattr(p, "topology", "flat") != "flat"
                else p.n_groups)
        g = max(1, min(knob, n))
        gsz = max(1, n // g)
        cap_l = max(gsz, n - (g - 1) * gsz)  # last cluster may be larger
        return g, gsz, cap_l

    def init_bank_state(self, p, a, n, q_cap):
        g, _, cap_l = self._geom(p, n)
        return dict(
            lqbuf=jnp.full((a * g, cap_l), -1, jnp.int32),
            lqhead=jnp.zeros((a * g,), jnp.int32),
            lqlen=jnp.zeros((a * g,), jnp.int32),
            ggq=jnp.full((a, g), -1, jnp.int32),    # FIFO of cluster ids
            gqhead=jnp.zeros((a,), jnp.int32),
            gqlen=jnp.zeros((a,), jnp.int32),
            g_inq=jnp.zeros((a, g), bool),
            cur_grp=jnp.full((a,), -1, jnp.int32),  # cluster holding it
            wake_tmr=jnp.zeros((a,), jnp.int32),
            # CLUSTER whose local wait set to wake (group id, not the
            # flat (addr, cluster) queue id — kernel-tiling safe, same
            # as colibri_hier's wake_grp)
            wake_grp=jnp.zeros((a,), jnp.int32),
        )

    def queue_depth(self, bank):
        a = bank["cur_grp"].shape[0]
        return bank["lqlen"].reshape(a, -1).sum(axis=1)

    def on_access(self, ctx, cs, bank):
        p, wa = ctx.p, ctx.wa
        is_acq, is_rel = ctx.is_acq, ctx.is_rel
        acq_b, rel_b, win, ba = ctx.acq_b, ctx.rel_b, ctx.win_core, ctx.ba
        G, gsz, cap_l = self._geom(p, ctx.n)
        lqbuf, lqhead, lqlen = bank["lqbuf"], bank["lqhead"], bank["lqlen"]
        ggq, gqhead, gqlen = bank["ggq"], bank["gqhead"], bank["gqlen"]
        g_inq, cur_grp = bank["g_inq"], bank["cur_grp"]
        wake_tmr, wake_grp = bank["wake_tmr"], bank["wake_grp"]

        # winning core's cluster and flat (addr, cluster) wait-set id;
        # all bank-state writes are dense (≤1 winner per bank)
        g_b = jnp.minimum(jnp.minimum(win, ctx.n - 1) // gsz, G - 1)
        lq_b = ba * G + g_b
        oob_a, oob_lq = ctx.a, ctx.a * G

        # ---- acquire ----
        idle_b = cur_grp < 0
        idle = idle_b[wa]
        grant = is_acq & idle
        grant_b = acq_b & idle_b
        cur_grp = jnp.where(grant_b, g_b, cur_grp)
        cs["st"] = jnp.where(grant, RESP, cs["st"])
        cs["tmr"] = jnp.where(grant, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(grant, NXT_MOD, cs["nxt"])
        # register with the local event unit and clock-gate.  The unit
        # is wired to its cores: registration is absorbed into the
        # request the core already sent (no extra NoC messages — the
        # Qnode SuccessorUpdate this replaces cost 1).
        enq = is_acq & ~idle
        enq_b = acq_b & ~idle_b
        slot_b = (lqhead[lq_b] + lqlen[lq_b]) % cap_l
        put_lq = jnp.where(enq_b, lq_b, oob_lq)
        lqbuf = lqbuf.at[put_lq, slot_b].set(win, mode="drop")
        lqlen = lqlen.at[put_lq].add(1, mode="drop")
        cs["st"] = jnp.where(enq, SLEEP, cs["st"])
        # first waiter of a non-holding cluster asserts the upward
        # combine line: ONE message up the tree, no round trip
        reg_b = enq_b & (cur_grp != g_b) & ~g_inq[ba, g_b]
        gslot_b = (gqhead + gqlen) % G
        reg_a = jnp.where(reg_b, ba, oob_a)
        ggq = ggq.at[reg_a, gslot_b].set(g_b, mode="drop")
        gqlen = gqlen + reg_b
        g_inq = g_inq.at[reg_a, g_b].set(True, mode="drop")
        cs["msgs"] = cs["msgs"] + reg_b.sum()

        # ---- release (releaser's cluster always == cur_grp[wa]) ----
        # the unit serves its cluster until the local wait set drains:
        # single-cycle broadcast wake, zero NoC messages
        more_local_b = rel_b & (lqlen[lq_b] > 0)
        wake_grp = jnp.where(more_local_b, g_b, wake_grp)
        wake_tmr = jnp.where(more_local_b, self.local_delay, wake_tmr)
        # drained: hand the resource to the next registered cluster
        # (cross-cluster wire + the receiving unit's broadcast cycle)
        end_turn_b = rel_b & (lqlen[lq_b] == 0)
        have_next_b = end_turn_b & (gqlen > 0)
        next_g_b = ggq[ba, gqhead]
        cur_grp = jnp.where(have_next_b, next_g_b, cur_grp)
        g_inq = g_inq.at[jnp.where(have_next_b, ba, oob_a), next_g_b].set(
            False, mode="drop")
        gqhead = jnp.where(have_next_b, (gqhead + 1) % G, gqhead)
        gqlen = gqlen - have_next_b
        wake_grp = jnp.where(have_next_b, next_g_b, wake_grp)
        wake_tmr = jnp.where(have_next_b, p.lat + 1, wake_tmr)
        cs["msgs"] = cs["msgs"] + 2 * have_next_b.sum()  # x-cluster handoff
        # nothing left anywhere: the resource goes idle
        cur_grp = jnp.where(end_turn_b & ~have_next_b, -1, cur_grp)
        cs["st"] = jnp.where(is_rel, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_rel, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(is_rel, NXT_WORK_DONE, cs["nxt"])

        bank.update(lqbuf=lqbuf, lqhead=lqhead, lqlen=lqlen, ggq=ggq,
                    gqhead=gqhead, gqlen=gqlen, g_inq=g_inq,
                    cur_grp=cur_grp, wake_tmr=wake_tmr, wake_grp=wake_grp)
        return cs, bank

    def fused_access(self, fx, bank):
        # on_access restated block-locally for the Pallas engine-step
        # kernel: local iota bank ids, per-core effects as OUT_* codes
        G, gsz, cap_l = self._geom(fx.p, fx.n)
        lqbuf, lqhead, lqlen = bank["lqbuf"], bank["lqhead"], bank["lqlen"]
        ggq, gqhead, gqlen = bank["ggq"], bank["gqhead"], bank["gqlen"]
        g_inq, cur_grp = bank["g_inq"], bank["cur_grp"]
        wake_tmr, wake_grp = bank["wake_tmr"], bank["wake_grp"]
        a = cur_grp.shape[0]                     # banks in this block
        ba = jnp.arange(a, dtype=jnp.int32)
        g_b = jnp.minimum(jnp.minimum(fx.win, fx.n - 1) // gsz, G - 1)
        lq_b = ba * G + g_b
        oob_a, oob_lq = a, a * G

        # ---- acquire ----
        idle_b = cur_grp < 0
        grant_b = fx.acq_b & idle_b
        cur_grp = jnp.where(grant_b, g_b, cur_grp)
        enq_b = fx.acq_b & ~idle_b
        slot_b = (lqhead[lq_b] + lqlen[lq_b]) % cap_l
        put_lq = jnp.where(enq_b, lq_b, oob_lq)
        lqbuf = lqbuf.at[put_lq, slot_b].set(fx.win, mode="drop")
        lqlen = lqlen.at[put_lq].add(1, mode="drop")
        reg_b = enq_b & (cur_grp != g_b) & ~g_inq[ba, g_b]
        gslot_b = (gqhead + gqlen) % G
        reg_a = jnp.where(reg_b, ba, oob_a)
        ggq = ggq.at[reg_a, gslot_b].set(g_b, mode="drop")
        gqlen = gqlen + reg_b
        g_inq = g_inq.at[reg_a, g_b].set(True, mode="drop")
        msgs = reg_b.astype(jnp.int32)           # upward combine line

        # ---- release ----
        more_local_b = fx.rel_b & (lqlen[lq_b] > 0)
        wake_grp = jnp.where(more_local_b, g_b, wake_grp)
        wake_tmr = jnp.where(more_local_b, self.local_delay, wake_tmr)
        end_turn_b = fx.rel_b & (lqlen[lq_b] == 0)
        have_next_b = end_turn_b & (gqlen > 0)
        next_g_b = ggq[ba, gqhead]
        cur_grp = jnp.where(have_next_b, next_g_b, cur_grp)
        g_inq = g_inq.at[jnp.where(have_next_b, ba, oob_a), next_g_b].set(
            False, mode="drop")
        gqhead = jnp.where(have_next_b, (gqhead + 1) % G, gqhead)
        gqlen = gqlen - have_next_b
        wake_grp = jnp.where(have_next_b, next_g_b, wake_grp)
        wake_tmr = jnp.where(have_next_b, fx.p.lat + 1, wake_tmr)
        msgs = msgs + 2 * have_next_b            # cross-cluster handoff
        cur_grp = jnp.where(end_turn_b & ~have_next_b, -1, cur_grp)

        kind = jnp.where(
            grant_b, OUT_GRANT,
            jnp.where(enq_b, OUT_SLEEP,
                      jnp.where(fx.rel_b, OUT_DONE, OUT_NONE))
        ).astype(jnp.int32)
        tmr = jnp.full_like(kind, fx.p.lat)
        bank = dict(bank, lqbuf=lqbuf, lqhead=lqhead, lqlen=lqlen, ggq=ggq,
                    gqhead=gqhead, gqlen=gqlen, g_inq=g_inq,
                    cur_grp=cur_grp, wake_tmr=wake_tmr, wake_grp=wake_grp)
        return bank, FusedOut(kind=kind, tmr=tmr, msgs=msgs.astype(jnp.int32))

    # ---- fault recovery (repro.faults) ----------------------------------
    # The holder is not queued (grantees bypass the wait sets), so a dead
    # owner's eviction REPLAYS the release handoff it would have issued:
    # wake the holding cluster's next local waiter, else hand the
    # resource to the next registered cluster, else go idle.
    def held(self, bank):
        return bank["cur_grp"] >= 0

    def on_timeout(self, ctx, cs, bank, stuck_b, killed, owner):
        p, n, ba = ctx.p, ctx.n, ctx.ba
        G, _, _ = self._geom(p, n)
        lqlen = bank["lqlen"]
        ggq, gqhead, gqlen = bank["ggq"], bank["gqhead"], bank["gqlen"]
        g_inq, cur_grp = bank["g_inq"], bank["cur_grp"]
        wake_tmr, wake_grp = bank["wake_tmr"], bank["wake_grp"]
        own_dead = (owner < n) & killed[jnp.clip(owner, 0, n - 1)]
        evict_b = stuck_b & own_dead
        g = jnp.clip(cur_grp, 0, G - 1)
        more_local = evict_b & (lqlen[ba * G + g] > 0)
        wake_grp = jnp.where(more_local, g, wake_grp)
        wake_tmr = jnp.where(more_local, self.local_delay, wake_tmr)
        end_b = evict_b & ~more_local
        have_next = end_b & (gqlen > 0)
        next_g = ggq[ba, gqhead]
        cur_grp = jnp.where(have_next, next_g, cur_grp)
        g_inq = g_inq.at[jnp.where(have_next, ba, ctx.a), next_g].set(
            False, mode="drop")
        gqhead = jnp.where(have_next, (gqhead + 1) % G, gqhead)
        gqlen = gqlen - have_next
        wake_grp = jnp.where(have_next, next_g, wake_grp)
        wake_tmr = jnp.where(have_next, p.lat + 1, wake_tmr)
        cur_grp = jnp.where(end_b & ~have_next, -1, cur_grp)
        # live owner, no progress: the recorded wake was lost — re-raise
        redeliver_b = (stuck_b & ~own_dead
                       & (lqlen[ba * G + wake_grp] > 0))
        wake_tmr = jnp.where(redeliver_b, self.local_delay, wake_tmr)
        cs["msgs"] = cs["msgs"] + 2 * (more_local | have_next
                                       | redeliver_b).sum()
        bank.update(ggq=ggq, gqhead=gqhead, gqlen=gqlen, g_inq=g_inq,
                    cur_grp=cur_grp, wake_tmr=wake_tmr, wake_grp=wake_grp)
        kind = jnp.where(evict_b, OUT_EVICT,
                         jnp.where(redeliver_b, OUT_REDELIVER,
                                   OUT_NONE)).astype(jnp.int32)
        return cs, bank, kind

    def on_wake(self, ctx, cs, bank):
        G, _, cap_l = self._geom(ctx.p, ctx.n)
        wake_tmr = bank["wake_tmr"]
        ba = ctx.ba if ctx.ba is not None else jnp.arange(ctx.a)
        wq = ba * G + bank["wake_grp"]      # flat local wait-set id
        lqbuf, lqhead, lqlen = bank["lqbuf"], bank["lqhead"], bank["lqlen"]
        fire = wake_tmr == 1
        wake_tmr = jnp.maximum(wake_tmr - 1, 0)
        head_core = lqbuf[wq, lqhead[wq]]
        valid = fire & (lqlen[wq] > 0)
        fire_core = jnp.where(valid, head_core, ctx.n)
        woken = jnp.zeros((ctx.n,), bool).at[fire_core].set(True, mode="drop")
        cs["st"] = jnp.where(woken, MOD, cs["st"])
        cs["tmr"] = jnp.where(woken, ctx.mod_dur, cs["tmr"])
        # pop the woken head: it is now the resource's active holder
        oob = jnp.where(valid, wq, ctx.a * G)
        lqhead = (lqhead.at[oob].add(1, mode="drop")) % cap_l
        lqlen = lqlen.at[oob].add(-1, mode="drop")
        bank.update(wake_tmr=wake_tmr, lqhead=lqhead, lqlen=lqlen)
        return cs, bank, (wake_tmr == 1).sum()
