"""``mwait_lock`` — MCS queue lock where waiters sleep via Mwait.

Contenders enqueue at the bank and sleep (Mwait setup costs messages);
the releaser wakes its successor directly — polling-free, but every
critical section pays lock-management round trips that the direct
LRSCwait RMW avoids.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.protocols.base import (NXT_MOD, NXT_WORK_DONE, OUT_DONE,
                                       OUT_GRANT, OUT_NONE, OUT_SLEEP, RESP,
                                       SLEEP, Contract, FifoQueueRecovery,
                                       FusedOut, Protocol)
from repro.core.protocols.registry import register


@register
class MwaitLock(FifoQueueRecovery, Protocol):
    # same queue shape as lrscwait (head = lock holder), so the FIFO
    # watchdog recovery applies: evict a dead holder, wake the successor
    name = "mwait_lock"
    uses_queue = True
    fixed_backoff = True
    # MCS-style queue sized one slot per core: contenders always park,
    # never poll — fully retry-free; the holder stays at the queue head
    # until its release pops it
    contract = Contract(exclusive_grant=True, wait_class=True,
                        retry_free=True, queue_counts_holder=True,
                        max_hot_scatters=4)

    def wake_delay(self, p):
        # successor wake: one response latency + Qnode bounce (the same
        # cost the release-path wake pays)
        return p.lat + 2

    def init_bank_state(self, p, a, n, q_cap):
        return dict(
            qbuf=jnp.full((a, q_cap), -1, jnp.int32),
            qhead=jnp.zeros((a,), jnp.int32),
            qlen=jnp.zeros((a,), jnp.int32),
            wake_tmr=jnp.zeros((a,), jnp.int32),
        )

    def on_access(self, ctx, cs, bank):
        p, wa, q_cap = ctx.p, ctx.wa, ctx.q_cap
        is_acq, is_rel = ctx.is_acq, ctx.is_rel
        acq_b, rel_b, win = ctx.acq_b, ctx.rel_b, ctx.win_core
        qbuf, qhead, qlen = bank["qbuf"], bank["qhead"], bank["qlen"]
        empty = qlen[wa] == 0
        grant = is_acq & empty
        enq = is_acq & ~empty
        # dense bank-side queue updates (≤1 winner per bank — see base)
        slot_b = (qhead + qlen) % q_cap
        qbuf = qbuf.at[jnp.where(acq_b, ctx.ba, ctx.a), slot_b].set(
            win, mode="drop")
        cs["st"] = jnp.where(grant, RESP, jnp.where(enq, SLEEP, cs["st"]))
        cs["tmr"] = jnp.where(grant, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(grant, NXT_MOD, cs["nxt"])
        cs["msgs"] = cs["msgs"] + 2 * enq.sum()          # Mwait setup
        qhead = jnp.where(rel_b, (qhead + 1) % q_cap, qhead)
        qlen = qlen + acq_b - rel_b
        cs["st"] = jnp.where(is_rel, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_rel, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(is_rel, NXT_WORK_DONE, cs["nxt"])
        pend_b = rel_b & (qlen > 0)
        # releaser wakes the successor: one response latency + Qnode bounce
        bank["wake_tmr"] = jnp.where(pend_b, p.lat + 2, bank["wake_tmr"])
        bank["qbuf"], bank["qhead"], bank["qlen"] = qbuf, qhead, qlen
        return cs, bank

    def fused_access(self, fx, bank):
        q_cap = fx.q_cap
        qbuf, qhead, qlen = bank["qbuf"], bank["qhead"], bank["qlen"]
        ba = jnp.arange(qbuf.shape[0], dtype=jnp.int32)   # block-local
        empty_b = qlen == 0
        grant_b = fx.acq_b & empty_b
        enq_b = fx.acq_b & ~empty_b
        slot_b = (qhead + qlen) % q_cap
        qbuf = qbuf.at[jnp.where(fx.acq_b, ba, qbuf.shape[0]), slot_b].set(
            fx.win, mode="drop")
        kind = jnp.where(
            grant_b, OUT_GRANT,
            jnp.where(enq_b, OUT_SLEEP,
                      jnp.where(fx.rel_b, OUT_DONE, OUT_NONE))
        ).astype(jnp.int32)
        tmr = jnp.full_like(kind, fx.p.lat)
        msgs = 2 * enq_b.astype(jnp.int32)               # Mwait setup
        qhead = jnp.where(fx.rel_b, (qhead + 1) % q_cap, qhead)
        qlen = qlen + fx.acq_b - fx.rel_b
        pend_b = fx.rel_b & (qlen > 0)
        wake_tmr = jnp.where(pend_b, fx.p.lat + 2, bank["wake_tmr"])
        bank = dict(bank, qbuf=qbuf, qhead=qhead, qlen=qlen,
                    wake_tmr=wake_tmr)
        return bank, FusedOut(kind=kind, tmr=tmr, msgs=msgs)
