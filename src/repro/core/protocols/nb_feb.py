"""``nb_feb`` — full/empty-bit atomics as a retry-free universal
primitive (NB-FEB, arXiv:0811.1304).

Every synchronization word carries a hardware **full/empty bit** (FEB).
An acquire is a ``readFE``: when the bit is *full* the word is handed
over and the bit flips to empty in the same bank access — no retry is
ever possible, the bit test and the claim are one atomic port
operation.  When the bit is empty the requester is appended to the
bank-side waiter FIFO and parks clock-gated (the *waiting* NB-FEB
variant: the paper's non-blocking forms return the bit state instead,
but on a manycore the polling-free wait is exactly what LRSCwait
demonstrates, so this plugin models the wait-class member of the same
family).  The release is a ``writeEF``: it stores, then either hands
the word straight to the FIFO head (bit stays empty — ownership moves,
the bit never lies) or sets the bit full when nobody waits.

Compared to ``lrscwait`` this is the capacity-collapse-free universal
form: the FEB is one bit per word and the waiter FIFO is sized for one
outstanding op per core, so there is NO full-queue ``OUT_FAIL`` path at
any core count — ``retry_free`` is part of the declared contract, not a
parameter choice.  The invariant the model checker certifies is that
the bit always tracks the queue: ``feb == (qlen == 0)`` in every
reachable state (the bit is the hardware-visible shadow of "no holder
and no waiters").
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.protocols.base import (NXT_MOD, NXT_WORK_DONE, OUT_DONE,
                                       OUT_GRANT, OUT_NONE, OUT_SLEEP, RESP,
                                       SLEEP, Contract, FifoQueueRecovery,
                                       FusedOut, Protocol)
from repro.core.protocols.registry import register


@register
class NbFeb(FifoQueueRecovery, Protocol):
    # single FIFO whose head is the owner (grantees enqueue too), so the
    # stock FIFO watchdog recovery applies; on_timeout only additionally
    # re-derives the bit after an eviction (see below)
    name = "nb_feb"
    uses_queue = True
    contract = Contract(exclusive_grant=True, wait_class=True,
                        retry_free=True, queue_counts_holder=True,
                        max_hot_scatters=4)   # measured 2 (+2 headroom)

    def q_cap(self, p, n):
        # the FIFO holds at most one entry per core (each core has one
        # outstanding op); q_slots does not apply — there is no finite-q
        # variant of a one-bit primitive
        return n

    def wake_delay(self, p):
        return p.lat

    def init_bank_state(self, p, a, n, q_cap):
        return dict(
            feb=jnp.ones((a,), bool),            # full/empty bit: full=free
            qbuf=jnp.full((a, q_cap), -1, jnp.int32),
            qhead=jnp.zeros((a,), jnp.int32),
            qlen=jnp.zeros((a,), jnp.int32),
            wake_tmr=jnp.zeros((a,), jnp.int32),
        )

    def on_access(self, ctx, cs, bank):
        p, wa, q_cap = ctx.p, ctx.wa, ctx.q_cap
        is_acq, is_rel = ctx.is_acq, ctx.is_rel
        acq_b, rel_b, win = ctx.acq_b, ctx.rel_b, ctx.win_core
        feb = bank["feb"]
        qbuf, qhead, qlen = bank["qbuf"], bank["qhead"], bank["qlen"]
        # readFE: bit full -> take the word (bit flips empty); bit empty
        # -> join the waiter FIFO and sleep.  Never fails.
        grant = is_acq & feb[wa]
        enq = is_acq & ~feb[wa]
        # every acquirer enters the FIFO (the grantee at its head), so
        # head == owner and release order is the service order
        put_b = acq_b
        slot_b = (qhead + qlen) % q_cap
        qbuf = qbuf.at[jnp.where(put_b, ctx.ba, ctx.a), slot_b].set(
            win, mode="drop")
        feb = jnp.where(acq_b, False, feb)
        cs["st"] = jnp.where(grant, RESP, jnp.where(enq, SLEEP, cs["st"]))
        cs["tmr"] = jnp.where(grant, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(grant, NXT_MOD, cs["nxt"])
        # writeEF: pop the owner; hand off to the new head, or set the
        # bit full when the FIFO drained
        qhead = jnp.where(rel_b, (qhead + 1) % q_cap, qhead)
        qlen = qlen + put_b - rel_b
        cs["st"] = jnp.where(is_rel, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_rel, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(is_rel, NXT_WORK_DONE, cs["nxt"])
        pend_b = rel_b & (qlen > 0)
        feb = jnp.where(rel_b & (qlen == 0), True, feb)
        bank["wake_tmr"] = jnp.where(pend_b, self.wake_delay(p),
                                     bank["wake_tmr"])
        bank["feb"] = feb
        bank["qbuf"], bank["qhead"], bank["qlen"] = qbuf, qhead, qlen
        return cs, bank

    def fused_access(self, fx, bank):
        q_cap = fx.q_cap
        feb = bank["feb"]
        qbuf, qhead, qlen = bank["qbuf"], bank["qhead"], bank["qlen"]
        ba = jnp.arange(qbuf.shape[0], dtype=jnp.int32)   # block-local
        grant_b = fx.acq_b & feb
        enq_b = fx.acq_b & ~feb
        put_b = fx.acq_b
        slot_b = (qhead + qlen) % q_cap
        qbuf = qbuf.at[jnp.where(put_b, ba, qbuf.shape[0]), slot_b].set(
            fx.win, mode="drop")
        feb = jnp.where(fx.acq_b, False, feb)
        kind = jnp.where(
            grant_b, OUT_GRANT,
            jnp.where(enq_b, OUT_SLEEP,
                      jnp.where(fx.rel_b, OUT_DONE, OUT_NONE))
        ).astype(jnp.int32)
        tmr = jnp.full_like(kind, fx.p.lat)
        qhead = jnp.where(fx.rel_b, (qhead + 1) % q_cap, qhead)
        qlen = qlen + put_b - fx.rel_b
        pend_b = fx.rel_b & (qlen > 0)
        feb = jnp.where(fx.rel_b & (qlen == 0), True, feb)
        wake_tmr = jnp.where(pend_b, self.wake_delay(fx.p),
                             bank["wake_tmr"])
        bank = dict(bank, feb=feb, qbuf=qbuf, qhead=qhead, qlen=qlen,
                    wake_tmr=wake_tmr)
        return bank, FusedOut(kind=kind, tmr=tmr)

    def on_timeout(self, ctx, cs, bank, stuck_b, killed, owner):
        # stock FIFO eviction; evicting the LAST entry must also set the
        # bit full again, or the bank refuses every future readFE — the
        # bit re-derivation IS the certified invariant feb == (qlen==0)
        cs, bank, kind = super().on_timeout(ctx, cs, bank, stuck_b,
                                            killed, owner)
        bank["feb"] = bank["qlen"] == 0
        return cs, bank, kind
