"""``colibri_hier`` — two-level Colibri: group-local queues + a global
spillover queue of groups.

Models the paper's distributed reservations at cluster granularity: cores
are partitioned into ``n_groups`` clusters.  Waiters enqueue in a queue
local to their (address, group) pair — a SuccessorUpdate that stays inside
the cluster (1 hop) and a wake-up that costs only an intra-cluster Qnode
bounce (2 cycles).  A group with waiters registers once in the address's
global FIFO of groups; when the serving group's local queue drains, the
release hands the address to the next registered group with the full
cross-cluster wake round trip (``lat + 2``).

Like flat Colibri this is polling-free and retry-free (local queues are
sized for the worst case of one outstanding RMW per core, so an LRwait
never bounces); unlike flat Colibri, the common-case wake and
SuccessorUpdate stay inside a cluster, trading strict global FIFO for
group-batched service.  Fairness across groups is preserved by a turn
budget: after ``group_size`` ops a group with registered competitors
re-registers at the global tail and hands the address over, so no group
can starve another (round-robin at cluster granularity).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.protocols.base import (MOD, NXT_MOD, NXT_WORK_DONE, OUT_DONE,
                                       OUT_EVICT, OUT_GRANT, OUT_NONE,
                                       OUT_REDELIVER, OUT_SLEEP, RESP, SLEEP,
                                       Contract, FusedOut, Protocol)
from repro.core.protocols.registry import register


@register
class ColibriHier(Protocol):
    name = "colibri_hier"
    uses_queue = True
    local_delay = 2          # intra-cluster Qnode bounce
    # retry-free wait-class like flat colibri, but grantees bypass the
    # local queues (woken heads are popped), so queue_depth counts the
    # sleepers ONLY — the conservation rule the PR 6 wake_grp aliasing
    # bug violated
    contract = Contract(exclusive_grant=True, wait_class=True,
                        retry_free=True, queue_counts_holder=False,
                        max_hot_scatters=12)

    @staticmethod
    def _geom(p, n):
        """(n_groups, group_size, local queue capacity) — all static."""
        g = max(1, min(p.n_groups, n))
        gsz = max(1, n // g)
        cap_l = max(gsz, n - (g - 1) * gsz)  # last group may be larger
        return g, gsz, cap_l

    def init_bank_state(self, p, a, n, q_cap):
        g, _, cap_l = self._geom(p, n)
        return dict(
            lqbuf=jnp.full((a * g, cap_l), -1, jnp.int32),
            lqhead=jnp.zeros((a * g,), jnp.int32),
            lqlen=jnp.zeros((a * g,), jnp.int32),
            ggq=jnp.full((a, g), -1, jnp.int32),    # FIFO of group ids
            gqhead=jnp.zeros((a,), jnp.int32),
            gqlen=jnp.zeros((a,), jnp.int32),
            g_inq=jnp.zeros((a, g), bool),
            cur_grp=jnp.full((a,), -1, jnp.int32),  # group holding the turn
            turn_srv=jnp.zeros((a,), jnp.int32),    # ops served this turn
            wake_tmr=jnp.zeros((a,), jnp.int32),
            # GROUP whose local queue to wake: storing the group id (not
            # the flat (addr, group) queue id) keeps the value meaningful
            # under the Pallas kernel's bank tiling — a block-local flat
            # id would alias another bank's queue once the block offset
            # is stripped; on_wake rebuilds the flat id from global ids
            wake_grp=jnp.zeros((a,), jnp.int32),
        )

    def queue_depth(self, bank):
        # total waiters per bank = its G group-local queues summed
        # (flat queue id is bank*G + group, so a (a, G) reshape lines up)
        a = bank["cur_grp"].shape[0]
        return bank["lqlen"].reshape(a, -1).sum(axis=1)

    def on_access(self, ctx, cs, bank):
        p, wa = ctx.p, ctx.wa
        is_acq, is_rel = ctx.is_acq, ctx.is_rel
        acq_b, rel_b, win, ba = ctx.acq_b, ctx.rel_b, ctx.win_core, ctx.ba
        G, gsz, cap_l = self._geom(p, ctx.n)
        lqbuf, lqhead, lqlen = bank["lqbuf"], bank["lqhead"], bank["lqlen"]
        ggq, gqhead, gqlen = bank["ggq"], bank["gqhead"], bank["gqlen"]
        g_inq, cur_grp = bank["g_inq"], bank["cur_grp"]
        turn_srv = bank["turn_srv"]
        wake_tmr, wake_grp = bank["wake_tmr"], bank["wake_grp"]

        # bank-side: the winning core's group and flat queue id.
        # All bank/queue state writes below are dense over banks (or
        # a-lane scatters into the (a*G,) local-queue arrays): the
        # engine guarantees ≤1 winner per bank, each either an acquire
        # or a release, so no two writes ever hit the same bank's state
        # and the former n-lane masked scatters collapse to vector ops.
        g_b = jnp.minimum(jnp.minimum(win, ctx.n - 1) // gsz, G - 1)
        lq_b = ba * G + g_b                      # flat (addr, group) id
        oob_a, oob_lq = ctx.a, ctx.a * G

        # ---- acquire ----
        idle_b = cur_grp < 0                     # no turn in progress
        idle = idle_b[wa]
        grant = is_acq & idle
        grant_b = acq_b & idle_b
        cur_grp = jnp.where(grant_b, g_b, cur_grp)
        turn_srv = jnp.where(grant_b, 0, turn_srv)
        cs["st"] = jnp.where(grant, RESP, cs["st"])
        cs["tmr"] = jnp.where(grant, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(grant, NXT_MOD, cs["nxt"])
        # enqueue in the group-local queue and sleep (never full: cap_l
        # covers one outstanding RMW per member core — polling-free)
        enq = is_acq & ~idle
        enq_b = acq_b & ~idle_b
        slot_b = (lqhead[lq_b] + lqlen[lq_b]) % cap_l
        put_lq = jnp.where(enq_b, lq_b, oob_lq)
        lqbuf = lqbuf.at[put_lq, slot_b].set(win, mode="drop")
        lqlen = lqlen.at[put_lq].add(1, mode="drop")
        cs["st"] = jnp.where(enq, SLEEP, cs["st"])
        cs["msgs"] = cs["msgs"] + enq_b.sum()    # intra-cluster SuccUpdate
        # first waiter of a non-serving group registers it globally
        reg_b = enq_b & (cur_grp != g_b) & ~g_inq[ba, g_b]
        gslot_b = (gqhead + gqlen) % G
        reg_a = jnp.where(reg_b, ba, oob_a)
        ggq = ggq.at[reg_a, gslot_b].set(g_b, mode="drop")
        gqlen = gqlen + reg_b
        g_inq = g_inq.at[reg_a, g_b].set(True, mode="drop")
        cs["msgs"] = cs["msgs"] + 2 * reg_b.sum()  # global registration RT

        # ---- release (releaser's group always == cur_grp[wa]) ----
        srv_b = turn_srv + 1                     # ops completed this turn
        # turn budget: with competitors registered, a group yields after
        # group_size ops even if its local queue still holds waiters —
        # round-robin fairness at cluster granularity
        exhausted_b = rel_b & (srv_b >= gsz) & (gqlen > 0)
        more_local_b = rel_b & (lqlen[lq_b] > 0) & ~exhausted_b
        wake_grp = jnp.where(more_local_b, g_b, wake_grp)
        wake_tmr = jnp.where(more_local_b, self.local_delay, wake_tmr)
        cs["msgs"] = cs["msgs"] + more_local_b.sum()  # intra-cluster wake
        turn_srv = jnp.where(more_local_b, srv_b, turn_srv)
        # yielding with waiters left: re-register at the global tail
        re_reg_b = rel_b & (lqlen[lq_b] > 0) & exhausted_b
        tail_b = (gqhead + gqlen) % G
        re_reg_a = jnp.where(re_reg_b, ba, oob_a)
        ggq = ggq.at[re_reg_a, tail_b].set(g_b, mode="drop")
        gqlen = gqlen + re_reg_b
        g_inq = g_inq.at[re_reg_a, g_b].set(True, mode="drop")
        cs["msgs"] = cs["msgs"] + 2 * re_reg_b.sum()  # re-registration RT
        # turn over: local queue drained, or budget spent with competitors
        end_turn_b = rel_b & ((lqlen[lq_b] == 0) | exhausted_b)
        have_next_b = end_turn_b & (gqlen > 0)
        next_g_b = ggq[ba, gqhead]
        cur_grp = jnp.where(have_next_b, next_g_b, cur_grp)
        g_inq = g_inq.at[jnp.where(have_next_b, ba, oob_a), next_g_b].set(
            False, mode="drop")
        gqhead = jnp.where(have_next_b, (gqhead + 1) % G, gqhead)
        gqlen = gqlen - have_next_b
        wake_grp = jnp.where(have_next_b, next_g_b, wake_grp)
        wake_tmr = jnp.where(have_next_b, p.lat + 2, wake_tmr)
        turn_srv = jnp.where(have_next_b, 0, turn_srv)
        cs["msgs"] = cs["msgs"] + 2 * have_next_b.sum()  # x-cluster wake RT
        # nothing left anywhere: the address goes idle
        cur_grp = jnp.where(end_turn_b & ~have_next_b, -1, cur_grp)
        cs["st"] = jnp.where(is_rel, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_rel, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(is_rel, NXT_WORK_DONE, cs["nxt"])

        bank.update(lqbuf=lqbuf, lqhead=lqhead, lqlen=lqlen, ggq=ggq,
                    gqhead=gqhead, gqlen=gqlen, g_inq=g_inq,
                    cur_grp=cur_grp, turn_srv=turn_srv,
                    wake_tmr=wake_tmr, wake_grp=wake_grp)
        return cs, bank

    def fused_access(self, fx, bank):
        # the on_access dense bank updates, restated block-locally: bank
        # ids come from a local iota over this block's lanes (the flat
        # (addr, group) queue ids follow from it), and the per-core
        # grant/enqueue/release effects become OUT_* codes.
        G, gsz, cap_l = self._geom(fx.p, fx.n)
        lqbuf, lqhead, lqlen = bank["lqbuf"], bank["lqhead"], bank["lqlen"]
        ggq, gqhead, gqlen = bank["ggq"], bank["gqhead"], bank["gqlen"]
        g_inq, cur_grp = bank["g_inq"], bank["cur_grp"]
        turn_srv = bank["turn_srv"]
        wake_tmr, wake_grp = bank["wake_tmr"], bank["wake_grp"]
        a = cur_grp.shape[0]                     # banks in this block
        ba = jnp.arange(a, dtype=jnp.int32)
        g_b = jnp.minimum(jnp.minimum(fx.win, fx.n - 1) // gsz, G - 1)
        lq_b = ba * G + g_b
        oob_a, oob_lq = a, a * G

        # ---- acquire ----
        idle_b = cur_grp < 0
        grant_b = fx.acq_b & idle_b
        cur_grp = jnp.where(grant_b, g_b, cur_grp)
        turn_srv = jnp.where(grant_b, 0, turn_srv)
        enq_b = fx.acq_b & ~idle_b
        slot_b = (lqhead[lq_b] + lqlen[lq_b]) % cap_l
        put_lq = jnp.where(enq_b, lq_b, oob_lq)
        lqbuf = lqbuf.at[put_lq, slot_b].set(fx.win, mode="drop")
        lqlen = lqlen.at[put_lq].add(1, mode="drop")
        msgs = enq_b.astype(jnp.int32)           # intra-cluster SuccUpdate
        reg_b = enq_b & (cur_grp != g_b) & ~g_inq[ba, g_b]
        gslot_b = (gqhead + gqlen) % G
        reg_a = jnp.where(reg_b, ba, oob_a)
        ggq = ggq.at[reg_a, gslot_b].set(g_b, mode="drop")
        gqlen = gqlen + reg_b
        g_inq = g_inq.at[reg_a, g_b].set(True, mode="drop")
        msgs = msgs + 2 * reg_b                  # global registration RT

        # ---- release ----
        srv_b = turn_srv + 1
        exhausted_b = fx.rel_b & (srv_b >= gsz) & (gqlen > 0)
        more_local_b = fx.rel_b & (lqlen[lq_b] > 0) & ~exhausted_b
        wake_grp = jnp.where(more_local_b, g_b, wake_grp)
        wake_tmr = jnp.where(more_local_b, self.local_delay, wake_tmr)
        msgs = msgs + more_local_b               # intra-cluster wake
        turn_srv = jnp.where(more_local_b, srv_b, turn_srv)
        re_reg_b = fx.rel_b & (lqlen[lq_b] > 0) & exhausted_b
        tail_b = (gqhead + gqlen) % G
        re_reg_a = jnp.where(re_reg_b, ba, oob_a)
        ggq = ggq.at[re_reg_a, tail_b].set(g_b, mode="drop")
        gqlen = gqlen + re_reg_b
        g_inq = g_inq.at[re_reg_a, g_b].set(True, mode="drop")
        msgs = msgs + 2 * re_reg_b               # re-registration RT
        end_turn_b = fx.rel_b & ((lqlen[lq_b] == 0) | exhausted_b)
        have_next_b = end_turn_b & (gqlen > 0)
        next_g_b = ggq[ba, gqhead]
        cur_grp = jnp.where(have_next_b, next_g_b, cur_grp)
        g_inq = g_inq.at[jnp.where(have_next_b, ba, oob_a), next_g_b].set(
            False, mode="drop")
        gqhead = jnp.where(have_next_b, (gqhead + 1) % G, gqhead)
        gqlen = gqlen - have_next_b
        wake_grp = jnp.where(have_next_b, next_g_b, wake_grp)
        wake_tmr = jnp.where(have_next_b, fx.p.lat + 2, wake_tmr)
        turn_srv = jnp.where(have_next_b, 0, turn_srv)
        msgs = msgs + 2 * have_next_b            # cross-cluster wake RT
        cur_grp = jnp.where(end_turn_b & ~have_next_b, -1, cur_grp)

        kind = jnp.where(
            grant_b, OUT_GRANT,
            jnp.where(enq_b, OUT_SLEEP,
                      jnp.where(fx.rel_b, OUT_DONE, OUT_NONE))
        ).astype(jnp.int32)
        tmr = jnp.full_like(kind, fx.p.lat)
        bank = dict(bank, lqbuf=lqbuf, lqhead=lqhead, lqlen=lqlen, ggq=ggq,
                    gqhead=gqhead, gqlen=gqlen, g_inq=g_inq,
                    cur_grp=cur_grp, turn_srv=turn_srv,
                    wake_tmr=wake_tmr, wake_grp=wake_grp)
        return bank, FusedOut(kind=kind, tmr=tmr, msgs=msgs.astype(jnp.int32))

    # ---- fault recovery (repro.faults) ----------------------------------
    # Unlike the flat FIFO protocols the current holder is NOT queued
    # (grantees skip the local queues; woken heads are popped), so
    # eviction cannot pop the dead core — instead it REPLAYS the release
    # handoff the dead owner would have performed: wake the serving
    # group's next local waiter, else hand the address to the next
    # registered group, else go idle.  The engine-tracked last grantee
    # (``owner``) tells the watchdog whether the holder is dead.
    def held(self, bank):
        return bank["cur_grp"] >= 0

    def on_timeout(self, ctx, cs, bank, stuck_b, killed, owner):
        p, n, ba = ctx.p, ctx.n, ctx.ba
        G, _, _ = self._geom(p, n)
        lqlen = bank["lqlen"]
        ggq, gqhead, gqlen = bank["ggq"], bank["gqhead"], bank["gqlen"]
        g_inq, cur_grp = bank["g_inq"], bank["cur_grp"]
        turn_srv = bank["turn_srv"]
        wake_tmr, wake_grp = bank["wake_tmr"], bank["wake_grp"]
        own_dead = (owner < n) & killed[jnp.clip(owner, 0, n - 1)]
        evict_b = stuck_b & own_dead
        g = jnp.clip(cur_grp, 0, G - 1)
        more_local = evict_b & (lqlen[ba * G + g] > 0)
        wake_grp = jnp.where(more_local, g, wake_grp)
        wake_tmr = jnp.where(more_local, self.local_delay, wake_tmr)
        end_b = evict_b & ~more_local
        have_next = end_b & (gqlen > 0)
        next_g = ggq[ba, gqhead]
        cur_grp = jnp.where(have_next, next_g, cur_grp)
        g_inq = g_inq.at[jnp.where(have_next, ba, ctx.a), next_g].set(
            False, mode="drop")
        gqhead = jnp.where(have_next, (gqhead + 1) % G, gqhead)
        gqlen = gqlen - have_next
        wake_grp = jnp.where(have_next, next_g, wake_grp)
        wake_tmr = jnp.where(have_next, p.lat + 2, wake_tmr)
        turn_srv = jnp.where(evict_b, 0, turn_srv)
        cur_grp = jnp.where(end_b & ~have_next, -1, cur_grp)
        # live owner, no progress: the recorded wake was lost — re-send
        redeliver_b = (stuck_b & ~own_dead
                       & (lqlen[ba * G + wake_grp] > 0))
        wake_tmr = jnp.where(redeliver_b, self.local_delay, wake_tmr)
        cs["msgs"] = cs["msgs"] + 2 * (more_local | have_next
                                       | redeliver_b).sum()
        bank.update(ggq=ggq, gqhead=gqhead, gqlen=gqlen, g_inq=g_inq,
                    cur_grp=cur_grp, turn_srv=turn_srv,
                    wake_tmr=wake_tmr, wake_grp=wake_grp)
        kind = jnp.where(evict_b, OUT_EVICT,
                         jnp.where(redeliver_b, OUT_REDELIVER,
                                   OUT_NONE)).astype(jnp.int32)
        return cs, bank, kind

    def on_wake(self, ctx, cs, bank):
        G, _, cap_l = self._geom(ctx.p, ctx.n)
        wake_tmr = bank["wake_tmr"]
        ba = ctx.ba if ctx.ba is not None else jnp.arange(ctx.a)
        wq = ba * G + bank["wake_grp"]      # flat local-queue id
        lqbuf, lqhead, lqlen = bank["lqbuf"], bank["lqhead"], bank["lqlen"]
        fire = wake_tmr == 1
        wake_tmr = jnp.maximum(wake_tmr - 1, 0)
        head_core = lqbuf[wq, lqhead[wq]]
        valid = fire & (lqlen[wq] > 0)
        fire_core = jnp.where(valid, head_core, ctx.n)
        woken = jnp.zeros((ctx.n,), bool).at[fire_core].set(True, mode="drop")
        cs["st"] = jnp.where(woken, MOD, cs["st"])
        cs["tmr"] = jnp.where(woken, ctx.mod_dur, cs["tmr"])
        # pop the woken head: it is now the address's active holder
        oob = jnp.where(valid, wq, ctx.a * G)
        lqhead = (lqhead.at[oob].add(1, mode="drop")) % cap_l
        lqlen = lqlen.at[oob].add(-1, mode="drop")
        bank.update(wake_tmr=wake_tmr, lqhead=lqhead, lqlen=lqlen)
        return cs, bank, (wake_tmr == 1).sum()
