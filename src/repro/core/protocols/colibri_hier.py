"""``colibri_hier`` — two-level Colibri: group-local queues + a global
spillover queue of groups.

Models the paper's distributed reservations at cluster granularity: cores
are partitioned into ``n_groups`` clusters.  Waiters enqueue in a queue
local to their (address, group) pair — a SuccessorUpdate that stays inside
the cluster (1 hop) and a wake-up that costs only an intra-cluster Qnode
bounce (2 cycles).  A group with waiters registers once in the address's
global FIFO of groups; when the serving group's local queue drains, the
release hands the address to the next registered group with the full
cross-cluster wake round trip (``lat + 2``).

Like flat Colibri this is polling-free and retry-free (local queues are
sized for the worst case of one outstanding RMW per core, so an LRwait
never bounces); unlike flat Colibri, the common-case wake and
SuccessorUpdate stay inside a cluster, trading strict global FIFO for
group-batched service.  Fairness across groups is preserved by a turn
budget: after ``group_size`` ops a group with registered competitors
re-registers at the global tail and hands the address over, so no group
can starve another (round-robin at cluster granularity).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.protocols.base import (MOD, NXT_MOD, NXT_WORK_DONE, RESP,
                                       SLEEP, Protocol, mset)
from repro.core.protocols.registry import register


@register
class ColibriHier(Protocol):
    name = "colibri_hier"
    uses_queue = True
    local_delay = 2          # intra-cluster Qnode bounce

    @staticmethod
    def _geom(p, n):
        """(n_groups, group_size, local queue capacity) — all static."""
        g = max(1, min(p.n_groups, n))
        gsz = max(1, n // g)
        cap_l = max(gsz, n - (g - 1) * gsz)  # last group may be larger
        return g, gsz, cap_l

    def init_bank_state(self, p, a, n, q_cap):
        g, _, cap_l = self._geom(p, n)
        return dict(
            lqbuf=jnp.full((a * g, cap_l), -1, jnp.int32),
            lqhead=jnp.zeros((a * g,), jnp.int32),
            lqlen=jnp.zeros((a * g,), jnp.int32),
            ggq=jnp.full((a, g), -1, jnp.int32),    # FIFO of group ids
            gqhead=jnp.zeros((a,), jnp.int32),
            gqlen=jnp.zeros((a,), jnp.int32),
            g_inq=jnp.zeros((a, g), bool),
            cur_grp=jnp.full((a,), -1, jnp.int32),  # group holding the turn
            turn_srv=jnp.zeros((a,), jnp.int32),    # ops served this turn
            wake_tmr=jnp.zeros((a,), jnp.int32),
            wake_q=jnp.zeros((a,), jnp.int32),      # flat local-queue to wake
        )

    def on_access(self, ctx, cs, bank):
        p, wa, wc = ctx.p, ctx.wa, ctx.wc
        is_acq, is_rel = ctx.is_acq, ctx.is_rel
        G, gsz, cap_l = self._geom(p, ctx.n)
        lqbuf, lqhead, lqlen = bank["lqbuf"], bank["lqhead"], bank["lqlen"]
        ggq, gqhead, gqlen = bank["ggq"], bank["gqhead"], bank["gqlen"]
        g_inq, cur_grp = bank["g_inq"], bank["cur_grp"]
        turn_srv = bank["turn_srv"]
        wake_tmr, wake_q = bank["wake_tmr"], bank["wake_q"]

        g = jnp.minimum(wc // gsz, G - 1)        # each core's group
        lq = wa * G + g                          # flat (addr, group) queue id
        oob_a = jnp.full_like(wa, ctx.a)
        oob_lq = jnp.full_like(lq, ctx.a * G)

        # ---- acquire ----
        idle = cur_grp[wa] < 0                   # no turn in progress
        grant = is_acq & idle
        cur_grp = mset(cur_grp, wa, grant, g)
        turn_srv = mset(turn_srv, wa, grant, 0)
        cs["st"] = jnp.where(grant, RESP, cs["st"])
        cs["tmr"] = jnp.where(grant, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(grant, NXT_MOD, cs["nxt"])
        # enqueue in the group-local queue and sleep (never full: cap_l
        # covers one outstanding RMW per member core — polling-free)
        enq = is_acq & ~idle
        slot = (lqhead[lq] + lqlen[lq]) % cap_l
        lqbuf = lqbuf.at[jnp.where(enq, lq, oob_lq), slot].set(wc, mode="drop")
        lqlen = lqlen.at[lq].add(jnp.where(enq, 1, 0), mode="drop")
        cs["st"] = jnp.where(enq, SLEEP, cs["st"])
        cs["msgs"] = cs["msgs"] + enq.sum()      # intra-cluster SuccUpdate
        # first waiter of a non-serving group registers it globally
        reg = enq & (cur_grp[wa] != g) & ~g_inq[wa, g]
        gslot = (gqhead[wa] + gqlen[wa]) % G
        ggq = ggq.at[jnp.where(reg, wa, oob_a), gslot].set(g, mode="drop")
        gqlen = gqlen.at[wa].add(jnp.where(reg, 1, 0), mode="drop")
        g_inq = g_inq.at[jnp.where(reg, wa, oob_a), g].set(True, mode="drop")
        cs["msgs"] = cs["msgs"] + 2 * reg.sum()  # global registration RT

        # ---- release (releaser's group always == cur_grp[wa]) ----
        srv = turn_srv[wa] + 1                   # ops completed this turn
        # turn budget: with competitors registered, a group yields after
        # group_size ops even if its local queue still holds waiters —
        # round-robin fairness at cluster granularity
        exhausted = is_rel & (srv >= gsz) & (gqlen[wa] > 0)
        more_local = is_rel & (lqlen[lq] > 0) & ~exhausted
        wake_q = mset(wake_q, wa, more_local, lq)
        wake_tmr = mset(wake_tmr, wa, more_local, self.local_delay)
        cs["msgs"] = cs["msgs"] + more_local.sum()   # intra-cluster wake
        turn_srv = mset(turn_srv, wa, more_local, srv)
        # yielding with waiters left: re-register at the global tail
        re_reg = is_rel & (lqlen[lq] > 0) & exhausted
        tail = (gqhead[wa] + gqlen[wa]) % G
        ggq = ggq.at[jnp.where(re_reg, wa, oob_a), tail].set(g, mode="drop")
        gqlen = gqlen.at[wa].add(jnp.where(re_reg, 1, 0), mode="drop")
        g_inq = g_inq.at[jnp.where(re_reg, wa, oob_a), g].set(
            True, mode="drop")
        cs["msgs"] = cs["msgs"] + 2 * re_reg.sum()   # re-registration RT
        # turn over: local queue drained, or budget spent with competitors
        end_turn = is_rel & ((lqlen[lq] == 0) | exhausted)
        have_next = end_turn & (gqlen[wa] > 0)
        next_g = ggq[wa, gqhead[wa]]
        cur_grp = mset(cur_grp, wa, have_next, next_g)
        g_inq = g_inq.at[jnp.where(have_next, wa, oob_a), next_g].set(
            False, mode="drop")
        gqhead = (gqhead.at[wa].add(jnp.where(have_next, 1, 0), mode="drop")
                  % G)
        gqlen = gqlen.at[wa].add(jnp.where(have_next, -1, 0), mode="drop")
        wake_q = mset(wake_q, wa, have_next, wa * G + next_g)
        wake_tmr = mset(wake_tmr, wa, have_next, p.lat + 2)
        turn_srv = mset(turn_srv, wa, have_next, 0)
        cs["msgs"] = cs["msgs"] + 2 * have_next.sum()  # cross-cluster wake RT
        # nothing left anywhere: the address goes idle
        cur_grp = mset(cur_grp, wa, end_turn & ~have_next, -1)
        cs["st"] = jnp.where(is_rel, RESP, cs["st"])
        cs["tmr"] = jnp.where(is_rel, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(is_rel, NXT_WORK_DONE, cs["nxt"])

        bank.update(lqbuf=lqbuf, lqhead=lqhead, lqlen=lqlen, ggq=ggq,
                    gqhead=gqhead, gqlen=gqlen, g_inq=g_inq,
                    cur_grp=cur_grp, turn_srv=turn_srv,
                    wake_tmr=wake_tmr, wake_q=wake_q)
        return cs, bank

    def on_wake(self, ctx, cs, bank):
        G, _, cap_l = self._geom(ctx.p, ctx.n)
        wake_tmr, wq = bank["wake_tmr"], bank["wake_q"]
        lqbuf, lqhead, lqlen = bank["lqbuf"], bank["lqhead"], bank["lqlen"]
        fire = wake_tmr == 1
        wake_tmr = jnp.maximum(wake_tmr - 1, 0)
        head_core = lqbuf[wq, lqhead[wq]]
        valid = fire & (lqlen[wq] > 0)
        fire_core = jnp.where(valid, head_core, ctx.n)
        woken = jnp.zeros((ctx.n,), bool).at[fire_core].set(True, mode="drop")
        cs["st"] = jnp.where(woken, MOD, cs["st"])
        cs["tmr"] = jnp.where(woken, ctx.mod_dur, cs["tmr"])
        # pop the woken head: it is now the address's active holder
        oob = jnp.where(valid, wq, ctx.a * G)
        lqhead = (lqhead.at[oob].add(1, mode="drop")) % cap_l
        lqlen = lqlen.at[oob].add(-1, mode="drop")
        bank.update(wake_tmr=wake_tmr, lqhead=lqhead, lqlen=lqlen)
        return cs, bank, (wake_tmr == 1).sum()
