"""``amo`` — single-instruction atomic add (Fig. 3 roofline).

No bank state: the RMW commits in one bank access, the response sends the
core straight back to work.  Every generic-RMW protocol is bounded above
by this line.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.protocols.base import (NXT_WORK_DONE, OUT_DONE, OUT_NONE,
                                       RESP, Contract, FusedOut, Protocol)
from repro.core.protocols.registry import register


@register
class Amo(Protocol):
    name = "amo"
    # one access commits the op: no retries, no waiting, nothing held
    contract = Contract(exclusive_grant=True, retry_free=True,
                        wait_class=False, max_hot_scatters=2)

    def on_access(self, ctx, cs, bank):
        p = ctx.p
        cs["st"] = jnp.where(ctx.is_acq, RESP, cs["st"])
        cs["tmr"] = jnp.where(ctx.is_acq, p.lat, cs["tmr"])
        cs["nxt"] = jnp.where(ctx.is_acq, NXT_WORK_DONE, cs["nxt"])
        return cs, bank

    def fused_access(self, fx, bank):
        # the AMO commits at the bank: every acquire winner retires in
        # one access (amo cores never issue a release phase)
        kind = jnp.where(fx.acq_b, OUT_DONE, OUT_NONE).astype(jnp.int32)
        tmr = jnp.full_like(kind, fx.p.lat)
        return bank, FusedOut(kind=kind, tmr=tmr)
