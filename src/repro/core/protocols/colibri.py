"""``colibri`` — LRSCwait with an unbounded distributed queue.

Same queue semantics as ``lrscwait`` with q = N (the linked list of
per-core Qnodes never fills), but the wake-up takes an extra round trip
(SCwait → Qnode → WakeUpRequest → memory → LR response) and
SuccessorUpdates add network traffic.
"""
from __future__ import annotations

from repro.core.protocols.base import Contract
from repro.core.protocols.lrscwait import LrscWait
from repro.core.protocols.registry import register


@register
class Colibri(LrscWait):
    name = "colibri"
    successor_updates = True
    # the distributed queue never fills (q = N), so unlike finite-q
    # lrscwait the protocol is fully retry-free: OUT_FAIL unreachable
    contract = Contract(exclusive_grant=True, wait_class=True,
                        retry_free=True, queue_counts_holder=True,
                        max_hot_scatters=4)

    def q_cap(self, p, n):
        return n                             # distributed queue never fills

    def wake_delay(self, p):
        # the WakeUpRequest is dispatched when the SCwait PASSES the Qnode,
        # travelling in parallel with it — the successor's response costs
        # one response latency plus a small Qnode bounce.
        return p.lat + 2
