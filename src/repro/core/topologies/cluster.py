"""Hierarchical cluster topologies (arXiv:2307.10248 latency model).

``cluster2``
    Two-level machine: ``p.clusters`` leaf clusters of cores, banks
    interleaved across the cluster-local SPMs.  A request leaving its
    cluster pays +8 cycles round trip (the reference manycore's
    measured remote-cluster access penalty over the local one-cycle
    SPM port) and contends for a cross-cluster link budget of
    ``net_bw // 4`` acceptances per cycle.

``cluster3``
    Three-level machine: leaf clusters pair into super-groups (the
    ``leaf >> 1`` default tree), with a cheaper intra-group boundary
    (+6 cycles, ``net_bw // 2``) and an expensive top-level crossing
    (+12 cycles, ``net_bw // 8``) — the "group → top" split of the same
    reference NoC.  A top-level crossing pays both boundaries
    (hops = 5, extra = 18): messages traverse the group router on the
    way to the top crossbar.
"""
from __future__ import annotations

from repro.core.topologies.base import LinkLevel, Topology
from repro.core.topologies.registry import register


@register
class Cluster2(Topology):
    name = "cluster2"
    levels = (LinkLevel("cluster", extra_lat=8, bw_div=4),)


@register
class Cluster3(Topology):
    name = "cluster3"
    levels = (LinkLevel("cluster", extra_lat=6, bw_div=2),
              LinkLevel("group", extra_lat=12, bw_div=8))
