"""Topology plugin interface: the machine's NoC shape as data.

The engine (``core.sim``) models a flat core↔bank crossbar; the paper's
Colibri is explicitly hierarchical (per-cluster reservation stations,
cross-cluster handoffs), and the related 1024-core manycore
(arXiv:2307.10248) routes every remote access through a multi-level
cluster NoC with per-level latencies and per-level link bandwidth.  A
:class:`Topology` plugin describes that shape declaratively — a cluster
tree with per-level extra latency and link capacity, plus a placement
rule mapping cores and banks onto clusters — and *compiles* it into
static per-(core, bank) tables the engine's network stage consumes:

* ``hops[c, b]``   — NoC hop count of a ``c → b`` request (1 for a
  bank in the core's own cluster, +2 per crossed level: up through the
  level router and back down);
* ``extra[c, b]``  — round-trip extra latency in cycles beyond the flat
  ``lat`` baseline, billed once at request issue;
* ``cross[ℓ][c, b]`` — whether a ``c → b`` message crosses level ``ℓ``'s
  boundary; crossing messages contend for that level's per-cycle link
  budget (``net_bw // bw_div``) on top of the global acceptance budget.

The tables are plain numpy, computed once per trace and closed over as
constants — the engine's ``lax.scan`` carry contract is untouched, and
the ``flat`` topology compiles to the *absence* of tables
(:attr:`TopoTables.is_flat`), so the engine Python-gates every topology
branch off and traces to exactly the pre-topology jaxpr (the telemetry/
faults static-elision discipline, audited by ``repro.analysis``).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinkLevel:
    """One boundary level of the cluster tree, leaf-most first."""
    name: str            # e.g. "cluster", "group"
    extra_lat: int       # round-trip extra cycles for crossing messages
    bw_div: int          # level link budget = max(1, net_bw // bw_div)

    def __post_init__(self):
        if self.extra_lat < 0:
            raise ValueError(f"level {self.name!r}: extra_lat must be >= 0")
        if self.bw_div < 1:
            raise ValueError(f"level {self.name!r}: bw_div must be >= 1")


@dataclasses.dataclass(frozen=True)
class TopoTables:
    """Compiled per-(core, bank) tables for one (topology, n, a, clusters)
    point.  All arrays are numpy (trace-time constants)."""
    hops: np.ndarray                      # (n, a) int32, >= 1
    extra: np.ndarray                     # (n, a) int32, >= 0
    cross: Tuple[np.ndarray, ...]         # per level: (n, a) bool
    core_cluster: np.ndarray              # (n,) int32 leaf-cluster of core
    bank_cluster: np.ndarray              # (a,) int32 leaf-cluster of bank
    is_flat: bool                         # no levels: engine gates all off


def cluster_of(idx: np.ndarray, count: int, clusters: int) -> np.ndarray:
    """Block placement: contiguous ``ceil``-free partition of ``count``
    ids into ``clusters`` blocks — ``idx // (count // clusters)`` clamped
    so the remainder joins the last cluster.  Matches the hw_event
    protocol's group geometry (``_geom``) exactly, so the event unit a
    core registers with is the cluster the topology routes it to."""
    c = max(1, min(clusters, count))
    sz = max(1, count // c)
    return np.minimum(idx // sz, c - 1).astype(np.int32)


class Topology:
    """Base topology plugin.  Subclasses declare ``name`` and ``levels``
    and may override the placement hooks."""

    name: str = ""
    #: boundary levels, leaf-most first (empty = flat crossbar)
    levels: Tuple[LinkLevel, ...] = ()

    # ---- placement ------------------------------------------------------
    def core_clusters(self, p, n: int) -> np.ndarray:
        """(n,) leaf-cluster id of every core (block placement)."""
        return cluster_of(np.arange(n), n, getattr(p, "clusters", 1))

    def bank_clusters(self, p, a: int) -> np.ndarray:
        """(a,) leaf-cluster id of every bank.  Banks interleave across
        clusters (``b % clusters``) — the address-interleaved SPM layout
        of the reference manycore, so hot addresses spread over all
        cluster-local memories instead of piling into one."""
        c = max(1, min(getattr(p, "clusters", 1), max(a, 1)))
        return (np.arange(a) % c).astype(np.int32)

    def level_cluster(self, leaf: np.ndarray, level: int, p) -> np.ndarray:
        """Collapse leaf-cluster ids to the cluster id at ``level`` (0 =
        leaf).  Default tree: each level pairs up the clusters below it
        (``leaf >> level``)."""
        return leaf >> level

    # ---- compilation ----------------------------------------------------
    def tables(self, p, n: int, a: int) -> TopoTables:
        """Compile the placement + level declarations into the static
        per-(core, bank) hop/latency/crossing tables."""
        cc = np.asarray(self.core_clusters(p, n), np.int32)
        bc = np.asarray(self.bank_clusters(p, a), np.int32)
        if cc.shape != (n,) or bc.shape != (a,):
            raise ValueError(
                f"topology {self.name!r}: placement shapes {cc.shape}/"
                f"{bc.shape} do not match (n={n}, a={a})")
        hops = np.ones((n, a), np.int32)
        extra = np.zeros((n, a), np.int32)
        cross = []
        for lv, spec in enumerate(self.levels):
            cl = self.level_cluster(cc, lv, p)[:, None]
            bl = self.level_cluster(bc, lv, p)[None, :]
            x = cl != bl
            cross.append(x)
            hops = hops + 2 * x.astype(np.int32)
            extra = extra + spec.extra_lat * x.astype(np.int32)
        return TopoTables(hops=hops, extra=extra, cross=tuple(cross),
                          core_cluster=cc, bank_cluster=bc,
                          is_flat=not self.levels)
