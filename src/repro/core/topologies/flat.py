"""``flat`` — the degenerate single-crossbar topology.

No levels: every (core, bank) pair is one hop at the baseline ``lat``,
and the only bandwidth limit is the global ``net_bw`` acceptance budget
the engine has always enforced.  ``tables()`` therefore compiles to
``is_flat=True`` and the engine Python-gates every topology branch off,
tracing to exactly the pre-topology jaxpr — this is what keeps every
existing golden bit-identical and the scan carry contract unchanged.
"""
from __future__ import annotations

from repro.core.topologies.base import Topology
from repro.core.topologies.registry import register


@register
class Flat(Topology):
    name = "flat"
    levels = ()
