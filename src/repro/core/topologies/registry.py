"""String-keyed registry of NoC topology plugins.

Adding a topology is one module: subclass ``base.Topology``, decorate
the class (or call ``register`` on an instance), import it from
``topologies/__init__``.  The engine, sweep runner, and benchmarks all
resolve topologies by name through ``get``.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.topologies.base import Topology

_REGISTRY: Dict[str, Topology] = {}


def register(topo):
    """Register a Topology subclass or instance under its ``name``."""
    inst = topo() if isinstance(topo, type) else topo
    if not inst.name:
        raise ValueError(f"topology {topo!r} has no name")
    if inst.name in _REGISTRY:
        raise ValueError(f"duplicate topology name: {inst.name}")
    _REGISTRY[inst.name] = inst
    return topo


def get(name: str) -> Topology:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown topology {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
