"""NoC topology plugins for the cycle-level engine.

Importing this package registers every built-in topology:

=============  ==========================================================
``flat``       single crossbar (the engine's historical shape); compiles
               to no tables at all — bit-identical to the pre-topology
               engine on every protocol × workload golden
``cluster2``   two-level hierarchical-cluster NoC, arXiv:2307.10248
               latencies (+8 cyc / bw÷4 cross-cluster)
``cluster3``   three-level variant: cluster (+6 / ÷2) below a top-level
               group boundary (+12 / ÷8)
=============  ==========================================================

A topology compiles ``(p, n_cores, n_addrs)`` into static per-(core,
bank) hop/latency tables plus per-level link-crossing masks
(:class:`~repro.core.topologies.base.TopoTables`) that the engine's
network stage closes over as constants — the scan carry contract is
untouched and mixed-topology sweeps chunk per compile group like any
other static field.

New topologies: subclass :class:`~repro.core.topologies.base.Topology`,
decorate with :func:`~repro.core.topologies.registry.register`, and
import the module here.  Certify with the trace-safety audit
(``python -m repro.analysis trace``) plus the placement property tests
in ``tests/test_topology.py``.
"""
from repro.core.topologies import cluster, flat
from repro.core.topologies.base import LinkLevel, TopoTables, Topology
from repro.core.topologies.registry import get, names, register

__all__ = ["LinkLevel", "TopoTables", "Topology", "get", "names",
           "register", "cluster", "flat"]
