"""repro.core — the paper's contribution (engine layer).

The supported user surface is ``repro.sync`` (Spec / Result / Study);
everything here is the machinery it compiles onto — the legacy
``sim.run`` / ``sweep.sweep`` entry points are deprecated shims.

* ``dispatch``  — colibri ordered-commit: the LRSCwait insight (linearize at
  request time, serve in order, commit exactly once) as an SPMD primitive.
* ``sim``       — vectorized cycle-level manycore engine (performance
  reproduction: Figs. 3–6), parameterized by a protocol plugin.
* ``protocols`` — registry of synchronization protocol plugins (the
  paper's seven plus ``colibri_hier`` and ``ticket_lock``).
* ``sweep``     — batched parameter sweeps: jit the engine once per
  protocol, ``jax.vmap`` across the grid (batch + streaming executors).
* ``metrics``   — single derivation layer for the paper's metric triple.
* ``colibri``   — message-level protocol model (correctness: Section IV-A).
* ``costmodel`` — area/energy models calibrated to Tables I–II.
"""
from repro.core import colibri, costmodel, dispatch, protocols, sim, sweep

__all__ = ["colibri", "costmodel", "dispatch", "protocols", "sim", "sweep"]
