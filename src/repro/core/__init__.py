"""repro.core — the paper's contribution.

* ``dispatch``  — colibri ordered-commit: the LRSCwait insight (linearize at
  request time, serve in order, commit exactly once) as an SPMD primitive.
* ``sim``       — vectorized cycle-level manycore simulator (performance
  reproduction: Figs. 3–6).
* ``colibri``   — message-level protocol model (correctness: Section IV-A).
* ``costmodel`` — area/energy models calibrated to Tables I–II.
"""
from repro.core import colibri, costmodel, dispatch, sim

__all__ = ["colibri", "costmodel", "dispatch", "sim"]
