"""Single derivation layer for the paper's metric triple.

The paper's headline claims are throughput **and** fairness **and**
energy efficiency (6.5×/7.1× for Colibri vs LRSC at high contention),
so every simulation result — not just the figure-specific scripts —
must report all three.  This module owns that derivation:

* **Throughput** — completed ops per cycle (plus the Fig. 5 worker
  streaming rate), exactly as the engine always reported it.
* **Fairness** — Jain's fairness index over the per-core completed-op
  distribution (1.0 = perfectly uniform, 1/n = one core monopolises),
  alongside the legacy min/max rates and a NaN-safe span.  The raw
  ``fairness_max / max(fairness_min, 1e-9)`` span the benchmarks used
  to compute blows up to ~1e9 the moment any core completes 0 ops;
  Jain's index is bounded in (0, 1] and degrades smoothly, and
  :func:`fairness_span` pins the starved case to ``inf`` explicitly
  (with :func:`json_safe` mapping it to ``None`` for reports).
* **Latency** — per-atomic completion-latency percentiles (p50 / p95 /
  max), measured from the cycle a core first issues an acquire to the
  cycle the micro-op retires (so retry storms, backoff loops and queue
  waits all count).  The engine always accumulates a geometric
  latency histogram (``lat_hist``, :data:`LAT_BINS` buckets with
  :data:`LAT_SUB` sub-buckets per octave → ≤ ~19 % value resolution)
  plus the exact maximum (``lat_max``); when a full completion trace is
  recorded (``record_trace=True`` → ``trace_wait``) the percentiles are
  exact instead of bucketed.
* **Energy** — pJ per completed op through the Table II-calibrated
  event-energy model (``core.costmodel``), threaded through ``run()``
  and ``sweep()`` so every result dict carries ``energy_pj_per_op``.

Degenerate configurations (``n_workers == n_cores`` leaves no atomic
cores; zero completions) consistently report 0.0 instead of crashing.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import numpy as np

from repro.core import costmodel

#: latency histogram geometry: bucket(v) = floor(LAT_SUB * log2(v + 1)),
#: clipped to [0, LAT_BINS).  64 buckets at 4 sub-buckets per octave
#: cover latencies up to 2^16 cycles at ≤ 2^(1/4) ≈ 1.19× bucket width.
LAT_BINS = 64
LAT_SUB = 4

#: engine stat totals the energy model bills (see costmodel.fit_energy)
ENERGY_STAT_KEYS = ("msgs", "bank_ops", "active_cyc", "sleep_cyc",
                    "backoff_cyc", "bar_cyc")

#: the triple every result dict must carry (schema-checked in reports)
METRIC_TRIPLE = ("jain_fairness", "lat_p95", "energy_pj_per_op")


def json_safe(v: float) -> Optional[float]:
    """Map non-finite metric values (inf span from a starved core, NaN)
    to ``None`` so benchmark report rows stay strict JSON."""
    return float(v) if math.isfinite(v) else None


# ---------------------------------------------------------------------------
# Fairness
# ---------------------------------------------------------------------------

def jain_fairness(ops) -> float:
    """Jain's fairness index ``(Σx)² / (n·Σx²)`` over per-core ops.

    1.0 when every core completed the same count; → 1/n when a single
    core monopolises; 0.0 for an empty slice or when nothing completed
    (no allocation to be fair about).
    """
    x = np.asarray(ops, dtype=np.float64).ravel()
    if x.size == 0:
        return 0.0
    sq = float((x * x).sum())
    if sq == 0.0:
        return 0.0
    return float(x.sum()) ** 2 / (x.size * sq)


def fairness_span(ops) -> float:
    """NaN-safe fastest/slowest per-core ops ratio: ``inf`` when some
    core starved (0 ops) while another made progress, 0.0 when nothing
    completed at all (or the slice is empty) — never a division by an
    epsilon that manufactures a ~1e9 pseudo-value."""
    x = np.asarray(ops, dtype=np.float64).ravel()
    if x.size == 0:
        return 0.0
    lo, hi = float(x.min()), float(x.max())
    if lo <= 0.0:
        return 0.0 if hi <= 0.0 else math.inf
    return hi / lo


# ---------------------------------------------------------------------------
# Latency percentiles
# ---------------------------------------------------------------------------

def bucket_rep(i) -> np.ndarray:
    """Representative latency for histogram bucket ``i`` (geometric mean
    of the bucket's value range ``[2^(i/S) - 1, 2^((i+1)/S) - 1)``)."""
    return np.power(2.0, (np.asarray(i, np.float64) + 0.5) / LAT_SUB) - 1.0


def _percentile_from_hist(hist: np.ndarray, q: float,
                          lat_max: float) -> float:
    """Inverted-CDF percentile from the geometric histogram, clamped to
    the exact observed maximum."""
    cum = np.cumsum(hist.astype(np.int64))
    total = int(cum[-1]) if cum.size else 0
    if total == 0:
        return 0.0
    want = max(int(math.ceil(q * total)), 1)
    idx = int(np.searchsorted(cum, want))
    return float(min(bucket_rep(idx), lat_max))


def _percentile_from_waits(waits: np.ndarray, q: float) -> float:
    """Exact inverted-CDF percentile (the value at rank ⌈q·k⌉) over the
    recorded per-completion waits."""
    if waits.size == 0:
        return 0.0
    s = np.sort(waits)
    return float(s[max(int(math.ceil(q * s.size)), 1) - 1])


def trace_latency_hist(res: Dict[str, np.ndarray],
                       use_kernel: bool = True) -> np.ndarray:
    """Exact-trace completion-latency histogram on the engine's geometric
    bins — the recorded per-completion waits (``record_trace=True``)
    folded onto the same ``LAT_BINS``/``LAT_SUB`` geometry as the
    always-on ``lat_hist`` accumulator, so the two are directly
    comparable (equal, in fact: both count every retirement once —
    ``tests/test_kernels.py`` pins this against a live engine run).

    The commit goes through the ``colibri_scatter`` Pallas kernel (the
    paper's retry-free scatter-RMW counting its own latencies);
    ``use_kernel=False`` uses a plain ``np.bincount``.
    """
    tw = np.asarray(res["trace_wait"])
    waits = tw[tw >= 0]
    if waits.size == 0:
        return np.zeros((LAT_BINS,), np.int32)
    # identical bucket math to the engine's in-scan accumulator,
    # including the float32 rounding
    bkt = np.clip((LAT_SUB * np.log2(
        waits.astype(np.float32) + np.float32(1.0))).astype(np.int32),
        0, LAT_BINS - 1)
    if not use_kernel:
        return np.bincount(bkt, minlength=LAT_BINS).astype(np.int32)
    from repro.kernels.colibri_scatter import colibri_histogram
    return np.asarray(colibri_histogram(bkt, LAT_BINS))


def latency_percentiles(res: Dict[str, np.ndarray]) -> Dict[str, float]:
    """p50/p95/max completion latency for one result dict.

    Prefers the exact per-completion waits when a trace was recorded
    (``trace_wait``); otherwise reconstructs from the always-on
    ``lat_hist``/``lat_max`` accumulators (≤ one bucket width of error,
    max is exact either way).
    """
    lat_max = float(np.asarray(res.get("lat_max", 0)))
    if "trace_wait" in res:
        tw = np.asarray(res["trace_wait"])
        waits = tw[tw >= 0]
        out = {"lat_p50": _percentile_from_waits(waits, 0.50),
               "lat_p95": _percentile_from_waits(waits, 0.95)}
    else:
        hist = np.asarray(res.get("lat_hist", np.zeros(LAT_BINS, np.int64)))
        out = {"lat_p50": _percentile_from_hist(hist, 0.50, lat_max),
               "lat_p95": _percentile_from_hist(hist, 0.95, lat_max)}
    out["lat_max"] = lat_max
    return out


# ---------------------------------------------------------------------------
# Energy
# ---------------------------------------------------------------------------

def energy_stats(res: Dict[str, np.ndarray]) -> Dict[str, float]:
    """The billable stat totals of one result dict, as plain floats —
    the exact contract :func:`costmodel.fit_energy` /
    :func:`costmodel.energy_per_op` validate."""
    s = {k: float(np.asarray(res[k])) for k in ENERGY_STAT_KEYS}
    s["ops"] = float(np.asarray(res["ops"]).sum())
    # hierarchical-topology runs carry NoC hop traversals; flat runs
    # don't have the key and the energy model bills them as before
    if "hops" in res:
        s["hops"] = float(np.asarray(res["hops"]))
    return s


# ---------------------------------------------------------------------------
# The derivation layer
# ---------------------------------------------------------------------------

def attach(res: Dict[str, np.ndarray], n_workers: int, cycles: int,
           fit: Optional[costmodel.EnergyFit] = None
           ) -> Dict[str, np.ndarray]:
    """Attach the full paper-metric set to a raw engine result dict.

    This is the single derivation layer behind ``sim.run`` /
    ``sim.derive_metrics`` and every ``sweep()`` point: throughput and
    worker rate, the per-core fairness family (min/max rates, Jain
    index, NaN-safe span), completion-latency percentiles, and pJ per
    op through ``fit`` (default: the Table II calibration,
    :func:`costmodel.default_fit`).
    """
    ops = res["ops"][n_workers:] if n_workers else res["ops"]
    res["throughput"] = float(ops.sum()) / cycles if ops.size else 0.0
    res["fairness_min"] = float(ops.min()) / cycles if ops.size else 0.0
    res["fairness_max"] = float(ops.max()) / cycles if ops.size else 0.0
    res["jain_fairness"] = jain_fairness(ops)
    res["fairness_span"] = fairness_span(ops)
    res.update(latency_percentiles(res))
    stats = energy_stats(res)
    res["energy_pj_per_op"] = (
        costmodel.energy_per_op(stats, fit or costmodel.default_fit())
        if stats["ops"] > 0 else 0.0)
    if n_workers:
        w = res["w_served"][:n_workers]
        res["worker_rate"] = (float(w.sum()) / cycles / n_workers
                              if w.size else 0.0)
    if "dead_mask" in res:
        # graceful-degradation metrics (repro.faults): the engine only
        # emits these keys when a FaultPlan is enabled, so faults-off
        # results carry zero extra columns
        dm = np.asarray(res["dead_mask"])[n_workers:] if n_workers \
            else np.asarray(res["dead_mask"])
        res["stalled_cores"] = int(np.asarray(res["dead_mask"]).sum())
        surv = ops[~dm] if dm.size else ops
        res["survivor_throughput"] = (float(surv.sum()) / cycles
                                      if surv.size else 0.0)
        res["survivor_jain"] = jain_fairness(surv)
        res["faults_injected"] = int(np.asarray(res["faults_injected"]))
        res["recoveries"] = int(np.asarray(res.get("recoveries", 0)))
        # liveness verdict: the forward-progress watchdog never flagged
        # a halt => the system kept retiring ops to the horizon
        res["halt_cyc"] = int(np.asarray(res["halt_cyc"]))
        res["progress_ok"] = bool(res["halt_cyc"] < 0)
    return res
