"""Batched parameter sweeps: jit the engine once, ``vmap`` the grid.

The benchmark figures each run dozens of configurations.  The engine
jits per *static* parameter set, so a sweep over
``(seed, n_addrs, lat, work, ...)`` used to pay one full XLA compile per
point.  This runner groups configurations by their static fingerprint
(protocol, workload program, core count, cycle count, queue capacity,
group count, trace flag, unroll factor), lifts
every other scalar into a traced axis (``sim.DYN_FIELDS``), and runs each
group through a single ``jax.vmap``-ed compilation of the engine.

Entry points: :func:`sweep_params` (list in, input-order list out) and
:func:`sweep_iter` (generator yielding points as chunks materialize) —
both internal machinery behind ``repro.sync.Study.run()`` /
``.stream()``; the module-level :func:`sweep` / :func:`sweep_grid` are
deprecated legacy shims over them.

Executor shape (the hot path behind every figure):

* **Chunking** — each fingerprint group is split into ``max_batch``-point
  chunks (default 256, ``REPRO_SWEEP_MAX_BATCH``), so a 4096-point grid
  never materializes 4096 copies of the engine state at once; chunks of
  equal length reuse one compilation.
* **Overlapped dispatch** — chunks are dispatched ahead of
  materialization through a bounded look-ahead window (4 chunks in
  flight): jax computations are asynchronous, so the next chunks'
  host-side setup and device work overlap the current chunk's
  execution instead of blocking per group, while the window bounds how
  many chunk outputs are resident at once.
* **One transfer per chunk** — results come back through a single
  ``jax.device_get`` of the whole result pytree per chunk (the former
  per-key ``np.asarray`` did one host sync per array).
* **Device sharding** — with more than one device visible, chunks are
  padded to a multiple of ``jax.device_count()`` and their batch axis is
  sharded across devices (``NamedSharding``); the single-device path is
  byte-for-byte the old behaviour.
* **Persistent compilation cache** — :func:`enable_persistent_cache`
  points jax at an on-disk cache so repeated benchmark runs skip
  recompiles entirely (``benchmarks/run.py`` calls it at startup).

``n_addrs`` is traced too: configs bucket by the next power of two of
their address count (``_bucket_a``), the engine allocates banks for the
bucket and runs the live count through the address hash — so nearby
contention levels share one compile without a hot 1-address point
paying a 256-bank arbitration loop.  Results are **identical** to
per-config ``sim.run`` calls — all engine state is integer, and the
traced scalars feed the same arithmetic the Python constants did
(``tests/test_sweep.py`` locks this in, including chunked and sharded
execution).

EXPERIMENTS.md §Sweep and §Engine-throughput record the measured
speedups; ``benchmarks/bench_sweep.py`` and ``benchmarks/bench_engine.py``
regenerate them.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
import warnings
from functools import partial
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sim import (DYN_FIELDS, _DENSE_BANK_ELTS, SimParams,
                            derive_metrics, simulate)

#: fields that must match for configs to share one compilation — the
#: workload's compiled program, the trace shape and the scan unroll
#: factor are baked into the scan body, so all are part of the fingerprint
STATIC_FIELDS = ("protocol", "workload", "n_cores", "cycles", "q_slots",
                 "n_groups", "record_trace", "unroll", "backend",
                 "telemetry_windows", "faults", "topology", "clusters")

#: default ceiling on points per compiled vmap invocation
#: (``REPRO_SWEEP_MAX_BATCH`` overrides — read at each ``sweep()`` call,
#: so setting it after import still takes effect)
DEFAULT_MAX_BATCH = 256


def enable_persistent_cache(path: Optional[str] = None) -> str:
    """Enable jax's on-disk compilation cache (idempotent).

    Repeated benchmark runs re-trace the same engine fingerprints; with
    the cache enabled the XLA compile step is skipped on every run after
    the first.  ``path`` defaults to ``$REPRO_CACHE_DIR`` or
    ``~/.cache/lrscwait-repro/jax``.  Returns the cache directory.
    """
    path = path or os.environ.get(
        "REPRO_CACHE_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "lrscwait-repro",
                     "jax"))
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    # cache even fast/small compiles — the sweep fingerprints are many
    # and individually cheap, but a full benchmark run has dozens
    for opt, val in (("jax_persistent_cache_min_entry_size_bytes", -1),
                     ("jax_persistent_cache_min_compile_time_secs", 0.0)):
        try:
            jax.config.update(opt, val)
        except AttributeError:          # option not in this jax version
            pass
    return path


def _bucket_a(n_addrs: int) -> int:
    """Bank-allocation bucket: next power of two ≥ ``n_addrs``.

    Mixed-contention configs used to share one compile padded to the
    group's *maximum* address count — a ``n_addrs=1`` point then dragged
    256 banks of arbitration work through every cycle, and with the
    scatter-free hot path that padding dominates wall time.  Bucketing
    by power of two bounds the waste at 2× while keeping the compile
    count logarithmic in the contention range."""
    return 1 << max(n_addrs - 1, 0).bit_length()


def _static_key(p: SimParams):
    return tuple(getattr(p, f) for f in STATIC_FIELDS) + (_bucket_a(p.n_addrs),)


#: headline metrics screened for NaN/inf per point — ``fairness_span``
#: is deliberately absent (inf legitimately encodes a starved core)
_HEADLINE_KEYS = ("throughput", "jain_fairness", "energy_pj_per_op")


def _finite_metrics(res) -> bool:
    for k in _HEADLINE_KEYS:
        v = res.get(k)
        if v is not None and not math.isfinite(float(v)):
            return False
    return True


@partial(jax.jit, static_argnums=(0, 2))
def _sweep_group(rep: SimParams, dyn: Dict[str, jnp.ndarray], batch: int):
    # `batch` sizes the engine's dense-vs-scatter arbitration choice for
    # the vmapped working set; it is already implied by dyn's shapes, so
    # making it static adds no extra compiles
    return jax.vmap(lambda d: simulate(rep, dyn=d, batch=batch))(dyn)


def _batch_sharding():
    """(sharding, n_devices) for the chunk batch axis; (None, 1) on a
    single device — that path is unchanged from the unsharded runner."""
    devs = jax.devices()
    if len(devs) <= 1:
        return None, 1
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    mesh = Mesh(np.asarray(devs), ("batch",))
    return NamedSharding(mesh, PartitionSpec("batch")), len(devs)


def sweep_iter(configs: Sequence[SimParams],
               max_batch: Optional[int] = None, energy_fit=None,
               report=None
               ) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
    """Streaming sweep: yield ``(index, result)`` pairs as chunks
    materialize, in chunk-completion order (fingerprint groups in
    first-appearance order, chunks in order within a group) — NOT input
    order.  Each result dict is exactly what :func:`sweep` returns for
    that config, metric triple included; consumers that need input
    order collect into a list by index (that is all :func:`sweep`
    does).  This is the engine behind ``repro.sync.Study.stream()``:
    figure scripts consume points while later chunks are still in
    flight instead of waiting on the full grid.

    ``report`` (a :class:`repro.obs.RunReport`) records per-chunk
    compile/execute wall time and environment facts; when None, the
    ambient report of an enclosing ``repro.obs.collect()`` block is
    used (no-op when neither exists).  Instrumentation never changes
    results — it only reads clocks around the existing dispatch and
    transfer points.

    **Failure isolation:** a chunk that raises (at dispatch, execution
    or metric derivation) no longer kills the whole stream.  The
    poisoned chunk is re-run through a bisection ladder — halves
    batched, a failing half split again, a failing single point re-run
    solo — so every healthy point still yields its normal result and
    only the minimal failing set yields a structured error record
    (``{"error": "ExcType: message", "error_stage": ...}``, surfaced as
    ``Result.ok == False``).  Points whose headline metrics come back
    non-finite (NaN/inf throughput, Jain or energy — never the
    legitimately-inf ``fairness_span``) get one solo retry, then an
    error record.  Healthy sweeps take the exact pre-isolation path.
    """
    if max_batch is None:
        max_batch = int(os.environ.get("REPRO_SWEEP_MAX_BATCH",
                                       DEFAULT_MAX_BATCH))
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1 (got {max_batch})")
    if report is None:
        from repro.obs import runreport as _runreport
        report = _runreport.current()            # ambient collect(), or None
    groups: Dict[tuple, List[int]] = {}
    for i, c in enumerate(configs):
        groups.setdefault(_static_key(c), []).append(i)
    sharding, ndev = _batch_sharding()
    pending: List[tuple] = []                    # dispatched, not fetched
    if report is not None and configs:
        from repro.core.sim import resolve_backend
        report.note_env(resolve_backend(configs[0].backend), max_batch)

    def solo(i, stage):
        """Last rung of the isolation ladder: run ONE point un-batched;
        a failure here becomes its structured error record."""
        c = configs[i]
        try:
            rep1 = dataclasses.replace(c, n_addrs=_bucket_a(c.n_addrs))
            dyn1 = {f: jnp.asarray([getattr(c, f)], jnp.int32)
                    for f in DYN_FIELDS}
            out1 = jax.device_get(_sweep_group(rep1, dyn1, 1))
            stage = "metrics"
            m = derive_metrics({k: v[0] for k, v in out1.items()},
                               min(c.n_workers, c.n_cores), c.cycles,
                               energy_fit=energy_fit)
        except Exception as e:       # noqa: BLE001 — fenced by design
            return {"error": f"{type(e).__name__}: {e}",
                    "error_stage": stage}
        if not _finite_metrics(m):
            return {"error": "non-finite headline metrics "
                             "(throughput/jain/energy)",
                    "error_stage": "nonfinite"}
        return m

    def derive_checked(i, res, stage):
        """Per-point metric derivation with the solo-retry fallback."""
        c = configs[i]
        try:
            m = derive_metrics(res, min(c.n_workers, c.n_cores), c.cycles,
                               energy_fit=energy_fit)
        except Exception:            # noqa: BLE001 — fenced by design
            return solo(i, "metrics")
        if not _finite_metrics(m):
            return solo(i, "nonfinite")
        return m

    def isolate(part, stage):
        """Bisected retry of a poisoned chunk: halves re-run batched,
        a failing half recurses, a single point falls through to
        :func:`solo` — healthy points keep their normal results."""
        if len(part) == 1:
            yield part[0], solo(part[0], stage)
            return
        mid = len(part) // 2
        for half in (part[:mid], part[mid:]):
            chunk = [configs[i] for i in half]
            try:
                rep_h = dataclasses.replace(
                    chunk[0], n_addrs=_bucket_a(chunk[0].n_addrs))
                dyn_h = {f: jnp.asarray([getattr(c, f) for c in chunk],
                                        jnp.int32) for f in DYN_FIELDS}
                out_h = jax.device_get(_sweep_group(rep_h, dyn_h,
                                                    len(chunk)))
            except Exception:        # noqa: BLE001 — fenced by design
                yield from isolate(half, stage)
                continue
            for j, i in enumerate(half):
                yield i, derive_checked(i, {k: v[j] for k, v in
                                            out_h.items()}, stage)

    def materialize(part, out, rec):
        # one device->host transfer per chunk (the whole result pytree)
        t0 = time.perf_counter()
        try:
            out_np = jax.device_get(out)
        except Exception:            # noqa: BLE001 — fenced by design
            if rec is not None:
                rec.execute_s = time.perf_counter() - t0
            yield from isolate(part, "execute")
            return
        if rec is not None:
            # async dispatch drains here, so this wall is execute time
            rec.execute_s = time.perf_counter() - t0
        for j, i in enumerate(part):             # padding rows never read
            res = {k: v[j] for k, v in out_np.items()}
            yield i, derive_checked(i, res, "metrics")

    # dispatch chunks ahead of materialization: jax computations are
    # async, so the next chunk's host-side setup (and, with >1 device,
    # its execution) overlaps the previous chunk's run.  The look-ahead
    # window bounds how many chunk outputs are resident on device at
    # once — a record_trace point carries a (cycles, n) trace, so
    # unbounded dispatch would defeat the max_batch memory bound.
    window = 4
    for idxs in groups.values():
        grp = [configs[i] for i in idxs]
        # bank allocation = the group's power-of-two bucket (identical
        # for every member, so every chunk reuses one compilation)
        rep = dataclasses.replace(grp[0],
                                  n_addrs=_bucket_a(grp[0].n_addrs))
        # auto chunk: keep the vmapped dense-arbitration working set
        # (chunk, a, n) inside the engine's cache-friendly budget —
        # measured 2.3× on a 96-point a=16 grid vs one big chunk; grids
        # on the scatter path (large a*n) just take max_batch.
        # ``max_batch`` stays the hard memory ceiling either way.
        an = rep.n_addrs * rep.n_cores
        chunk_cap = max_batch
        if an <= _DENSE_BANK_ELTS:
            chunk_cap = max(1, min(max_batch, _DENSE_BANK_ELTS // an))
        for lo in range(0, len(idxs), chunk_cap):
            part = idxs[lo:lo + chunk_cap]
            chunk = [configs[i] for i in part]
            # pad the tail chunk to the full chunk length (and to a
            # device multiple) so it reuses the full chunk's compile
            want = chunk_cap if len(idxs) > chunk_cap else len(chunk)
            want += (-want) % ndev
            padded = chunk + [chunk[-1]] * (want - len(chunk))
            # a worker-free chunk drops the n_workers axis so the engine
            # statically elides the Fig.5 worker machinery (two written
            # (n,) scan carries whose dead writes sit on a compile
            # cliff); chunks with any workers keep the traced axis.  The
            # dropped axis falls back to the representative's static
            # value, so that must be pinned to 0 too — the group leader
            # may carry workers while a later chunk is worker-free.
            drop_workers = not any(c.n_workers for c in padded)
            dyn = {f: jnp.asarray([getattr(c, f) for c in padded], jnp.int32)
                   for f in DYN_FIELDS
                   if f != "n_workers" or not drop_workers}
            crep = dataclasses.replace(rep, n_workers=0) if drop_workers \
                else rep
            if sharding is not None:
                dyn = jax.device_put(dyn, sharding)
            t0 = time.perf_counter()
            cache_before = _sweep_group._cache_size() \
                if report is not None else 0
            try:
                out = _sweep_group(crep, dyn, len(padded))
            except Exception:        # noqa: BLE001 — fenced by design
                # poisoned at trace/compile time: fence it now, the
                # stream keeps flowing
                yield from isolate(part, "dispatch")
                continue
            rec = None
            if report is not None:
                # the jitted call traces+compiles synchronously on an
                # in-process cache miss and returns immediately on a
                # hit, so dispatch wall ~= compile time when the cache
                # grew; execution drains at materialize's device_get
                compiled = _sweep_group._cache_size() > cache_before
                report.record_chunk(
                    label=(f"{crep.protocol}/{crep.workload} "
                           f"{crep.n_cores}c a{crep.n_addrs} "
                           f"{crep.cycles}cyc"),
                    points=len(part), batch=len(padded),
                    compile_s=time.perf_counter() - t0, execute_s=0.0,
                    compiled=compiled)
                rec = report.chunks[-1]
            pending.append((part, out, rec))
            if len(pending) >= window:
                yield from materialize(*pending.pop(0))
    for part, out, rec in pending:
        yield from materialize(part, out, rec)


def sweep_params(configs: Sequence[SimParams],
                 max_batch: Optional[int] = None, energy_fit=None,
                 report=None) -> List[Dict[str, np.ndarray]]:
    """Run every configuration; returns one result dict per config (same
    keys and values as ``sim.execute``), in input order — including the
    paper metric triple (``jain_fairness`` / ``lat_p95`` /
    ``energy_pj_per_op``) attached per point by the shared derivation
    layer (``core.metrics``).  ``energy_fit`` overrides the frozen
    Table II calibration used for ``energy_pj_per_op``.

    Configurations sharing a static fingerprint are batched through one
    vmapped compile in ``max_batch``-point chunks; a heterogeneous list
    degrades gracefully to one compile per fingerprint.  Chunks are
    dispatched up to a 4-chunk look-ahead window before results are
    materialized (one ``device_get`` per chunk), and the batch axis is
    sharded across devices when more than one is visible.

    Internal engine entry point: the supported public surface is
    :class:`repro.sync.Study`, which wraps each point in a typed
    :class:`repro.sync.Result`.
    """
    results: List[Dict[str, np.ndarray]] = [None] * len(configs)  # type: ignore
    for i, res in sweep_iter(configs, max_batch=max_batch,
                             energy_fit=energy_fit, report=report):
        results[i] = res
    return results


def sweep(configs: Sequence[SimParams], max_batch: Optional[int] = None,
          energy_fit=None) -> List[Dict[str, np.ndarray]]:
    """Deprecated legacy entry point — use ``repro.sync.Study``.

    Behaviour is unchanged (bit-identical result dicts, input order;
    locked in by ``tests/test_sync_api.py``); only the warning is new.
    """
    warnings.warn(
        "repro.core.sweep.sweep() is deprecated; use repro.sync.Study "
        "(Study.from_specs(...).run() / .stream()) which returns typed "
        "Results.", DeprecationWarning, stacklevel=2)
    return sweep_params(configs, max_batch=max_batch, energy_fit=energy_fit)


def sweep_grid(base: SimParams, max_batch: Optional[int] = None,
               energy_fit=None, **axes: Sequence
               ) -> List[Dict[str, np.ndarray]]:
    """Deprecated legacy entry point — use
    ``repro.sync.Study(base).grid(...)``.

    Cartesian sweep: ``sweep_grid(base, n_addrs=(1, 16), seed=(0, 1))``
    runs every combination (last axis fastest) and returns results plus
    a ``_config`` entry recording each point's SimParams."""
    warnings.warn(
        "repro.core.sweep.sweep_grid() is deprecated; use "
        "repro.sync.Study(base_spec).grid(...).run() / .stream().",
        DeprecationWarning, stacklevel=2)
    for name in axes:
        if name not in DYN_FIELDS:
            raise ValueError(f"{name!r} is not sweepable; axes: {DYN_FIELDS}")
    points = [base]
    for name, values in axes.items():
        points = [dataclasses.replace(pt, **{name: v})
                  for pt in points for v in values]
    results = sweep_params(points, max_batch=max_batch,
                           energy_fit=energy_fit)
    for pt, res in zip(points, results):
        res["_config"] = pt
    return results
