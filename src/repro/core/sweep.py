"""Batched parameter sweeps: jit the engine once, ``vmap`` the grid.

The benchmark figures each run dozens of ``SimParams`` configurations.
``sim.run`` jits per *static* parameter set, so a sweep over
``(seed, n_addrs, lat, work, ...)`` used to pay one full XLA compile per
point.  This runner groups configurations by their static fingerprint
(protocol, workload program, core count, cycle count, queue capacity,
group count, trace flag), lifts
every other scalar into a traced axis (``sim.DYN_FIELDS``), and runs each
group through a single ``jax.vmap``-ed compilation of the engine.

``n_addrs`` is traced too: the engine allocates banks for the group's
maximum and runs the live count through the address hash, so mixed
contention levels share one compile.  Results are **identical** to
per-config ``sim.run`` calls — all engine state is integer, and the
traced scalars feed the same arithmetic the Python constants did
(``tests/test_sweep.py`` locks this in).

EXPERIMENTS.md §Sweep records the measured speedup; the ``sweep_speedup``
benchmark (``benchmarks/bench_sweep.py``) regenerates it.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sim import (DYN_FIELDS, SimParams, derive_metrics, simulate)

#: fields that must match for configs to share one compilation — the
#: workload's compiled program and the trace shape are baked into the
#: scan body, so both are part of the fingerprint
STATIC_FIELDS = ("protocol", "workload", "n_cores", "cycles", "q_slots",
                 "n_groups", "record_trace")


def _static_key(p: SimParams):
    return tuple(getattr(p, f) for f in STATIC_FIELDS)


@partial(jax.jit, static_argnums=0)
def _sweep_group(rep: SimParams, dyn: Dict[str, jnp.ndarray]):
    return jax.vmap(lambda d: simulate(rep, dyn=d))(dyn)


def sweep(configs: Sequence[SimParams]) -> List[Dict[str, np.ndarray]]:
    """Run every configuration; returns one result dict per config (same
    keys and values as ``sim.run``), in input order.

    Configurations sharing a static fingerprint are batched through one
    vmapped compile; a heterogeneous list degrades gracefully to one
    compile per fingerprint.
    """
    groups: Dict[tuple, List[int]] = {}
    for i, c in enumerate(configs):
        groups.setdefault(_static_key(c), []).append(i)
    results: List[Dict[str, np.ndarray]] = [None] * len(configs)  # type: ignore
    for idxs in groups.values():
        grp = [configs[i] for i in idxs]
        # bank allocation covers the group's largest address space
        rep = dataclasses.replace(grp[0], n_addrs=max(c.n_addrs for c in grp))
        dyn = {f: jnp.asarray([getattr(c, f) for c in grp], jnp.int32)
               for f in DYN_FIELDS}
        out = _sweep_group(rep, dyn)
        out_np = {k: np.asarray(v) for k, v in out.items()}
        for j, i in enumerate(idxs):
            res = {k: v[j] for k, v in out_np.items()}
            results[i] = derive_metrics(
                res, min(configs[i].n_workers, configs[i].n_cores),
                configs[i].cycles)
    return results


def sweep_grid(base: SimParams, **axes: Sequence) -> List[Dict[str, np.ndarray]]:
    """Cartesian sweep: ``sweep_grid(base, n_addrs=(1, 16), seed=(0, 1))``
    runs every combination (last axis fastest) and returns results plus a
    ``_config`` entry recording each point's SimParams."""
    for name in axes:
        if name not in DYN_FIELDS:
            raise ValueError(f"{name!r} is not sweepable; axes: {DYN_FIELDS}")
    points = [base]
    for name, values in axes.items():
        points = [dataclasses.replace(pt, **{name: v})
                  for pt in points for v in values]
    results = sweep(points)
    for pt, res in zip(points, results):
        res["_config"] = pt
    return results
