"""Area and energy models calibrated to the paper's Tables I and II.

Silicon numbers cannot be re-derived in JAX; we model them structurally
(component counts × per-component costs) and fit the per-component costs to
the published rows, reporting residuals. See DESIGN.md §2.

Table I (area, kGE, MemPool tile = 4 cores + 16 banks):
    tile 691 | +LRSCwait_1 790 | +LRSCwait_8 865 |
    +Colibri 1/2/4/8 addr: 732 / 750 / 761 / 802

Table II (energy @ highest contention):
    AMO 29 pJ/op | Colibri 124 | LRSC 884 | AMO lock 1092
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

TILE_CORES = 4
TILE_BANKS = 16
TILE_BASE_KGE = 691.0

PAPER_AREA = {  # design -> (param, kGE)
    "lrscwait_1": (1, 790.0),
    "lrscwait_8": (8, 865.0),
    "colibri_1": (1, 732.0),
    "colibri_2": (2, 750.0),
    "colibri_4": (4, 761.0),
    "colibri_8": (8, 802.0),
}

PAPER_ENERGY = {  # protocol -> pJ/op at highest contention
    "amo": 29.0, "colibri": 124.0, "lrsc": 884.0, "amo_lock": 1092.0,
}


# ---------------------------------------------------------------------------
# Area model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AreaFit:
    lrscwait_ctrl: float      # per-bank controller (kGE)
    lrscwait_slot: float      # per queue slot per bank
    colibri_ctrl: float       # per-bank head/tail controller
    colibri_addr: float       # per additional address queue per bank
    qnode: float              # per-core Qnode


def fit_area() -> AreaFit:
    """Least-squares fit of component costs to Table I."""
    # lrscwait: overhead = banks * (ctrl + slot*q)
    a = np.array([[TILE_BANKS, TILE_BANKS * 1],
                  [TILE_BANKS, TILE_BANKS * 8]])
    b = np.array([790 - 691, 865 - 691], float)
    ctrl, slot = np.linalg.solve(a, b)
    # colibri: overhead = banks * (ctrl2 + addr*(A-1)) + cores * qnode
    rows, rhs = [], []
    for name, (A, kge) in PAPER_AREA.items():
        if name.startswith("colibri"):
            rows.append([TILE_BANKS, TILE_BANKS * (A - 1), TILE_CORES])
            rhs.append(kge - TILE_BASE_KGE)
    sol, *_ = np.linalg.lstsq(np.array(rows, float), np.array(rhs), rcond=None)
    ctrl2, addr, qnode = sol
    return AreaFit(float(ctrl), float(slot), float(ctrl2), float(addr),
                   float(qnode))


def tile_area(design: str, param: int, fit: AreaFit = None) -> float:
    """kGE of a MemPool tile with the given synchronization design."""
    fit = fit or fit_area()
    if design == "base":
        return TILE_BASE_KGE
    if design == "lrscwait":
        return TILE_BASE_KGE + TILE_BANKS * (
            fit.lrscwait_ctrl + fit.lrscwait_slot * param)
    if design == "colibri":
        return TILE_BASE_KGE + TILE_BANKS * (
            fit.colibri_ctrl + fit.colibri_addr * (param - 1)) \
            + TILE_CORES * fit.qnode
    raise ValueError(design)


def system_overhead(design: str, n_cores: int, n_banks: int,
                    q: int = 1) -> float:
    """Asymptotic state count (paper Section III-A / IV):
    LRSCwait_ideal is O(n·log2(n)·m); Colibri is O(n + 2m)."""
    if design == "lrscwait_ideal":
        return n_cores * np.log2(max(n_cores, 2)) * n_banks
    if design == "lrscwait_q":
        return q * np.log2(max(n_cores, 2)) * n_banks
    if design == "colibri":
        return n_cores + 2 * n_banks
    raise ValueError(design)


# ---------------------------------------------------------------------------
# Energy model
# ---------------------------------------------------------------------------

#: pJ per NoC hop traversal (router + link segment) under a hierarchical
#: topology.  Table II has no hop-resolved rows (the paper's tile is a
#: flat crossbar), so this is a structural constant from the hierarchical
#: -cluster NoC literature (arXiv:2307.10248-class meshes, ~1 pJ/hop at
#: the reference node), NOT a fitted coefficient: ``fit_energy`` never
#: adjusts it, and flat-topology results (which carry no ``hops`` stat)
#: are billed exactly as before.
E_HOP_PJ = 1.2


@dataclasses.dataclass(frozen=True)
class EnergyFit:
    e_msg: float          # pJ per network message
    e_bank: float         # pJ per bank operation
    e_active: float       # pJ per core-active cycle (issue/stall)
    e_backoff: float      # pJ per backoff-loop cycle (busy wait)
    e_sleep: float        # pJ per clock-gated wait cycle (sleep/barrier)
    residuals: Dict[str, float]
    e_hop: float = E_HOP_PJ   # pJ per NoC hop traversal (structural)


#: stat totals every energy evaluation needs — validated up front so a
#: missing key fails with a clear ValueError instead of a KeyError deep
#: inside the fit (the seed's fit_energy docstring omitted backoff_cyc
#: and the model silently dropped bar_cyc entirely).
REQUIRED_ENERGY_KEYS = ("msgs", "bank_ops", "active_cyc", "sleep_cyc",
                        "backoff_cyc", "bar_cyc", "ops")


def _require_energy_keys(stats: Dict[str, float], who: str) -> None:
    for k in REQUIRED_ENERGY_KEYS:
        if k not in stats:
            raise ValueError(
                f"energy stats for {who!r} missing required key {k!r}; "
                f"required: {', '.join(REQUIRED_ENERGY_KEYS)}")


def fit_energy(stats: Dict[str, Dict[str, float]]) -> EnergyFit:
    """Fit per-event energies so that per-op energy matches Table II.

    ``stats[protocol]`` must provide: msgs, bank_ops, active_cyc,
    sleep_cyc, backoff_cyc, bar_cyc, ops (totals from a
    highest-contention simulation).  BARWAIT cycles are clock-gated
    waits exactly like SLEEP cycles (Glaser et al., arXiv:2004.06662),
    so they are billed at the ``e_sleep`` rate — the seed model charged
    them zero energy, undercounting every ``barrier_phases`` run.
    """
    protos = [p for p in ("amo", "colibri", "lrsc", "amo_lock") if p in stats]
    rows, rhs = [], []
    for pr in protos:
        s = stats[pr]
        _require_energy_keys(s, pr)
        ops = max(s["ops"], 1.0)
        rows.append([s["msgs"] / ops, s["bank_ops"] / ops,
                     (s["active_cyc"] - s["backoff_cyc"]) / ops,
                     s["backoff_cyc"] / ops,
                     (s["sleep_cyc"] + s["bar_cyc"]) / ops])
        rhs.append(PAPER_ENERGY[pr])
    A = np.array(rows, float)
    b = np.array(rhs, float)
    # relative-error weighting (targets span 29..1092 pJ/op), non-negative
    # least squares via projected gradient (tiny problem)
    Aw = A / b[:, None]
    bw = np.ones_like(b)
    x = np.maximum(np.linalg.lstsq(Aw, bw, rcond=None)[0], 0.0)
    lr = 0.5 / max(np.linalg.eigvalsh(Aw.T @ Aw).max(), 1e-12)
    for _ in range(20000):
        g = Aw.T @ (Aw @ x - bw)
        x = np.maximum(x - lr * g, 0.0)
    resid = {pr: float(A[i] @ x - b[i]) for i, pr in enumerate(protos)}
    return EnergyFit(*[float(v) for v in x], residuals=resid)


def energy_per_op(stats: Dict[str, float], fit: EnergyFit) -> float:
    """pJ per completed op for one simulation's stat totals (same
    required keys as :func:`fit_energy`; barrier waits billed at the
    clock-gated ``e_sleep`` rate).  Hierarchical-topology runs carry a
    ``hops`` total (NoC hop traversals, ``core.topologies``) billed at
    ``e_hop`` each; flat runs carry no such key and are billed exactly
    as before."""
    _require_energy_keys(stats, "energy_per_op")
    ops = max(stats["ops"], 1.0)
    total = (fit.e_msg * stats["msgs"] + fit.e_bank * stats["bank_ops"]
             + fit.e_active * (stats["active_cyc"] - stats["backoff_cyc"])
             + fit.e_backoff * stats["backoff_cyc"]
             + fit.e_sleep * (stats["sleep_cyc"] + stats["bar_cyc"]))
    if "hops" in stats:
        total += fit.e_hop * stats["hops"]
    return total / ops


#: Per-event energies fit to Table II at the canonical calibration point
#: (256 cores, 1 hot address, 12 000 cycles; ``amo_lock`` at the paper's
#: fixed 128-cycle backoff) — the values ``benchmarks/bench_energy.py``
#: regenerates, frozen here so every ``run()``/``sweep()`` result can
#: carry ``energy_pj_per_op`` without re-running the calibration sims.
#: ``tests/test_metrics.py`` checks a fresh fit stays within tolerance.
CALIBRATED_ENERGY = EnergyFit(
    e_msg=0.0, e_bank=0.0,
    e_active=0.08835048098662274, e_backoff=0.0,
    e_sleep=0.030535247039837937,
    residuals={"amo": -6.363413044962048, "colibri": 0.0,
               "lrsc": 1.4566872991167656, "amo_lock": 161.3840985167235})


def default_fit() -> EnergyFit:
    """The frozen Table II calibration (:data:`CALIBRATED_ENERGY`)."""
    return CALIBRATED_ENERGY
