"""Vectorized cycle-level simulator of an SPM manycore (MemPool-like).

This is the **faithful reproduction** layer: the paper's claims are
behavioural properties of the synchronization protocols (retries, polling
traffic, ordering, fairness), which a cycle-level protocol simulator
reproduces exactly; silicon numbers (kGE, pJ) are treated as calibration
constants in ``core.costmodel``.

Machine model
-------------
* N cores, A addresses (≤ #banks; each contended address lives in its own
  single-ported bank — one request served per bank per cycle).
* A shared request/response network with ``lat``-cycle one-way latency and a
  global bandwidth cap of ``net_bw`` accepted requests per cycle
  (models MemPool's group-level interconnect; responsible for the Fig. 5
  interference effect).
* Every core runs: local work (``work`` cycles) → atomic RMW on a
  pseudo-random address (``modify`` cycles between load and store) → repeat.

Protocols (paper Sections III–IV)
---------------------------------
* ``amo``        — single-instruction atomic add (Fig. 3 roofline).
* ``lrsc``       — MemPool LRSC: ONE reservation slot per bank, an LR
                   overwrites the previous reservation ⇒ SC retry storms.
                   Failed SC → backoff (default 128) → full LRSC retry.
* ``lrscwait``   — q reservation slots, linearized at the LR (q ≥ N =
                   LRSCwait_ideal). LR to a full queue fails immediately.
* ``colibri``    — LRSCwait with unbounded (distributed) queue; the wakeup
                   takes an extra round trip (SCwait→Qnode→WakeUpRequest→
                   memory→LR response) and SuccessorUpdates add traffic.
* ``amo_lock``   — test&set spin lock with backoff protecting the bin.
* ``lrsc_lock``  — spin lock built from an LRSC pair (two round trips per
                   attempt) with backoff.
* ``mwait_lock`` — MCS queue lock where waiters sleep via Mwait and are
                   woken by the releaser (polling-free).

All state lives in int32/bool arrays; one `lax.scan` step per cycle.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# core states
WORK, REQ, SLEEP, MOD, BACKOFF, RESP = 0, 1, 2, 3, 4, 5
# request phases
P_ACQ, P_REL = 0, 1
# resp_next codes
NXT_WORK_DONE, NXT_MOD, NXT_BACKOFF = 0, 1, 2

PROTOCOLS = ("amo", "lrsc", "lrscwait", "colibri",
             "amo_lock", "lrsc_lock", "mwait_lock")


@dataclasses.dataclass(frozen=True)
class SimParams:
    protocol: str = "colibri"
    n_cores: int = 256
    n_addrs: int = 1                 # contention: fewer addresses = hotter
    cycles: int = 20_000
    lat: int = 5                     # one-way network latency (cycles)
    work: int = 10                   # local work between atomics
    modify: int = 4                  # cycles between load and store
    # Calibrated backoff policy: base 160 with one exponential doubling
    # reproduces the paper's headline ratios (6.5x high contention, ~13% low)
    # against its nominal "128-cycle backoff" (which sits on a very steep
    # sensitivity cliff -- see EXPERIMENTS.md §Calibration).
    backoff: int = 160               # base retry backoff
    backoff_exp: int = 2             # exponential backoff: cap base<<(exp-1)
    q_slots: int = 256               # lrscwait queue capacity (≥N ⇒ ideal)
    net_bw: int = 64                 # network acceptances per cycle
    # Head-of-line blocking: requests parked at a saturated bank back up
    # through switch buffers, each `hol_block` parked requests occupy one
    # network slot (0 disables). This is the Fig.5 interference mechanism.
    hol_block: int = 16
    n_workers: int = 0               # Fig.5: cores streaming a matmul
    seed: int = 0


def _hash(x):
    """Cheap counter-based pseudo-random (Knuth multiplicative)."""
    return (x.astype(jnp.uint32) * jnp.uint32(2654435761)) >> 8




def _mset(arr, idx, mask, val):
    """Masked scatter-set: only lanes with mask write; others dropped
    (out-of-bounds index). Avoids duplicate-index races."""
    oob = jnp.full_like(idx, arr.shape[0])
    return arr.at[jnp.where(mask, idx, oob)].set(val, mode="drop")


def simulate(p: SimParams) -> Dict[str, jnp.ndarray]:
    proto = PROTOCOLS.index(p.protocol)
    n, a = p.n_cores, p.n_addrs
    is_wait = proto in (2, 3, 6)                 # queue-based protocols
    is_lock = proto >= 4
    q_cap = min(p.q_slots if proto == 2 else n, n)
    # colibri & mwait: the WakeUpRequest is dispatched when the SCwait PASSES
    # the Qnode, travelling in parallel with it — the successor's response
    # costs one response latency plus a small Qnode bounce.
    wake_delay = {3: p.lat + 2, 6: p.lat + 2}.get(proto, p.lat)
    # lrsc_lock pays two round trips per acquire attempt
    acq_rt = 2 * p.lat if proto == 5 else p.lat
    msgs_per_attempt = {0: 2, 1: 4, 2: 4, 3: 6, 4: 2, 5: 4, 6: 4}[proto]

    state = dict(
        st=jnp.full((n,), WORK, jnp.int32),
        tmr=(jnp.arange(n, dtype=jnp.int32) * 3) % (p.work + 1),  # stagger
        addr=jnp.zeros((n,), jnp.int32),
        phase=jnp.zeros((n,), jnp.int32),
        nxt=jnp.zeros((n,), jnp.int32),
        arr_cyc=jnp.full((n,), -1, jnp.int32),   # FIFO arrival stamp
        parked=jnp.zeros((n,), bool),            # accepted, waiting at bank
        resp_prev=jnp.zeros((), jnp.int32),      # last cycle's response load
        opc=jnp.zeros((n,), jnp.int32),          # per-core op counter
        streak=jnp.zeros((n,), jnp.int32),       # consecutive failures
        ops=jnp.zeros((n,), jnp.int32),          # completed ops
        # bank state
        resv_core=jnp.full((a,), -1, jnp.int32),
        resv_valid=jnp.zeros((a,), bool),
        lock=jnp.zeros((a,), bool),
        qbuf=jnp.full((a, q_cap), -1, jnp.int32),
        qhead=jnp.zeros((a,), jnp.int32),
        qlen=jnp.zeros((a,), jnp.int32),
        wake_tmr=jnp.zeros((a,), jnp.int32),
        # stats
        msgs=jnp.zeros((), jnp.int32),
        polls=jnp.zeros((), jnp.int32),          # failed attempts (retries)
        sleep_cyc=jnp.zeros((), jnp.int32),
        backoff_cyc=jnp.zeros((), jnp.int32),
        active_cyc=jnp.zeros((), jnp.int32),
        bank_ops=jnp.zeros((), jnp.int32),
        net_stall=jnp.zeros((), jnp.int32),
        # Fig.5 workers: streaming loads; progress = served requests
        w_tmr=jnp.zeros((n,), jnp.int32),
        w_served=jnp.zeros((n,), jnp.int32),
    )
    is_worker = jnp.arange(n) < p.n_workers      # first W cores are workers

    def pick_addr(core, opc, cyc):
        return (_hash(core * 7919 + opc * 104729 + p.seed) % a).astype(jnp.int32)

    def step(s, cyc):
        st, tmr = s["st"], s["tmr"]
        # ---- timers ----
        tmr = jnp.maximum(tmr - 1, 0)

        # ---- WORK done -> issue acquire ----
        start = (st == WORK) & (tmr == 0) & ~is_worker
        new_addr = pick_addr(jnp.arange(n), s["opc"], cyc)
        addr = jnp.where(start, new_addr, s["addr"])
        st = jnp.where(start, REQ, st)
        phase = jnp.where(start, P_ACQ, s["phase"])
        tmr = jnp.where(start, p.lat, tmr)

        # ---- BACKOFF done -> reissue acquire ----
        rb = (st == BACKOFF) & (tmr == 0)
        st = jnp.where(rb, REQ, st)
        phase = jnp.where(rb, P_ACQ, phase)
        tmr = jnp.where(rb, p.lat, tmr)

        # ---- MOD done -> issue release/SC ----
        md = (st == MOD) & (tmr == 0)
        st = jnp.where(md, REQ, st)
        phase = jnp.where(md, P_REL, phase)
        tmr = jnp.where(md, p.lat, tmr)

        # ---- RESP arrives ----
        ra = (st == RESP) & (tmr == 0)
        done = ra & (s["nxt"] == NXT_WORK_DONE)
        st = jnp.where(done, WORK, st)
        tmr = jnp.where(done, p.work, tmr)
        ops = s["ops"] + done
        opc = s["opc"] + done
        to_mod = ra & (s["nxt"] == NXT_MOD)
        st = jnp.where(to_mod, MOD, st)
        tmr = jnp.where(to_mod, p.modify, tmr)
        to_bo = ra & (s["nxt"] == NXT_BACKOFF)
        st = jnp.where(to_bo, BACKOFF, st)
        # lock protocols use the paper's stated FIXED backoff (Fig. 4 /
        # Table II: "spin locks with a backoff of 128 cycles"); bare LRSC
        # uses the calibrated exponential policy.
        exp_cap = 1 if is_lock else p.backoff_exp
        streak = jnp.where(to_bo, jnp.minimum(s["streak"] + 1, exp_cap),
                           jnp.where(done, 0, s["streak"]))
        bo_len = (p.backoff << jnp.maximum(streak - 1, 0)) + (_hash(
            jnp.arange(n) + cyc) % 32).astype(jnp.int32)
        tmr = jnp.where(to_bo, bo_len, tmr)

        # ---- workers stream loads (Fig. 5) ----
        w_tmr = jnp.maximum(s["w_tmr"] - 1, 0)
        w_arr = is_worker & (w_tmr == 0)         # a load arrives at a bank

        # ---- network acceptance (rotating-fair, bounded bandwidth) ----
        # A new request consumes one network slot ONCE; accepted requests are
        # "parked" in the bank input queue and no longer use the network.
        fresh = (st == REQ) & (tmr == 0) & ~is_worker & ~s["parked"]
        rot = (jnp.arange(n) + cyc * 97) % n
        big = jnp.iinfo(jnp.int32).max
        all_req = fresh | w_arr
        order = jnp.argsort(jnp.where(all_req, rot, big))
        rank = jnp.zeros((n,), jnp.int32).at[order].set(
            jnp.arange(n, dtype=jnp.int32))
        # responses issued last cycle share the same links, and parked
        # requests at saturated banks back up through switch buffers
        # (head-of-line blocking): both shrink the request budget.
        hol = (s["parked"].sum() // p.hol_block) if p.hol_block else 0
        budget = jnp.maximum(p.net_bw - s["resp_prev"] - hol, 1)
        accepted = all_req & (rank < budget)
        net_stall = s["net_stall"] + (all_req & ~accepted).sum()
        w_acc = w_arr & accepted
        w_served = s["w_served"] + w_acc
        w_tmr = jnp.where(w_acc, 2, w_tmr)       # pipelined stream of loads
        w_tmr = jnp.where(is_worker & (w_tmr == 0), 1, w_tmr)
        parked = s["parked"] | (fresh & accepted)
        arr_cyc = jnp.where(fresh & accepted, cyc, s["arr_cyc"])

        # ---- bank arbitration: FIFO by arrival stamp among parked ----
        arrived = parked & (st == REQ)
        key = arr_cyc * (n + 1) + rot            # FIFO key (int32-safe)
        bkey = jnp.where(arrived, key, big)
        best = jnp.full((a,), big, jnp.int32).at[addr].min(
            jnp.where(arrived, bkey, big))
        winner = arrived & (bkey == best[addr])
        parked = parked & ~winner                    # served
        arr_cyc = jnp.where(winner, -1, arr_cyc)

        wa, wc = addr, jnp.arange(n)             # per-core views
        is_acq = winner & (phase == P_ACQ)
        is_rel = winner & (phase == P_REL)
        bank_ops = s["bank_ops"] + winner.sum()
        msgs = s["msgs"] + 2 * winner.sum()      # req + resp
        resv_core, resv_valid = s["resv_core"], s["resv_valid"]
        lock = s["lock"]
        qbuf, qhead, qlen = s["qbuf"], s["qhead"], s["qlen"]
        wake_tmr = s["wake_tmr"]
        nxt = s["nxt"]
        polls = s["polls"]

        if proto == 0:                           # ---- amo ----
            st = jnp.where(is_acq, RESP, st)
            tmr = jnp.where(is_acq, p.lat, tmr)
            nxt = jnp.where(is_acq, NXT_WORK_DONE, nxt)

        elif proto == 1:                         # ---- lrsc ----
            # MemPool LRSC: ONE sticky reservation slot per bank. An LR takes
            # the slot only if free; otherwise it still gets the value but its
            # SC is doomed (the "sacrificed non-blocking property").
            free_slot = ~resv_valid[wa]
            got_resv = is_acq & free_slot
            resv_core = _mset(resv_core, wa, got_resv, wc)
            resv_valid = _mset(resv_valid, wa, got_resv, True)
            st = jnp.where(is_acq, RESP, st)
            tmr = jnp.where(is_acq, p.lat, tmr)
            nxt = jnp.where(is_acq, NXT_MOD, nxt)
            # SC: succeeds iff holding the reservation; owner's SC releases it
            owner = is_rel & resv_valid[wa] & (resv_core[wa] == wc)
            fail = is_rel & ~owner
            resv_valid = _mset(resv_valid, wa, owner, False)
            st = jnp.where(is_rel, RESP, st)
            tmr = jnp.where(is_rel, p.lat, tmr)
            nxt = jnp.where(owner, NXT_WORK_DONE,
                            jnp.where(fail, NXT_BACKOFF, nxt))
            polls = polls + fail.sum()

        elif proto in (2, 3):                    # ---- lrscwait / colibri ----
            empty = qlen[wa] == 0
            full = qlen[wa] >= q_cap
            grant = is_acq & empty
            enq = is_acq & ~empty & ~full
            rej = is_acq & full                  # finite-q immediate fail
            slot = (qhead[wa] + qlen[wa]) % q_cap
            put = grant | enq
            oob = jnp.full_like(wa, a)
            qbuf = qbuf.at[jnp.where(put, wa, oob), slot].set(wc, mode="drop")
            qlen = qlen.at[wa].add(jnp.where(put, 1, 0), mode="drop")
            st = jnp.where(grant, RESP, jnp.where(enq, SLEEP, st))
            tmr = jnp.where(grant, p.lat, tmr)
            nxt = jnp.where(grant, NXT_MOD, nxt)
            st = jnp.where(rej, RESP, st)
            tmr = jnp.where(rej, p.lat, tmr)
            nxt = jnp.where(rej, NXT_BACKOFF, nxt)
            polls = polls + rej.sum()
            # colibri SuccessorUpdate traffic on enqueue-behind
            if proto == 3:
                msgs = msgs + 2 * enq.sum()
            # SCwait: always valid (only the head ever gets a response)
            qhead = (qhead.at[wa].add(jnp.where(is_rel, 1, 0), mode="drop")
                     % q_cap)
            qlen = qlen.at[wa].add(jnp.where(is_rel, -1, 0), mode="drop")
            st = jnp.where(is_rel, RESP, st)
            tmr = jnp.where(is_rel, p.lat, tmr)
            nxt = jnp.where(is_rel, NXT_WORK_DONE, nxt)
            pend = is_rel & (qlen[wa] > 0)
            wake_tmr = _mset(wake_tmr, wa, pend, wake_delay)
            if proto == 3:
                msgs = msgs + 2 * pend.sum()     # WakeUpRequest + response

        elif proto in (4, 5):                    # ---- spin locks ----
            free = ~lock[wa]
            got = is_acq & free
            fail = is_acq & ~free
            lock = _mset(lock, wa, got, True)
            st = jnp.where(is_acq, RESP, st)
            tmr = jnp.where(is_acq, acq_rt, tmr)
            nxt = jnp.where(got, NXT_MOD, jnp.where(fail, NXT_BACKOFF, nxt))
            polls = polls + fail.sum()
            if proto == 5:
                msgs = msgs + 2 * is_acq.sum()   # LR+SC = two round trips
            rel = is_rel
            lock = _mset(lock, wa, rel, False)
            st = jnp.where(rel, RESP, st)
            tmr = jnp.where(rel, p.lat, tmr)
            nxt = jnp.where(rel, NXT_WORK_DONE, nxt)

        else:                                    # ---- mwait MCS lock ----
            empty = qlen[wa] == 0
            grant = is_acq & empty
            enq = is_acq & ~empty
            slot = (qhead[wa] + qlen[wa]) % q_cap
            put = grant | enq
            oob = jnp.full_like(wa, a)
            qbuf = qbuf.at[jnp.where(put, wa, oob), slot].set(wc, mode="drop")
            qlen = qlen.at[wa].add(jnp.where(put, 1, 0), mode="drop")
            st = jnp.where(grant, RESP, jnp.where(enq, SLEEP, st))
            tmr = jnp.where(grant, p.lat, tmr)
            nxt = jnp.where(grant, NXT_MOD, nxt)
            msgs = msgs + 2 * enq.sum()          # Mwait setup
            qhead = (qhead.at[wa].add(jnp.where(is_rel, 1, 0), mode="drop")
                     % q_cap)
            qlen = qlen.at[wa].add(jnp.where(is_rel, -1, 0), mode="drop")
            st = jnp.where(is_rel, RESP, st)
            tmr = jnp.where(is_rel, p.lat, tmr)
            nxt = jnp.where(is_rel, NXT_WORK_DONE, nxt)
            pend = is_rel & (qlen[wa] > 0)
            wake_tmr = _mset(wake_tmr, wa, pend, wake_delay)

        # ---- wakeups (queue-based protocols) ----
        if is_wait or proto == 6:
            fire = wake_tmr == 1
            wake_tmr = jnp.maximum(wake_tmr - 1, 0)
            head_core = qbuf[jnp.arange(a), qhead]
            # wake the head core of each firing queue
            fire_core = jnp.where(fire & (qlen > 0), head_core, n)
            woken = jnp.zeros((n,), bool).at[fire_core].set(True, mode="drop")
            st = jnp.where(woken, MOD, st)
            tmr = jnp.where(woken, p.modify, tmr)

        # network slots consumed by this cycle's responses and protocol
        # side-messages (SuccessorUpdate / WakeUpRequest / Mwait setup)
        extra = msgs - s["msgs"] - 2 * winner.sum()
        resp_load = winner.sum() + w_acc.sum() + extra
        if is_wait or proto == 6:
            resp_load = resp_load + (wake_tmr == 1).sum()
        sleep_cyc = s["sleep_cyc"] + (st == SLEEP).sum()
        backoff_cyc = s["backoff_cyc"] + (st == BACKOFF).sum()
        active_cyc = s["active_cyc"] + ((st != SLEEP) & ~is_worker).sum()

        out = dict(st=st, tmr=tmr, addr=addr, phase=phase, nxt=nxt, opc=opc,
                   arr_cyc=arr_cyc, streak=streak, parked=parked,
                   resp_prev=resp_load.astype(jnp.int32),
                   ops=ops, resv_core=resv_core, resv_valid=resv_valid,
                   lock=lock, qbuf=qbuf, qhead=qhead, qlen=qlen,
                   wake_tmr=wake_tmr, msgs=msgs, polls=polls,
                   sleep_cyc=sleep_cyc, active_cyc=active_cyc,
                   backoff_cyc=backoff_cyc,
                   bank_ops=bank_ops, net_stall=net_stall,
                   w_tmr=w_tmr, w_served=w_served)
        return out, None

    final, _ = lax.scan(step, state, jnp.arange(p.cycles, dtype=jnp.int32))
    return final


@partial(jax.jit, static_argnums=0)
def _run(p: SimParams):
    return simulate(p)


def run(p: SimParams) -> Dict[str, np.ndarray]:
    out = _run(p)
    res = {k: np.asarray(v) for k, v in out.items()}
    non_workers = p.n_cores - p.n_workers
    ops = res["ops"][p.n_workers:] if p.n_workers else res["ops"]
    res["throughput"] = float(ops.sum()) / p.cycles          # updates/cycle
    res["fairness_min"] = float(ops.min()) / p.cycles if non_workers else 0.0
    res["fairness_max"] = float(ops.max()) / p.cycles if non_workers else 0.0
    if p.n_workers:
        res["worker_rate"] = float(res["w_served"][: p.n_workers].sum()) \
            / p.cycles / p.n_workers
    return res
