"""Vectorized cycle-level engine of an SPM manycore (MemPool-like).

This is the **faithful reproduction** layer: the paper's claims are
behavioural properties of the synchronization protocols (retries, polling
traffic, ordering, fairness), which a cycle-level protocol simulator
reproduces exactly; silicon numbers (kGE, pJ) are treated as calibration
constants in ``core.costmodel``.

Machine model
-------------
* N cores, A addresses (≤ #banks; each contended address lives in its own
  single-ported bank — one request served per bank per cycle).
* A shared request/response network with ``lat``-cycle one-way latency and a
  global bandwidth cap of ``net_bw`` accepted requests per cycle
  (models MemPool's group-level interconnect; responsible for the Fig. 5
  interference effect).
* Every core runs a per-core **program** owned by a workload plugin
  (``core.workloads``): a micro-op table of local-work / atomic / barrier
  steps interpreted with a per-core program counter.  The default
  ``rmw_loop`` workload compiles to the seed behaviour — local work
  (``work`` cycles) → atomic RMW on a pseudo-random address (``modify``
  cycles between load and store) → repeat — and is bit-identical to the
  pre-workload engine.

Protocols
---------
What happens when an arbitrated request reaches its bank is owned by a
protocol *plugin* (``core.protocols``): the engine here keeps only the
protocol-agnostic machinery — per-core timers and state transitions, the
backoff policy, worker traffic, network acceptance with head-of-line
blocking, and per-bank FIFO arbitration.  ``PROTOCOLS`` lists the paper's
seven; ``repro.core.protocols.names()`` lists everything registered
(including ``colibri_hier`` and ``ticket_lock``).

All state lives in int32/bool arrays; one `lax.scan` step per cycle.
Scalar parameters (seed, n_addrs, lat, work, ...) may be **traced** — the
vmapped sweep runner (``core.sweep``) batches whole parameter grids
through one compilation of this engine.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from types import SimpleNamespace
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import metrics as metrics_mod
from repro.core import protocols as proto_registry
from repro.core import topologies as topo_registry
from repro.core import workloads as wl_registry
from repro.core.metrics import LAT_BINS, LAT_SUB
from repro.core.protocols.base import (BACKOFF, BARWAIT, MOD, NXT_BACKOFF,
                                       NXT_MOD, NXT_WORK_DONE, OUT_DONE,
                                       OUT_EVICT, OUT_FAIL, OUT_GRANT,
                                       OUT_NONE, OUT_SLEEP, P_ACQ, P_REL,
                                       REQ, RESP, SLEEP, WORK)
from repro.core.workloads.base import (ADDR_FIXED, ADDR_ZIPF, K_BARRIER,
                                       zipf_index)
from repro.faults import DROP_DENOM, FaultPlan
from repro.kernels import engine_step
from repro.obs.schema import TELE_K, TELE_NSUM, window_len

#: the paper's seven protocols (Figs. 3–6); the registry may hold more.
PROTOCOLS = ("amo", "lrsc", "lrscwait", "colibri",
             "amo_lock", "lrsc_lock", "mwait_lock")

#: execution backends for the engine hot loop.  ``auto`` resolves to the
#: best backend for the visible devices (accelerator if present, else the
#: XLA scan path); ``pallas_interpret`` runs the fused Pallas kernel in
#: interpret mode on CPU — slow, but it exercises the exact kernel
#: dataflow, which is how the backend-equivalence suite pins the kernel
#: bit-identical to the scan oracle on CPU-only hosts.
BACKENDS = ("auto", "xla_cpu", "pallas_gpu", "pallas_tpu",
            "pallas_interpret")


def _has_platform(platform: str) -> bool:
    try:
        return len(jax.devices(platform)) > 0
    except RuntimeError:
        return False


def available_backends() -> tuple:
    """The subset of :data:`BACKENDS` constructible on this host (the
    pallas device backends require a matching accelerator)."""
    avail = {"auto", "xla_cpu", "pallas_interpret"}
    if _has_platform("gpu"):
        avail.add("pallas_gpu")
    if _has_platform("tpu"):
        avail.add("pallas_tpu")
    return tuple(b for b in BACKENDS if b in avail)


def resolve_backend(backend: str) -> str:
    """Map ``auto`` onto the concrete backend for the visible devices."""
    if backend == "auto":
        if _has_platform("tpu"):
            return "pallas_tpu"
        if _has_platform("gpu"):
            return "pallas_gpu"
        return "xla_cpu"
    return backend

#: SimParams fields the engine accepts as traced scalars (sweep axes).
DYN_FIELDS = ("seed", "n_addrs", "lat", "work", "modify", "backoff",
              "backoff_exp", "net_bw", "hol_block", "n_workers",
              "zipf_skew")

#: int32 sentinel for "no request" in the arbitration primitives
_BIG = jnp.iinfo(jnp.int32).max


def fused_key_fits_int32(cycles: int, n: int) -> bool:
    """Static predicate behind the arbitration-path choice: may the
    engine use the one-segment-min fused FIFO key
    ``arr_cyc * (n + 1) + rot`` for this (horizon, core count)?

    True iff the largest possible key provably stays below the int32
    ``_BIG`` sentinel (``arr_cyc < cycles``, ``rot <= n``).  The seed
    engine assumed this always held — false at ``n_cores=1024`` past
    ~2M cycles, where the product wrapped int32 and inverted the FIFO
    order (the PR 3 bug).  ``repro.analysis.int_range`` independently
    re-derives the wrap threshold by interval arithmetic and certifies
    this predicate sound and tight, so the two must never drift: the
    engine imports THIS function, the analyzer checks it.
    """
    return cycles * (n + 1) + n <= int(_BIG)

#: element ceiling for the dense (a, n) arbitration/histogram path: with
#: a small bank×core product a masked 2-D min/sum vectorizes, while an
#: n-lane scatter serializes lane by lane on CPU (~10× the cost of a
#: dense element); past this the scatter's O(n) beats the dense O(a*n).
#: Measured crossover on the 2-vCPU reference box: a=64 @ n=256 still
#: wins dense (1.26×), a=256 @ n=256 loses (0.7×).
_DENSE_BANK_ELTS = 32768

#: under the vmapped sweep the dense intermediate is (batch, a, n) —
#: once that working set spills L2 the dense path collapses (measured
#: 0.15× at 393k elements), so ``simulate`` takes the batch size as a
#: static hint and also bounds the batched element count.
_DENSE_BATCH_ELTS = 131072


@dataclasses.dataclass(frozen=True)
class SimParams:
    protocol: str = "colibri"
    workload: str = "rmw_loop"       # per-core program (core.workloads)
    n_cores: int = 256
    # lax.scan unroll factor: XLA fuses this many simulated cycles per
    # loop iteration.  Pure compilation knob — results are bit-identical
    # at every setting (tests/test_protocols.py re-runs the goldens at
    # unroll 2 and 8 on top of the default).  With the scatter-free hot
    # path, 1 measures fastest up to 256 cores and ~2 at 1024
    # (EXPERIMENTS.md §Engine-throughput has the ablation).
    unroll: int = 1
    # Execution backend for the hot loop (see BACKENDS): "auto" picks the
    # accelerator's fused Pallas engine-step kernel when one is visible
    # and the XLA scan path otherwise.  Pure execution knob — results are
    # bit-identical across backends (tests/test_engine_backend.py pins
    # the full protocol × workload grid).
    backend: str = "auto"
    n_addrs: int = 1                 # contention: fewer addresses = hotter
    cycles: int = 20_000
    lat: int = 5                     # one-way network latency (cycles)
    work: int = 10                   # local work between atomics
    modify: int = 4                  # cycles between load and store
    # Calibrated backoff policy: base 160 with one exponential doubling
    # reproduces the paper's headline ratios (6.5x high contention, ~13% low)
    # against its nominal "128-cycle backoff" (which sits on a very steep
    # sensitivity cliff -- see EXPERIMENTS.md §Calibration).
    backoff: int = 160               # base retry backoff
    backoff_exp: int = 2             # exponential backoff: cap base<<(exp-1)
    q_slots: int = 256               # lrscwait queue capacity (≥N ⇒ ideal)
    net_bw: int = 64                 # network acceptances per cycle
    # Head-of-line blocking: requests parked at a saturated bank back up
    # through switch buffers, each `hol_block` parked requests occupy one
    # network slot (0 disables). This is the Fig.5 interference mechanism.
    hol_block: int = 16
    n_workers: int = 0               # Fig.5: cores streaming a matmul
    seed: int = 0
    n_groups: int = 4                # colibri_hier: clusters of cores
    zipf_skew: int = 100             # 100*s for ADDR_ZIPF streams (s=1.0)
    # NoC topology (core.topologies): "flat" is the historical single
    # crossbar and compiles to NO topology tables at all — the trace is
    # bit-identical to the pre-topology engine (tests/test_topology.py
    # pins the full protocol × workload grid).  Hierarchical entries
    # ("cluster2", "cluster3") close per-(core,bank) hop/latency tables
    # and per-level link budgets over the scan as constants: the carry
    # contract gains only the single ``hops`` counter.
    topology: str = "flat"
    clusters: int = 4                # leaf clusters (hierarchical topologies)
    record_trace: bool = False       # emit (cycles, n) completed-step trace
    # Windowed in-scan telemetry (repro.obs): > 0 carries a
    # (telemetry_windows, TELE_K) accumulator through the scan — a
    # per-window timeseries of core states, bank-access outcomes, queue
    # depths and NoC traffic, identical across backends and read back by
    # Result.timeseries().  0 (the default) statically elides the carry:
    # the trace is bit-identical to the pre-telemetry engine (an extra
    # written carry is a measured compile cliff — EXPERIMENTS.md
    # §Metric-cost / §Telemetry-cost).
    telemetry_windows: int = 0
    # Fault injection & recovery (repro.faults): a FaultPlan describing
    # deterministic seed-derived core kills/stalls, NoC message drops
    # (incl. lost wakeups) and bank stalls, plus the recovery knobs
    # (reservation watchdog_cyc -> protocol on_timeout eviction, and the
    # progress_cyc livelock/deadlock flag).  The default no-fault plan
    # statically elides every fault branch AND every extra scan carry —
    # the off path is bit-identical to the pre-fault engine
    # (tests/test_faults.py pins both, jaxpr carry count included).
    faults: FaultPlan = FaultPlan()

    # Early validation: bad names and impossible sizes fail HERE, with
    # the registry's available names in the message, instead of deep
    # inside a jit trace (or as a registry KeyError mid-``simulate``).
    # ``repro.sync.Spec`` lowers onto this, so both API layers share one
    # set of constraints and error texts.
    _BOUNDS = (("n_cores", 1), ("cycles", 1), ("n_addrs", 1),
               ("q_slots", 1), ("n_groups", 1), ("unroll", 1),
               ("backoff_exp", 1), ("net_bw", 1), ("lat", 0),
               ("work", 0), ("modify", 0), ("backoff", 0),
               ("hol_block", 0), ("n_workers", 0), ("zipf_skew", 0),
               ("telemetry_windows", 0), ("clusters", 1))

    def __post_init__(self):
        if self.protocol not in proto_registry.names():
            raise ValueError(
                f"unknown protocol {self.protocol!r}; registered protocols: "
                f"{', '.join(proto_registry.names())}")
        if self.workload not in wl_registry.names():
            raise ValueError(
                f"unknown workload {self.workload!r}; registered workloads: "
                f"{', '.join(wl_registry.names())}")
        if self.topology not in topo_registry.names():
            raise ValueError(
                f"unknown topology {self.topology!r}; registered topologies: "
                f"{', '.join(topo_registry.names())}")
        for fname, lo in self._BOUNDS:
            v = getattr(self, fname)
            if (not isinstance(v, (int, np.integer))
                    or isinstance(v, bool) or v < lo):
                raise ValueError(
                    f"{fname} must be an int >= {lo} (got {v!r})")
        if not isinstance(self.seed, (int, np.integer)) \
                or isinstance(self.seed, bool):
            raise ValueError(f"seed must be an int (got {self.seed!r})")
        if not isinstance(self.record_trace, (bool, np.bool_)):
            raise ValueError(
                f"record_trace must be a bool (got {self.record_trace!r})")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; available backends: "
                f"{', '.join(available_backends())}")
        if self.backend not in available_backends():
            dev = "TPU" if self.backend == "pallas_tpu" else "GPU"
            raise ValueError(
                f"backend {self.backend!r} requires a {dev} device and "
                f"none is visible to jax; available backends: "
                f"{', '.join(available_backends())}")
        # forgiving about shape, strict about content: None and plain
        # dicts (the JSON round-trip shape) normalize to a FaultPlan,
        # whose own __post_init__ owns the field validation
        if self.faults is None:
            object.__setattr__(self, "faults", FaultPlan())
        elif isinstance(self.faults, dict):
            object.__setattr__(self, "faults", FaultPlan(**self.faults))
        elif not isinstance(self.faults, FaultPlan):
            raise ValueError(
                f"faults must be a FaultPlan, a dict or None "
                f"(got {self.faults!r})")
        wl = wl_registry.get(self.workload)
        if self.n_addrs < wl.min_addrs:
            raise ValueError(
                f"workload {self.workload!r} needs n_addrs >= "
                f"{wl.min_addrs} (got {self.n_addrs})")


def _hash(x):
    """Cheap counter-based pseudo-random (Knuth multiplicative)."""
    return (x.astype(jnp.uint32) * jnp.uint32(2654435761)) >> 8


def accept_rotating_fair(all_req: jnp.ndarray, rot: jnp.ndarray,
                         budget, shift=None) -> jnp.ndarray:
    """Accept the ``budget`` requesters with the lowest rotated priority.

    O(n) replacement for the former per-cycle ``jnp.argsort`` ranking:
    ``rot`` is a permutation of ``[0, n)``, so laying the request mask
    out in rot-space and taking a cumulative sum yields each requester's
    exact rank among requesters (the stable argsort put all requesters
    first, ordered by ``rot``, which is the same ordering).

    For an arbitrary permutation the transpose into rot-space is a
    scatter and the rank read-back a gather.  The engine's rotation is
    *affine* — ``rot = (iota + shift) % n`` — so when ``shift`` is
    passed both turn into plain array rotations (``jnp.roll``), leaving
    the hot path scatter- and gather-free: roll, cumsum, roll back.
    The winner set is bit-identical either way —
    ``tests/test_arbitration.py`` proves both against the argsort path.
    """
    if shift is None:
        n = all_req.shape[0]
        req_by_rot = jnp.zeros((n,), jnp.int32).at[rot].set(
            all_req.astype(jnp.int32))
        rank = jnp.cumsum(req_by_rot)[rot] - 1   # rank among requesters
    else:
        req_by_rot = jnp.roll(all_req.astype(jnp.int32), shift)
        rank = jnp.roll(jnp.cumsum(req_by_rot), -shift) - 1
    return all_req & (rank < budget)


def _fifo_lex_best(arrived, arr_cyc, rot, addr, a: int):
    """Lexicographic (arrival stamp, rotated priority) segment-min.
    Returns ``(winner_mask (n,), best_rot (a,), valid (a,))`` — overflow
    -safe at any stamp magnitude (two chained int32 mins, no product)."""
    best_cyc = jnp.full((a,), _BIG, jnp.int32).at[addr].min(
        jnp.where(arrived, arr_cyc, _BIG))
    tie = arrived & (arr_cyc == best_cyc[addr])
    best_rot = jnp.full((a,), _BIG, jnp.int32).at[addr].min(
        jnp.where(tie, rot, _BIG))
    return tie & (rot == best_rot[addr]), best_rot, best_cyc != _BIG


def fifo_bank_winners(arrived: jnp.ndarray, arr_cyc: jnp.ndarray,
                      rot: jnp.ndarray, addr: jnp.ndarray,
                      a: int) -> jnp.ndarray:
    """Per-bank FIFO arbitration: the oldest arrival stamp wins its bank;
    rotating priority breaks same-cycle ties.

    Two chained segment-mins replace the former fused
    ``arr_cyc * (n + 1) + rot`` key, which silently overflowed int32 at
    ``n_cores=1024`` once ``arr_cyc`` passed ~2M cycles (the product
    exceeds 2^31), inverting the FIFO order.  Comparing stamps directly
    keeps the full int32 cycle horizon at any core count and is
    bit-identical to the key on every non-overflowing input.  (The
    engine statically picks the one-min fused key whenever
    ``cycles * (n + 1)`` provably fits int32, and this path otherwise.)
    """
    return _fifo_lex_best(arrived, arr_cyc, rot, addr, a)[0]


def _resolve(p: SimParams, dyn: Optional[Dict] = None) -> SimpleNamespace:
    """Parameter namespace handed to the engine and plugins.  Fields named
    in ``dyn`` become traced scalars; everything else stays a Python int
    (so the plain ``run`` path traces to exactly the constants it always
    did)."""
    vals = {f.name: getattr(p, f.name) for f in dataclasses.fields(p)}
    if dyn:
        for k, v in dyn.items():
            if k not in DYN_FIELDS:
                raise ValueError(f"{k!r} is not a sweepable field; "
                                 f"sweep axes: {DYN_FIELDS}")
            vals[k] = v
    return SimpleNamespace(**vals)


def simulate(p: SimParams, dyn: Optional[Dict] = None, batch: int = 1
             ) -> Dict[str, jnp.ndarray]:
    """One engine run.  ``p`` is static (shapes, protocol, cycle count);
    ``dyn`` optionally overrides ``DYN_FIELDS`` entries with traced
    scalars — ``p.n_addrs`` then acts as the static bank allocation upper
    bound while ``dyn["n_addrs"]`` is the live address count.  ``batch``
    is a static hint from the vmapped sweep runner: how many engine
    instances share this trace (sizes the dense-vs-scatter arbitration
    choice; never changes results)."""
    proto = proto_registry.get(p.protocol)
    wl = wl_registry.get(p.workload)
    prog = wl.program(p)
    pt = prog.tables()                   # static micro-op table (int32)
    L = prog.length
    n, a = p.n_cores, p.n_addrs
    rp = _resolve(p, dyn)
    q_cap = proto.q_cap(p, n)
    exp_cap = 1 if proto.fixed_backoff else rp.backoff_exp
    # ---- NoC topology (core.topologies) --------------------------------
    # Placement/hop/latency tables are compiled host-side ONCE per trace
    # and closed over as constants — same carry-cliff discipline as
    # telemetry/faults: ``flat`` compiles to is_flat and every topology
    # branch below is Python-gated off, tracing to exactly the
    # pre-topology jaxpr (tests/test_topology.py pins bit-identity).
    topo = topo_registry.get(p.topology)
    tt = topo.tables(p, n, a)
    use_topo = not tt.is_flat

    state = dict(
        st=jnp.full((n,), WORK, jnp.int32),
        tmr=(jnp.arange(n, dtype=jnp.int32) * 3) % (rp.work + 1),  # stagger
        addr=jnp.zeros((n,), jnp.int32),
        phase=jnp.zeros((n,), jnp.int32),
        pc=jnp.zeros((n,), jnp.int32),           # program counter
        bar_cnt=jnp.zeros((n,), jnp.int32),      # barrier arrivals
        nxt=jnp.zeros((n,), jnp.int32),
        arr_cyc=jnp.full((n,), -1, jnp.int32),   # FIFO arrival stamp
        parked=jnp.zeros((n,), bool),            # accepted, waiting at bank
        resp_prev=jnp.zeros((), jnp.int32),      # last cycle's response load
        opc=jnp.zeros((n,), jnp.int32),          # per-core op counter
        streak=jnp.zeros((n,), jnp.int32),       # consecutive failures
        ops=jnp.zeros((n,), jnp.int32),          # completed ops
        acq_start=jnp.zeros((n,), jnp.int32),    # first-issue cycle stamp
        bank=proto.init_bank_state(p, a, n, q_cap),
        xc=proto.init_core_state(p, n),
        # stats
        msgs=jnp.zeros((), jnp.int32),
        polls=jnp.zeros((), jnp.int32),          # failed attempts (retries)
        addr_ops=jnp.zeros((a,), jnp.int32),     # completed atomics per bank
        sleep_cyc=jnp.zeros((), jnp.int32),
        bar_cyc=jnp.zeros((), jnp.int32),        # cycles parked at barriers
        lat_hist=jnp.zeros((LAT_BINS,), jnp.int32),  # completion latencies
        lat_max=jnp.zeros((), jnp.int32),        # exact worst completion
        backoff_cyc=jnp.zeros((), jnp.int32),
        active_cyc=jnp.zeros((), jnp.int32),
        bank_ops=jnp.zeros((), jnp.int32),
        net_stall=jnp.zeros((), jnp.int32),
        # Fig.5 workers: streaming loads; progress = served requests
        w_tmr=jnp.zeros((n,), jnp.int32),
        w_served=jnp.zeros((n,), jnp.int32),
    )
    # hierarchical topologies carry ONE extra scalar: total NoC hop
    # traversals (requests + responses), the quantity the per-hop energy
    # term bills.  Flat runs never carry it (and their result dicts
    # never contain "hops"), keeping the 27-key contract untouched.
    if use_topo:
        state["hops"] = jnp.zeros((), jnp.int32)
    # windowed telemetry (repro.obs): the carry exists ONLY when the
    # knob is on — a Python-level gate, so the off path traces to
    # exactly the pre-telemetry scan (the PR 4 lesson: one extra
    # written carry is a compile cliff, not a rounding error)
    use_tele = p.telemetry_windows > 0
    if use_tele:
        state["tele"] = jnp.zeros((p.telemetry_windows, TELE_K), jnp.int32)
        tele_cw = window_len(p.cycles, p.telemetry_windows)
    # ---- fault injection & recovery (repro.faults) ----------------------
    # Same carry-cliff discipline as telemetry: EVERY fault carry and
    # branch below is Python-gated on the plan, so the default no-fault
    # plan traces to exactly the pre-fault scan (bit-identical, zero
    # extra carries — tests/test_faults.py asserts the jaxpr).  Victim
    # sets are drawn host-side from the plan's seed (trace constants,
    # never carried); only the holder-kill mode needs in-scan state
    # because its victims are data-dependent (the first n_kill grantees).
    fp = p.faults
    use_faults = fp.enabled
    holder_mode = use_faults and fp.n_kill > 0 and fp.kill_holder == 1
    uni_kill = use_faults and fp.n_kill > 0 and fp.kill_holder == 0
    has_stall = use_faults and fp.n_stall > 0
    has_bstall = use_faults and fp.n_bank_stall > 0
    has_drop = use_faults and fp.msg_drop_bp > 0
    any_core_fault = holder_mode or uni_kill or has_stall
    use_wd = (use_faults and fp.watchdog_cyc > 0
              and proto.held(state["bank"]) is not None)
    if use_faults:
        kill_m = jnp.asarray(fp.kill_mask(n)) if uni_kill else None
        stall_m = jnp.asarray(fp.stall_mask(n)) if has_stall else None
        bstall_m = jnp.asarray(fp.bank_stall_mask(a)) if has_bstall else None
        n_kill_eff = min(fp.n_kill, n)
        n_stall_eff = min(fp.n_stall, n)
        n_bstall_eff = min(fp.n_bank_stall, a)
        prog_thr = fp.progress_threshold()
        state["faults_injected"] = jnp.zeros((), jnp.int32)
        state["halt_cyc"] = jnp.full((), -1, jnp.int32)   # -1: never halted
        state["last_ret"] = jnp.zeros((), jnp.int32)
        if holder_mode:
            state["kmask"] = jnp.zeros((n,), bool)        # killed holders
            state["kleft"] = jnp.full((), fp.n_kill, jnp.int32)
        if use_wd:
            state["wd_srv"] = jnp.zeros((a,), jnp.int32)  # last service cyc
            state["wd_own"] = jnp.full((a,), n, jnp.int32)  # last grantee
            state["recoveries"] = jnp.zeros((), jnp.int32)
    xc_keys = tuple(state["xc"])

    # ---- closure constants hoisted out of the scan body ----------------
    # Everything here is computed ONCE per trace instead of once per
    # simulated cycle: the core-id iota, the worker mask, the per-step
    # duration/kind tables (micro-op table entries combined with the
    # possibly-traced ``work``/``modify`` scalars), and the fixed-address
    # table.  The scan body only gathers from them at ``pc``.
    iota = jnp.arange(n, dtype=jnp.int32)
    ba = jnp.arange(a, dtype=jnp.int32)
    if use_topo:
        # flattened (n*a,) tables: the scan body gathers one lane per
        # core at ``iota * a + addr`` (addr is finalized before every
        # consumer) — two O(n) gathers per cycle, no scatters
        extra_t = jnp.asarray(tt.extra.reshape(-1), jnp.int32)
        hops_t = jnp.asarray(tt.hops.reshape(-1), jnp.int32)
        cross_t = tuple(jnp.asarray(x.reshape(-1)) for x in tt.cross)
        lvl_div = tuple(lv.bw_div for lv in topo.levels)
    is_worker = iota < rp.n_workers              # first W cores are workers
    # static: worker machinery folds away when no config has workers
    # (run() always sees a Python int; sweep drops the axis when the
    # whole chunk is worker-free)
    has_workers = not (isinstance(rp.n_workers, int) and rp.n_workers == 0)
    na = rp.n_addrs
    if not isinstance(na, int):
        na = na.astype(jnp.uint32)
    pre_dur_tab = pt["pre_mult"] * rp.work + pt["pre_add"]      # (L,)
    mod_dur_tab = pt["mod_mult"] * rp.modify + pt["mod_add"]    # (L,)
    kind_is_bar = pt["kind"] == K_BARRIER                       # (L,)
    mode_is_fix = pt["addr_mode"] == ADDR_FIXED                 # (L,)
    mode_is_zipf = pt["addr_mode"] == ADDR_ZIPF                 # (L,)
    fix_tab = (pt["addr_arg"].astype(jnp.uint32) % na).astype(jnp.int32)
    has_zipf = bool(np.any(np.asarray(prog.addr_mode) == ADDR_ZIPF))
    has_bar = bool(np.any(np.asarray(prog.kind) == K_BARRIER))
    # static: can the fused FIFO key arr_cyc*(n+1)+rot ever leave int32?
    # (arr_cyc < cycles, rot <= n).  The seed engine assumed it never
    # did — false at n=1024 past ~2M cycles — so the safe two-stage
    # arbiter kicks in exactly where the old key wrapped.
    key_fits_int32 = fused_key_fits_int32(p.cycles, n)
    # execution backend: the fused Pallas engine-step kernel replaces
    # the arbitration + protocol + histogram stages of the scan body;
    # everything around it (issue, retire, network, wakeups) is shared
    bk = resolve_backend(p.backend)
    use_pallas = bk != "xla_cpu"
    pl_interpret = bk == "pallas_interpret"
    dense_banks = (a * n <= _DENSE_BANK_ELTS
                   and a * n * max(batch, 1) <= _DENSE_BATCH_ELTS)
    # same dense-vs-scatter choice for the latency histogram accumulator
    # (it runs over the a bank lanes; LAT_BINS plays the bank-count role)
    lbins = jnp.arange(LAT_BINS, dtype=jnp.int32)
    dense_lat = (LAT_BINS * a <= _DENSE_BANK_ELTS
                 and LAT_BINS * a * max(batch, 1) <= _DENSE_BATCH_ELTS)

    def step_addr(opc, pc):
        """Current micro-op's target address.  The uniform stream is the
        seed engine's counter hash, bit-identical under ``rmw_loop``."""
        h = _hash(iota * 7919 + opc * 104729 + rp.seed)
        uni = (h % na).astype(jnp.int32)
        out = jnp.where(mode_is_fix[pc], fix_tab[pc], uni)
        if has_zipf:
            out = jnp.where(mode_is_zipf[pc],
                            zipf_index(h, rp.n_addrs, rp.zipf_skew), out)
        return out

    def step(s, cyc):
        st, tmr, pc = s["st"], s["tmr"], s["pc"]
        # ---- timers ----
        tmr = jnp.maximum(tmr - 1, 0)
        t0 = tmr == 0

        # ---- fault injection: dead/stalled cores freeze ----
        # dead = permanently killed ∪ inside a transient stall window.
        # A dead core freezes (timers never fire, no new requests, no
        # retransmits) but requests already in flight still get served —
        # if one was granted a reservation, the bank wedges: exactly the
        # failure the reservation watchdog exists for.
        if any_core_fault:
            if holder_mode:
                killed = s["kmask"]
            elif uni_kill:
                killed = kill_m & (cyc >= fp.kill_cyc)
            else:
                killed = jnp.zeros((n,), bool)
            dead = killed
            if has_stall:
                dead = dead | (stall_m & (cyc >= fp.stall_cyc)
                               & (cyc < fp.stall_cyc + fp.stall_dur))
            t0 = t0 & ~dead
        if use_faults:
            finj = s["faults_injected"]
            if uni_kill:
                finj = finj + jnp.where(cyc == fp.kill_cyc, n_kill_eff, 0)
            if has_stall:
                finj = finj + jnp.where(cyc == fp.stall_cyc,
                                        n_stall_eff, 0)
            if has_bstall:
                finj = finj + jnp.where(cyc == fp.bank_stall_cyc,
                                        n_bstall_eff, 0)

        # ---- timer-expiry dispatch (one predicated block) ----
        # WORK -> issue current micro-op's acquire; BACKOFF -> reissue
        # acquire; MOD -> issue release/SC.  The three source states are
        # mutually exclusive, so a single fused REQ/latency write covers
        # what used to be three identical where-chains.
        start = t0 & (st == WORK) & ~is_worker
        rb = t0 & (st == BACKOFF)
        md = t0 & (st == MOD)
        issue = start | rb | md
        addr = jnp.where(start, step_addr(s["opc"], pc), s["addr"])
        phase = jnp.where(md, P_REL,
                          jnp.where(start | rb, P_ACQ, s["phase"]))
        st = jnp.where(issue, REQ, st)
        if use_topo:
            # cross-cluster requests pay the per-level extra latency once
            # per issue (acquire, reissue, release) — the round-trip cost
            # of the level routers on top of the flat ``lat`` baseline.
            # Billed HERE, before the request reaches the network/bank
            # stages, so protocols and the Pallas kernel never see
            # topology: backends stay bit-identical by construction.
            tmr = jnp.where(issue, rp.lat + extra_t[iota * a + addr], tmr)
        else:
            tmr = jnp.where(issue, rp.lat, tmr)

        # ---- RESP arrives: the current micro-op retires ----
        ra = t0 & (st == RESP)
        done = ra & (s["nxt"] == NXT_WORK_DONE)
        at_bar = done & kind_is_bar[pc]
        pc_next = (pc + 1) % L
        wrap = done & (pc_next == 0)             # program completed one op
        go_work = done & ~at_bar
        st = jnp.where(go_work, WORK, st)
        st = jnp.where(at_bar, BARWAIT, st)
        pc = jnp.where(done, pc_next, pc)
        # next step's local work (current step's for non-retiring cores)
        pre_dur = pre_dur_tab[pc]
        tmr = jnp.where(go_work, pre_dur, tmr)
        ops = s["ops"] + wrap
        opc = s["opc"] + done
        bar_cnt = s["bar_cnt"] + at_bar
        # completion-latency stamp: ``start`` (st==WORK, fresh micro-op)
        # and ``done`` (st==RESP) are mutually exclusive within a cycle,
        # so the stamp always predates the retirement that reads it;
        # retries (BACKOFF reissues) and queue waits keep the original
        # stamp and therefore count toward the op's latency.
        acq_start = jnp.where(start, cyc, s["acq_start"])
        if dense_banks:
            addr_ops = s["addr_ops"] + jnp.sum(
                (addr[None, :] == ba[:, None]) & done[None, :], axis=1)
        else:
            addr_ops = s["addr_ops"].at[jnp.where(done, addr, a)].add(
                1, mode="drop")
        to_mod = ra & (s["nxt"] == NXT_MOD)
        mod_dur = mod_dur_tab[pc]
        st = jnp.where(to_mod, MOD, st)
        tmr = jnp.where(to_mod, mod_dur, tmr)
        to_bo = ra & (s["nxt"] == NXT_BACKOFF)
        st = jnp.where(to_bo, BACKOFF, st)
        # lock protocols use the paper's stated FIXED backoff (Fig. 4 /
        # Table II: "spin locks with a backoff of 128 cycles"); bare LRSC
        # uses the calibrated exponential policy.
        streak = jnp.where(to_bo, jnp.minimum(s["streak"] + 1, exp_cap),
                           jnp.where(done, 0, s["streak"]))
        bo_len = (rp.backoff << jnp.maximum(streak - 1, 0)) + (_hash(
            iota + cyc) % 32).astype(jnp.int32)
        tmr = jnp.where(to_bo, bo_len, tmr)

        # ---- barrier: last arrival releases every waiter (broadcast) ----
        bar_msgs = jnp.zeros((), jnp.int32)
        if has_bar:
            min_bar = jnp.min(jnp.where(is_worker, _BIG, bar_cnt))
            rel_bar = (st == BARWAIT) & (bar_cnt <= min_bar)
            st = jnp.where(rel_bar, WORK, st)
            tmr = jnp.where(rel_bar, rp.lat + pre_dur, tmr)
            bar_msgs = rel_bar.sum().astype(jnp.int32)  # one wake msg each

        # ---- workers stream loads (Fig. 5) ----
        # the w_tmr/w_served updates are statically elided when the
        # trace has no workers: the writes are semantically dead at
        # n_workers == 0 but XLA cannot prove it, and two extra written
        # (n,) carries push the scan body over a compile cliff (~3×
        # wall time at 256 cores — EXPERIMENTS.md §Metric-cost)
        if has_workers:
            w_tmr = jnp.maximum(s["w_tmr"] - 1, 0)
            w_arr = is_worker & (w_tmr == 0)     # a load arrives at a bank
            if any_core_fault:
                w_arr = w_arr & ~dead            # dead workers go silent
        else:
            w_tmr = s["w_tmr"]
            w_arr = jnp.zeros((n,), bool)

        # ---- network acceptance (rotating-fair, bounded bandwidth) ----
        # A new request consumes one network slot ONCE; accepted requests are
        # "parked" in the bank input queue and no longer use the network.
        fresh = (st == REQ) & (tmr == 0) & ~is_worker & ~s["parked"]
        if any_core_fault:
            fresh = fresh & ~dead                # dead cores stop sending
        shift = (cyc * 97) % n
        rot = (iota + shift) % n
        all_req = fresh | w_arr
        # responses issued last cycle share the same links, and parked
        # requests at saturated banks back up through switch buffers
        # (head-of-line blocking): both shrink the request budget.
        if isinstance(rp.hol_block, int):
            hol = (s["parked"].sum() // rp.hol_block) if rp.hol_block else 0
        else:
            hol = jnp.where(rp.hol_block > 0,
                            s["parked"].sum() // jnp.maximum(rp.hol_block, 1),
                            0)
        budget = jnp.maximum(rp.net_bw - s["resp_prev"] - hol, 1)
        accepted = accept_rotating_fair(all_req, rot, budget, shift=shift)
        if use_topo:
            # per-level link capacity: a request whose (core, bank) path
            # crosses level ℓ must ALSO win one of that level's
            # ``net_bw // bw_div`` link slots this cycle (same rotating-
            # fair arbiter, same rotation — fairness is preserved level
            # by level).  Rejected requesters stay fresh and retry next
            # cycle; they count into net_stall like any denied request.
            # Worker streams stay cluster-local (their banks are the
            # local SPM ports), so only atomic requests contend here.
            xmask = [lx[iota * a + addr] & ~is_worker for lx in cross_t]
            for cm, div in zip(xmask, lvl_div):
                acc_x = accept_rotating_fair(
                    all_req & cm, rot, jnp.maximum(rp.net_bw // div, 1),
                    shift=shift)
                accepted = accepted & (~cm | acc_x)
        # Bernoulli NoC drop on newly-accepted requests: the message
        # dies in flight, the core stays in REQ and retransmits next
        # cycle; the wasted link hop is billed into msgs below
        if has_drop:
            u = _hash(iota * 9781 + cyc * 6271 + fp.fault_seed * 977 + 13)
            req_drop = (fresh & accepted
                        & ((u % DROP_DENOM) < fp.msg_drop_bp))
            accepted = accepted & ~req_drop
            n_req_drop = req_drop.sum()
            finj = finj + n_req_drop
        w_acc = w_arr & accepted
        if has_workers:
            w_served = s["w_served"] + w_acc
            w_tmr = jnp.where(w_acc, 2, w_tmr)   # pipelined stream of loads
            w_tmr = jnp.where(is_worker & (w_tmr == 0), 1, w_tmr)
        else:
            w_served = s["w_served"]
        stall_now = (all_req & ~accepted).sum()
        net_stall = s["net_stall"] + stall_now
        parked = s["parked"] | (fresh & accepted)
        arr_cyc = jnp.where(fresh & accepted, cyc, s["arr_cyc"])
        if use_topo:
            # hop accounting for the energy model: every accepted
            # request traverses its (core, bank) hop path twice (request
            # + response); accepted worker loads are cluster-local
            # single-hop round trips.
            hops_cnt = (s["hops"]
                        + 2 * jnp.where(fresh & accepted,
                                        hops_t[iota * a + addr], 0).sum()
                        + 2 * w_acc.sum())

        # ---- bank arbitration: FIFO by arrival stamp among parked ----
        arrived = parked & (st == REQ)
        # bank-stall window: stalled banks accept no requests (parked
        # requesters keep waiting); masking the arbitration INPUT makes
        # the scan and pallas paths identical by construction (the
        # kernel sees the masked cand_cyc)
        if has_bstall:
            bs_now = ((cyc >= fp.bank_stall_cyc)
                      & (cyc < fp.bank_stall_cyc + fp.bank_stall_dur))
            arrived = arrived & ~(bstall_m[addr] & bs_now)
        if use_pallas:
            # fused engine-step kernel (repro.kernels.engine_step):
            # arbitration + protocol bank update + latency histogram in
            # one tiled pass over (a, n); the engine scatters the
            # per-bank outcome codes back to the winning cores below —
            # exactly the (st, tmr, nxt) writes on_access performs via
            # masked wheres, so the two paths stay bit-identical
            # (tests/test_engine_backend.py).
            fs = engine_step.fused_step(
                proto, p, dict(s["bank"]),
                cand_cyc=jnp.where(arrived, arr_cyc, _BIG),
                rot=rot, addr=addr, phase=phase, acq_start=acq_start,
                core={f: s["xc"][f] for f in proto.fused_core_fields},
                cyc=cyc, shift=shift, lat=rp.lat,
                n=n, a=a, q_cap=q_cap, cycles=p.cycles,
                interpret=pl_interpret)
            valid_b, win_core, kind = fs["valid"], fs["win"], fs["kind"]
            winner = jnp.zeros((n,), bool).at[
                jnp.where(valid_b, win_core, n)].set(True, mode="drop")
            parked = parked & ~winner                    # served
            arr_cyc = jnp.where(winner, -1, arr_cyc)
            wcs = jnp.minimum(win_core, n - 1)           # gather-safe
            acq_b = valid_b & (phase[wcs] == P_ACQ)
            rel_b = valid_b & (phase[wcs] == P_REL)
            is_acq = winner & (phase == P_ACQ)
            is_rel = winner & (phase == P_REL)
            resp_k = ((kind == OUT_GRANT) | (kind == OUT_DONE)
                      | (kind == OUT_FAIL))
            rw = jnp.where(resp_k, win_core, n)
            st = st.at[rw].set(RESP, mode="drop")
            st = st.at[jnp.where(kind == OUT_SLEEP, win_core, n)].set(
                SLEEP, mode="drop")
            tmr = tmr.at[rw].set(fs["tmr"], mode="drop")
            nxt_code = jnp.where(
                kind == OUT_GRANT, NXT_MOD,
                jnp.where(kind == OUT_DONE, NXT_WORK_DONE,
                          NXT_BACKOFF)).astype(jnp.int32)
            nxt = s["nxt"].at[rw].set(nxt_code, mode="drop")
            cs = dict(st=st, tmr=tmr, nxt=nxt,
                      polls=s["polls"] + fs["polls"],
                      msgs=(s["msgs"] + 2 * winner.sum() + bar_msgs
                            + fs["msgs"]),
                      **{k: s["xc"][k] for k in xc_keys})
            # protocol per-core writes (e.g. the ticket lock's drawn
            # ticket) come back as (values, mask) pairs
            for f in proto.fused_xset_fields:
                val, msk = fs["xset"][f]
                cs[f] = cs[f].at[jnp.where(msk, win_core, n)].set(
                    val, mode="drop")
            bank = fs["bank"]
            ctx = proto_registry.Ctx(p=rp, n=n, a=a, q_cap=q_cap,
                                     is_acq=is_acq, is_rel=is_rel,
                                     wa=addr, wc=iota, ba=ba,
                                     win_core=win_core, acq_b=acq_b,
                                     rel_b=rel_b,
                                     mod_dur=mod_dur)
        else:
            if key_fits_int32:
                # fused lexicographic key, one segment-min (the common
                # case: the horizon is known at trace time to keep it
                # in int32)
                bkey = jnp.where(arrived, arr_cyc * (n + 1) + rot, _BIG)
                if dense_banks:        # few banks: vectorized 2-D min
                    best = jnp.min(jnp.where(addr[None, :] == ba[:, None],
                                             bkey[None, :], _BIG), axis=1)
                else:                  # many banks: one segment-min
                    best = jnp.full((a,), _BIG, jnp.int32).at[addr].min(
                        bkey)
                winner = arrived & (bkey == best[addr])
                valid_b = best != _BIG
                rot_w = best % (n + 1)   # key encodes the winner's rot
            else:
                # long horizons: chained segment-mins, no overflow
                winner, rot_w, valid_b = _fifo_lex_best(arrived, arr_cyc,
                                                        rot, addr, a)
            parked = parked & ~winner                    # served
            arr_cyc = jnp.where(winner, -1, arr_cyc)
            # decode each bank's winning CORE from its winning rot (the
            # rotation is affine) — protocols use it to update bank state
            # densely, O(a) instead of an n-lane scatter per array
            win_core = jnp.where(valid_b, (rot_w - shift) % n, n)
            wcs = jnp.minimum(win_core, n - 1)           # gather-safe

            # ---- protocol plugin handles the bank winners ----
            is_acq = winner & (phase == P_ACQ)
            is_rel = winner & (phase == P_REL)
            acq_b = valid_b & (phase[wcs] == P_ACQ)
            rel_b = valid_b & (phase[wcs] == P_REL)
            cs = dict(st=st, tmr=tmr, nxt=s["nxt"], polls=s["polls"],
                      msgs=s["msgs"] + 2 * winner.sum() + bar_msgs,
                      **{k: s["xc"][k] for k in xc_keys})
            ctx = proto_registry.Ctx(p=rp, n=n, a=a, q_cap=q_cap,
                                     is_acq=is_acq, is_rel=is_rel,
                                     wa=addr, wc=iota, ba=ba,
                                     win_core=win_core, acq_b=acq_b,
                                     rel_b=rel_b,
                                     mod_dur=mod_dur)
            cs, bank = proto.on_access(ctx, cs, dict(s["bank"]))
        bank_ops = s["bank_ops"] + winner.sum()

        # ---- telemetry: bank-access outcome tallies (pre-wake) ----
        # Derived generically instead of per-protocol: on the pallas
        # path the kernel already emits OUT_* codes per bank; on the
        # scan path the same four classes are recovered from the (st,
        # nxt) values on_access just wrote at each bank's winner — the
        # exact inverse of the engine's OUT_*->(st, nxt) apply mapping
        # (see core.protocols.base), so both backends tally identically.
        # O(a) gathers; captured BEFORE on_wake so wake-ups never
        # shadow this cycle's outcomes.
        if use_tele:
            if use_pallas:
                oc = engine_step.outcome_counts(fs["kind"])
            else:
                st_b, nxt_b = cs["st"][wcs], cs["nxt"][wcs]
                resp_b = valid_b & (st_b == RESP)
                oc = dict(
                    grants=(resp_b & (nxt_b == NXT_MOD)).sum(),
                    retires=(resp_b & (nxt_b == NXT_WORK_DONE)).sum(),
                    fails=(resp_b & (nxt_b == NXT_BACKOFF)).sum(),
                    enqueues=(valid_b & (st_b == SLEEP)).sum())
        if use_tele or use_wd or holder_mode:
            st_pre_wake = cs["st"]

        # ---- wakeups (queue-based protocols) ----
        # lost wakeup: a wake message firing this cycle drops with
        # msg_drop_bp probability — the sleeping head never hears it.
        # Without a watchdog the bank wedges forever; this is the
        # classic lost-wakeup hazard recovery must cover.
        wake_load = jnp.zeros((), jnp.int32)
        if proto.uses_queue and has_drop:
            wt = bank["wake_tmr"]
            uw = _hash(ba * 3643 + cyc * 9176 + fp.fault_seed * 389 + 7)
            wdrop = (wt == 1) & ((uw % DROP_DENOM) < fp.msg_drop_bp)
            bank["wake_tmr"] = jnp.where(wdrop, 0, wt)
            finj = finj + wdrop.sum()
        if proto.uses_queue:
            cs, bank, wake_load = proto.on_wake(ctx, cs, bank)

        # ---- fault recovery: holder kills + reservation watchdog ----
        if holder_mode or use_wd:
            # per-bank grant/retire flags.  Pallas: straight from the
            # kernel's outcome codes; scan: recovered from the (st, nxt)
            # the protocol wrote at each winner.  Reading AFTER on_wake
            # is still exact — a winner was REQ this cycle, never
            # sleeping, so on_wake cannot have touched it.
            if use_pallas:
                grant_bk = fs["kind"] == OUT_GRANT
                retire_bk = fs["kind"] == OUT_DONE
            else:
                stb, nxb = cs["st"][wcs], cs["nxt"][wcs]
                grant_bk = valid_b & (stb == RESP) & (nxb == NXT_MOD)
                retire_bk = valid_b & (stb == RESP) & (nxb
                                                       == NXT_WORK_DONE)
            # queue protocols hand ownership over by WAKE after warmup
            # (a bank-side OUT_GRANT needs an empty queue) — a woken
            # core is the new owner just as much as a granted one
            woken = (((st_pre_wake == SLEEP) & (cs["st"] != SLEEP))
                     if proto.uses_queue else jnp.zeros((n,), bool))
        if holder_mode:
            # targeted holder kill: the first n_kill cores handed
            # ownership (bank grant or wake) at or after kill_cyc die
            # while holding — the adversarial case (reservation/lock
            # owner vanishes mid-critical-section)
            gcore = jnp.zeros((n,), bool).at[
                jnp.where(grant_bk, win_core, n)].set(True, mode="drop")
            cand = (gcore | woken) & (cyc >= fp.kill_cyc) & ~s["kmask"]
            rank = jnp.cumsum(cand.astype(jnp.int32)) - 1
            newk = cand & (rank < s["kleft"])
            kmask = s["kmask"] | newk
            kleft = s["kleft"] - newk.sum()
            finj = finj + newk.sum()
            killed = kmask                       # includes this cycle's
        if use_wd:
            # reservation watchdog: per-bank service timer, re-armed on
            # every sign of life (not held / a retire / a wake handoff).
            # Grants do NOT re-arm it — under lrsc a dead holder lets
            # doomed LRs keep "granting" forever, which is exactly the
            # livelock the watchdog must see through.
            held_b = proto.held(bank)
            wd_own = jnp.where(grant_bk, win_core, s["wd_own"])
            wd_own = wd_own.at[jnp.where(woken, addr, a)].set(
                iota, mode="drop")
            wd_srv = jnp.where(~held_b | retire_bk, cyc, s["wd_srv"])
            wd_srv = wd_srv.at[jnp.where(woken, addr, a)].set(
                cyc, mode="drop")
            stuck_b = held_b & (cyc - wd_srv >= fp.watchdog_cyc)
            killed_perm = (killed if (holder_mode or uni_kill)
                           else jnp.zeros((n,), bool))
            cs, bank, rkind = proto.on_timeout(ctx, cs, bank, stuck_b,
                                               killed_perm, wd_own)
            recoveries = s["recoveries"] + (rkind != OUT_NONE).sum()
            wd_srv = jnp.where(stuck_b, cyc, wd_srv)     # re-arm
            # an eviction vacates the bank: forget the owner, else a
            # second timeout blames the dead core again and (e.g. for
            # ticket_lock) skips a LIVE waiter's turn — the next grant
            # or wake re-learns it
            wd_own = jnp.where(rkind == OUT_EVICT, n, wd_own)
        if use_faults:
            # forward-progress watchdog: no retirement anywhere for
            # prog_thr cycles => flag the halt cycle (detected livelock/
            # deadlock — the run completes and reports, never hangs)
            last_ret = jnp.where(done.any(), cyc, s["last_ret"])
            halt_cyc = jnp.where(
                (s["halt_cyc"] < 0) & (cyc - last_ret >= prog_thr),
                cyc, s["halt_cyc"])

        # network slots consumed by this cycle's responses and protocol
        # side-messages (SuccessorUpdate / WakeUpRequest / Mwait setup)
        st, tmr = cs["st"], cs["tmr"]

        # ---- completion-latency histogram (bank-side accumulation) ----
        # Every retirement is the timer expiry of a response granted at
        # a bank this cycle (protocols set st=RESP/nxt=WORK_DONE only at
        # service time and never disturb a RESP core), and arbitration
        # guarantees at most one winner per bank — so the histogram
        # update runs over the ``a`` bank lanes instead of the ``n``
        # core lanes (a is 1–16 in the hot benchmarks; the core-side
        # form measured +12 µs/cycle at 256 cores).  The grant retires
        # at ``cyc + max(tmr, 1)``; grants whose retirement falls past
        # the horizon are excluded so the histogram mass equals the
        # retired-op count exactly (the base workload invariant).  On
        # the pallas backends the kernel already accumulated this
        # cycle's rows (OUT_DONE grants are exactly the RESP/WORK_DONE
        # winners, and on_wake never touches them).
        if use_pallas:
            lat_hist = s["lat_hist"] + fs["hist"]
            lat_max = jnp.maximum(s["lat_max"], fs["lat_max"])
        else:
            fut = valid_b & (st[wcs] == RESP) & (cs["nxt"][wcs]
                                                 == NXT_WORK_DONE)
            done_cyc = cyc + jnp.maximum(tmr[wcs], 1)
            fut = fut & (done_cyc < p.cycles)
            lat_b = done_cyc - acq_start[wcs]
            lbkt = jnp.clip((LAT_SUB * jnp.log2(
                lat_b.astype(jnp.float32) + 1.0)).astype(jnp.int32),
                0, LAT_BINS - 1)
            if dense_lat:
                lat_hist = s["lat_hist"] + jnp.sum(
                    (lbkt[None, :] == lbins[:, None]) & fut[None, :],
                    axis=1)
            else:
                lat_hist = s["lat_hist"].at[
                    jnp.where(fut, lbkt, LAT_BINS)].add(1, mode="drop")
            lat_max = jnp.maximum(s["lat_max"],
                                  jnp.max(jnp.where(fut, lat_b, 0)))
        extra = cs["msgs"] - s["msgs"] - 2 * winner.sum()
        resp_load = winner.sum() + w_acc.sum() + extra + wake_load
        if has_drop:
            # the dropped request traversed the NoC once before dying;
            # billed after ``extra`` so it never occupies a response slot
            cs["msgs"] = cs["msgs"] + n_req_drop
        # per-cycle state census, shared by the cumulative stats and the
        # telemetry row (hoisted so telemetry adds no second n-lane pass)
        sleep_now = (st == SLEEP).sum()
        bar_now = (st == BARWAIT).sum()
        backoff_now = (st == BACKOFF).sum()
        active_now = ((st != SLEEP) & (st != BARWAIT) & ~is_worker).sum()
        sleep_cyc = s["sleep_cyc"] + sleep_now
        bar_cyc = s["bar_cyc"] + bar_now
        backoff_cyc = s["backoff_cyc"] + backoff_now
        active_cyc = s["active_cyc"] + active_now

        # ---- end-of-cycle queue depths (telemetry + event trace) ----
        # per-bank reservation-queue occupancy via the protocol's
        # queue_depth view (None for queueless protocols -> zeros); read
        # AFTER on_wake so popped heads are reflected
        if use_tele or p.record_trace:
            qd = proto.queue_depth(bank)
            qd = (jnp.zeros((a,), jnp.int32) if qd is None
                  else qd.astype(jnp.int32))
        out = dict(st=st, tmr=tmr, addr=addr, phase=phase, nxt=cs["nxt"],
                   pc=pc, bar_cnt=bar_cnt,
                   opc=opc, arr_cyc=arr_cyc, streak=streak, parked=parked,
                   resp_prev=resp_load.astype(jnp.int32),
                   ops=ops, acq_start=acq_start, bank=bank,
                   xc={k: cs[k] for k in xc_keys},
                   msgs=cs["msgs"], polls=cs["polls"], addr_ops=addr_ops,
                   sleep_cyc=sleep_cyc, bar_cyc=bar_cyc,
                   lat_hist=lat_hist, lat_max=lat_max,
                   active_cyc=active_cyc,
                   backoff_cyc=backoff_cyc,
                   bank_ops=bank_ops, net_stall=net_stall,
                   w_tmr=w_tmr, w_served=w_served)
        if use_topo:
            out["hops"] = hops_cnt
        if use_faults:
            out["faults_injected"] = finj
            out["last_ret"] = last_ret
            out["halt_cyc"] = halt_cyc
            if holder_mode:
                out["kmask"], out["kleft"] = kmask, kleft
            if use_wd:
                out["wd_srv"], out["wd_own"] = wd_srv, wd_own
                out["recoveries"] = recoveries
        # ---- telemetry accumulation: one window row per cycle ----
        # cyc // tele_cw is overflow-free (tele_cw is a static ceil
        # division; no cyc * n_windows product).  Column order follows
        # obs.schema.TELE_CHANNELS; the final queue_max column is
        # max-accumulated, everything else summed.
        if use_tele:
            wakes = (((st_pre_wake == SLEEP) & (st != SLEEP)).sum()
                     if proto.uses_queue else jnp.zeros((), jnp.int32))
            # NoC link locality: accepted requests split by whether the
            # (core, bank) path crosses the leaf-cluster boundary.  On
            # the flat topology the split is the Python constant
            # "everything local" — no extra work traced.
            if use_topo:
                xcl_now = (accepted & xmask[0]).sum().astype(jnp.int32)
            else:
                xcl_now = jnp.zeros((), jnp.int32)
            loc_now = accepted.sum().astype(jnp.int32) - xcl_now
            row = jnp.stack([active_now, sleep_now, backoff_now, bar_now,
                             oc["grants"], oc["retires"], oc["fails"],
                             oc["enqueues"], wakes, cs["msgs"] - s["msgs"],
                             stall_now, loc_now, xcl_now,
                             qd.sum()]).astype(jnp.int32)
            w = cyc // tele_cw
            tele = s["tele"].at[w, :TELE_NSUM].add(row)
            out["tele"] = tele.at[w, TELE_NSUM].max(qd.max())
        # completion trace: which micro-op (pre-advance pc) retired where,
        # how long it took from first acquire issue to retirement, plus
        # the per-cycle state/queue-depth traces behind Result.events()
        # and the Perfetto export (repro.obs)
        ev = (dict(step=jnp.where(done, s["pc"], -1).astype(jnp.int32),
                   wait=jnp.where(done, cyc - s["acq_start"],
                                  -1).astype(jnp.int32),
                   state=st.astype(jnp.int8), qlen=qd)
              if p.record_trace else None)
        return out, ev

    final, trace = lax.scan(step, state,
                            jnp.arange(p.cycles, dtype=jnp.int32),
                            unroll=max(int(p.unroll), 1))
    # flatten protocol state into the result dict (names never collide
    # with engine keys)
    flat = {k: v for k, v in final.items() if k not in ("bank", "xc")}
    flat.update(final["bank"])
    flat.update(final["xc"])
    if use_faults:
        # dead-at-horizon core mask for the survivor metrics (holder
        # kills come from the carry; scheduled kills/stalls are trace
        # constants — vmap broadcasts them across the batch dim)
        dm = final["kmask"] if holder_mode else jnp.zeros((n,), bool)
        if uni_kill and fp.kill_cyc < p.cycles:
            dm = dm | kill_m
        if has_stall and (fp.stall_cyc <= p.cycles - 1
                          < fp.stall_cyc + fp.stall_dur):
            dm = dm | stall_m
        flat["dead_mask"] = dm
        if not use_wd:
            flat["recoveries"] = jnp.zeros((), jnp.int32)
    if p.record_trace:
        flat["trace_step"] = trace["step"]
        flat["trace_wait"] = trace["wait"]
        flat["trace_state"] = trace["state"]
        flat["trace_qlen"] = trace["qlen"]
    return flat


@partial(jax.jit, static_argnums=0)
def _run(p: SimParams):
    return simulate(p)


def derive_metrics(res: Dict[str, np.ndarray], n_workers: int, cycles: int,
                   energy_fit=None) -> Dict[str, np.ndarray]:
    """Attach the paper's full metric set to a raw result dict — thin
    alias for :func:`repro.core.metrics.attach`, the single derivation
    layer shared with the sweep runner: throughput/worker rate, the
    fairness family (min/max, Jain index, NaN-safe span), completion-
    latency percentiles, and ``energy_pj_per_op`` under ``energy_fit``
    (default: the frozen Table II calibration).

    Degenerate configurations (``n_workers == n_cores`` leaves no atomic
    cores; ``n_workers == 0`` has no workers) consistently report 0.0
    instead of crashing on empty slices.
    """
    return metrics_mod.attach(res, n_workers, cycles, fit=energy_fit)


def execute(p: SimParams, energy_fit=None) -> Dict[str, np.ndarray]:
    """Run one configuration and return the raw metric-annotated result
    dict.  Internal engine entry point: the supported public surface is
    :func:`repro.sync.run`, which wraps this in a typed
    :class:`repro.sync.Result`."""
    out = _run(p)
    res = {k: np.asarray(v) for k, v in out.items()}
    return derive_metrics(res, min(p.n_workers, p.n_cores), p.cycles,
                          energy_fit=energy_fit)


def run(p: SimParams, energy_fit=None) -> Dict[str, np.ndarray]:
    """Deprecated legacy entry point — use ``repro.sync.run(Spec(...))``.

    Behaviour is unchanged (bit-identical result dict; the equivalence
    is locked in by ``tests/test_sync_api.py``); only the warning is
    new.
    """
    warnings.warn(
        "repro.core.sim.run() is deprecated; use repro.sync.run(Spec(...))"
        " which returns a typed Result (run().stats carries this dict).",
        DeprecationWarning, stacklevel=2)
    return execute(p, energy_fit=energy_fit)
