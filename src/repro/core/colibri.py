"""Message-level model of the Colibri protocol (paper Section IV).

The vectorized simulator (``core.sim``) measures *performance*; this model
checks *correctness*: the distributed linked-list queue built from per-core
Qnodes and per-bank head/tail registers, with ``SuccessorUpdate`` and
``WakeUpRequest`` messages subject to arbitrary delivery delays.

The test harness (hypothesis) drives ``ColibriSystem`` with adversarial
message interleavings and checks the paper's correctness argument:

* **Mutual exclusion** — at most one core holds a live reservation
  (is between its LRwait response and its SCwait) per address.
* **Exactly-once service** — every LRwait gets exactly one response; no lost
  wakeups even when a SuccessorUpdate races the SCwait (the "bounce").
* **FIFO / starvation freedom** — responses are granted in memory-arrival
  order of the LRwait requests.
* **Quiescent consistency** — when all cores are done, head/tail are empty
  and no messages are in flight.

Messages between a fixed (source, destination) pair are delivered in order
(the paper's "memory transactions are ordered" assumption); deliveries
across different pairs interleave arbitrarily (the scheduler picks).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Deque, Dict, List, Optional, Tuple

# message types
LRWAIT, SCWAIT, SUCC_UPDATE, WAKEUP_REQ, LR_RESP, SC_RESP, MWAIT, STORE = (
    "LRwait", "SCwait", "SuccUpdate", "WakeUpReq", "LRresp", "SCresp",
    "Mwait", "Store")


@dataclasses.dataclass
class Msg:
    kind: str
    src: str            # "core:<i>" | "mem" | "qnode:<i>"
    dst: str
    core: int           # issuing / target core
    succ: int = -1      # successor (SuccUpdate / WakeUpReq)
    value: int = 0


class Qnode:
    """Per-core hardware queue node."""
    def __init__(self, core: int):
        self.core = core
        self.succ: Optional[int] = None
        self.sc_passed = False      # SCwait already passed through


class ColibriSystem:
    """Single-address Colibri queue (one memory controller head/tail pair).

    Multi-address behaviour is a product of independent instances (each core
    can only be in one queue — enforced here)."""

    def __init__(self, n_cores: int, mwait: bool = False):
        self.n = n_cores
        self.mwait = mwait
        self.head: Optional[int] = None
        self.tail: Optional[int] = None
        self.reservation: Optional[int] = None   # core holding a live resv
        self.head_valid = True                   # paper: SCwait temporarily
                                                 # invalidates the head
        self.value = 0
        self.qnodes = [Qnode(i) for i in range(self.n)]
        # per-(src,dst) FIFO channels
        self.channels: Dict[Tuple[str, str], Deque[Msg]] = defaultdict(deque)
        # logs for invariant checking
        self.lr_arrival_order: List[int] = []
        self.responses: List[int] = []           # cores granted, in order
        self.sc_ok: List[int] = []
        self.outstanding: Dict[int, bool] = {}   # core -> has pending LRwait
        self.holder: Optional[int] = None        # core between LRresp & SCresp
        self.violations: List[str] = []
        # mwait
        self.mwait_value_seen: Dict[int, int] = {}

    # ---- message plumbing ----
    @staticmethod
    def _port(name: str) -> str:
        """The Qnode sits on its core's port: 'qnode:i' and 'core:i' share
        one ordered physical channel. This ordering is what makes the stale
        SuccessorUpdate always arrive before the core's next LRwait response
        (paper §IV-A: "memory transactions are ordered")."""
        return name.replace("qnode:", "core:")

    def _send(self, msg: Msg):
        self.channels[(self._port(msg.src), self._port(msg.dst))].append(msg)

    def pending_channels(self) -> List[Tuple[str, str]]:
        return [k for k, v in self.channels.items() if v]

    def deliver(self, chan: Tuple[str, str]):
        """Deliver the oldest message on a channel (scheduler's choice)."""
        msg = self.channels[chan].popleft()
        handler = {
            LRWAIT: self._mem_lrwait, SCWAIT: self._mem_scwait,
            WAKEUP_REQ: self._mem_wakeup, SUCC_UPDATE: self._qnode_succ,
            LR_RESP: self._core_lr_resp, SC_RESP: self._core_sc_resp,
            MWAIT: self._mem_lrwait, STORE: self._mem_store,
        }[msg.kind]
        handler(msg)

    # ---- core-side API (driver calls these) ----
    def core_issue_lrwait(self, core: int):
        if self.outstanding.get(core):
            raise AssertionError(f"core {core} has an outstanding LRwait "
                                 "(deadlock-freedom constraint)")
        self.outstanding[core] = True
        self.qnodes[core].succ = None
        self.qnodes[core].sc_passed = False
        kind = MWAIT if self.mwait else LRWAIT
        self._send(Msg(kind, f"core:{core}", "mem", core))

    def core_issue_scwait(self, core: int):
        """Must only be called after the LR response arrived (driver checks).

        The SCwait physically passes THROUGH the core's Qnode on its way to
        memory; the WakeUpRequest it triggers follows it on the same ordered
        channel (the paper's "memory transactions are ordered" argument), so
        the memory always processes the SCwait before the wakeup."""
        q = self.qnodes[core]
        q.sc_passed = True
        self._send(Msg(SCWAIT, f"qnode:{core}", "mem", core,
                       value=self.mwait_value_seen.get(core, 0) + 1))
        # the SCwait passes the Qnode: dispatch WakeUpRequest for a known succ
        if q.succ is not None:
            self._send(Msg(WAKEUP_REQ, f"qnode:{core}", "mem", core,
                           succ=q.succ))
            q.succ = None

    def store(self, value: int):
        """Plain store (invalidates reservations / wakes Mwait chain)."""
        self._send(Msg(STORE, "core:store", "mem", -1, value=value))

    # ---- memory controller ----
    def _mem_lrwait(self, msg: Msg):
        core = msg.core
        self.lr_arrival_order.append(core)
        if self.tail is None:                    # empty queue: become head
            self.head = self.tail = core
            if not self.mwait:
                self._grant(core)
            # Mwait: response withheld until a store (unless value differs,
            # modelled by the driver via expected-value check)
        else:
            old_tail = self.tail
            self.tail = core
            self._send(Msg(SUCC_UPDATE, "mem", f"qnode:{old_tail}", old_tail,
                           succ=core))

    def _grant(self, core: int):
        if self.holder is not None:
            self.violations.append(
                f"mutual exclusion: grant to {core} while {self.holder} holds")
        self.reservation = core
        self._send(Msg(LR_RESP, "mem", f"core:{core}", core, value=self.value))

    def _mem_scwait(self, msg: Msg):
        core = msg.core
        ok = self.reservation == core and self.head == core and self.head_valid
        if ok:
            self.value = msg.value
            self.reservation = None
            if self.holder == core:     # critical section ends at commit
                self.holder = None
            if self.head == self.tail:           # only member: trivial clear
                self.head = self.tail = None
            else:
                self.head_valid = False          # temporary invalidation
            self.sc_ok.append(core)
        else:
            self.violations.append(f"SCwait failed for core {core} "
                                   "(must never happen under LRSCwait)")
        self._send(Msg(SC_RESP, "mem", f"core:{core}", core, value=int(ok)))

    def _mem_wakeup(self, msg: Msg):
        succ = msg.succ
        self.head = succ
        self.head_valid = True
        self._grant(succ)

    def _mem_store(self, msg: Msg):
        self.value = msg.value
        if self.reservation is not None:         # store clears reservations
            self.reservation = None
        if self.mwait and self.head is not None:
            # a store releases the head Mwait response; the chain then drains
            # via Qnode bounces without further stores.
            self._grant_mwait(self.head)

    def _grant_mwait(self, core: int):
        self._send(Msg(LR_RESP, "mem", f"core:{core}", core, value=self.value))

    # ---- Qnode ----
    def _qnode_succ(self, msg: Msg):
        q = self.qnodes[msg.core]
        if q.sc_passed:
            # the bounce: SuccessorUpdate arrived after the SCwait passed
            self._send(Msg(WAKEUP_REQ, f"qnode:{msg.core}", "mem", msg.core,
                           succ=msg.succ))
        else:
            q.succ = msg.succ

    # ---- core-side responses (driver observes via callbacks) ----
    def _core_lr_resp(self, msg: Msg):
        core = msg.core
        self.responses.append(core)
        if self.mwait:
            self.outstanding[core] = False
            self.mwait_value_seen[core] = msg.value
            # Mwait wake cascades: the Qnode dispatches WakeUpReq for succ
            q = self.qnodes[core]
            q.sc_passed = True
            if q.succ is not None:
                self._send(Msg(WAKEUP_REQ, f"qnode:{core}", "mem", core,
                               succ=q.succ))
                q.succ = None
            if self.head == self.tail == core:
                self.head = self.tail = None
            elif self.head == core:
                self.head_valid = False
        else:
            self.holder = core

    def _core_sc_resp(self, msg: Msg):
        self.outstanding[msg.core] = False

    # ---- invariants ----
    def quiescent(self) -> bool:
        return not any(self.channels.values())

    def check_final(self, expected_ops: int):
        assert not self.violations, self.violations
        assert self.quiescent()
        assert self.head is None and self.tail is None, \
            f"queue not empty at quiescence: head={self.head} tail={self.tail}"
        assert len(self.responses) == expected_ops, \
            (len(self.responses), expected_ops)
        assert self.responses == self.lr_arrival_order, \
            "service order != arrival order (FIFO violated)"
        if not self.mwait:
            assert len(set(self.sc_ok)) == len(self.sc_ok) or True
            assert len(self.sc_ok) == expected_ops
