"""Colibri ordered-commit: the paper's insight as an SPMD primitive.

LRSCwait moves the linearization point of contending atomic RMW operations
from the store (retry on conflict) to the load (enqueue once, served in
order).  On an SPMD machine the analogous transformation for contended
scatter-RMW (histogram bins, embedding-gradient rows, MoE expert slots) is:

  1. **Enqueue** — a single stable sort of the request keys.  The sort IS the
     queue construction: requests to the same address form a contiguous
     segment, and arrival order (program order) is preserved inside each
     segment, giving the FIFO fairness / starvation-freedom property of
     Colibri's linked list.
  2. **Serve in order** — each segment is reduced (or assigned slots) with a
     segmented scan; every element has a unique *queue position*
     (Qnode link depth).
  3. **Commit exactly once** — one writer per address performs the final
     store.  Nothing ever retries; nothing ever polls.

XLA's native ``scatter-add`` with duplicate indices is the moral equivalent
of an LRSC retry loop (the combiner serializes conflicting updates at the
destination); this module replaces it with the sort-linearized form.
Capacity-bounded dispatch (MoE expert capacity) maps to the paper's
``LRSCwait_q``: the *oldest* q waiters win — FIFO, not random drop.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


class Dispatch(NamedTuple):
    """Result of colibri dispatch of T requests onto ``num_bins`` queues."""
    queue_pos: jnp.ndarray   # (T,) int32 — FIFO rank of each request in its bin
    counts: jnp.ndarray      # (num_bins,) int32 — requests per bin
    keep: jnp.ndarray        # (T,) bool — rank < capacity (all True if no cap)


def queue_positions(keys: jnp.ndarray, num_bins: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """FIFO queue position of each request within its bin, plus bin counts.

    keys: (T,) int32 in [0, num_bins). Stable sort ⇒ program order preserved
    per bin (starvation freedom).  Returns (queue_pos (T,), counts (bins,)).
    """
    t = keys.shape[0]
    order = jnp.argsort(keys, stable=True)
    sk = keys[order]
    seg_start = jnp.searchsorted(sk, jnp.arange(num_bins, dtype=keys.dtype))
    rank_sorted = jnp.arange(t, dtype=jnp.int32) - seg_start[sk].astype(jnp.int32)
    # invert the permutation: unique destinations -> single-writer commit
    queue_pos = jnp.zeros((t,), jnp.int32).at[order].set(rank_sorted)
    counts = jnp.bincount(keys, length=num_bins).astype(jnp.int32)
    return queue_pos, counts


def dispatch(keys: jnp.ndarray, num_bins: int,
             capacity: Optional[int] = None) -> Dispatch:
    qp, counts = queue_positions(keys, num_bins)
    keep = (qp < capacity) if capacity is not None else jnp.ones_like(qp, bool)
    return Dispatch(qp, counts, keep)


def dispatch_indices(keys: jnp.ndarray, num_bins: int, capacity: int,
                     d: Optional[Dispatch] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, Dispatch]:
    """Build (num_bins, capacity) gather table of source indices.

    Returns (src_idx, valid, dispatch). ``src_idx[e, c]`` is the request index
    occupying slot c of bin e; ``valid`` marks occupied slots. The scatter
    that builds the table has unique destinations — commit-exactly-once.
    """
    t = keys.shape[0]
    d = d if d is not None else dispatch(keys, num_bins, capacity)
    flat = keys.astype(jnp.int32) * capacity + jnp.minimum(d.queue_pos, capacity - 1)
    src = jnp.full((num_bins * capacity,), t, jnp.int32)   # t = sentinel
    src = src.at[jnp.where(d.keep, flat, num_bins * capacity)].set(
        jnp.arange(t, dtype=jnp.int32), mode="drop")
    src = src.reshape(num_bins, capacity)
    valid = src < t
    return src, valid, d


def ordered_segment_sum(keys: jnp.ndarray, values: jnp.ndarray,
                        num_bins: int) -> jnp.ndarray:
    """Sort-linearized segment sum: deterministic, retry-free scatter-add.

    values: (T, ...) summed into (num_bins, ...). Equivalent to
    ``zeros.at[keys].add(values)`` but with a single ordered commit per bin.
    """
    order = jnp.argsort(keys, stable=True)
    sk = keys[order]
    sv = values[order].astype(jnp.float32)
    csum = jnp.cumsum(sv, axis=0)
    # segment end positions: last index of each bin (searchsorted right) - 1
    ends = jnp.searchsorted(sk, jnp.arange(num_bins, dtype=keys.dtype),
                            side="right")
    starts = jnp.searchsorted(sk, jnp.arange(num_bins, dtype=keys.dtype),
                              side="left")
    zero = jnp.zeros((1,) + sv.shape[1:], sv.dtype)
    padded = jnp.concatenate([zero, csum], axis=0)         # (T+1, ...)
    out = padded[ends] - padded[starts]
    return out.astype(values.dtype)


def histogram(keys: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    """The paper's benchmark op: concurrent bin increments, polling-free."""
    return ordered_segment_sum(keys, jnp.ones_like(keys, jnp.float32),
                               num_bins).astype(jnp.int32)


def ordered_segment_reduce(keys: jnp.ndarray, values: jnp.ndarray,
                           num_bins: int, op: str = "add") -> jnp.ndarray:
    """Generic RMW flavours (the 'more complex modifications' the paper cites
    as the reason generic LRSC exists): add / max / min via sort + segmented
    associative scan with boundary resets."""
    if op == "add":
        return ordered_segment_sum(keys, values, num_bins)
    ident = {"max": -jnp.inf, "min": jnp.inf}[op]
    if keys.shape[0] == 0:                       # no requests: all identity
        return jnp.full((num_bins,), ident, jnp.float32).astype(values.dtype)
    order = jnp.argsort(keys, stable=True)
    sk = keys[order]
    sv = values[order]
    fn = {"max": jnp.maximum, "min": jnp.minimum}[op]
    is_start = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])

    def combine(a, b):
        va, sa = a
        vb, sb = b
        return jnp.where(sb, vb, fn(va, vb)), sa | sb

    scanned, _ = lax.associative_scan(combine, (sv.astype(jnp.float32), is_start))
    ends = jnp.searchsorted(sk, jnp.arange(num_bins, dtype=keys.dtype),
                            side="right")
    counts = jnp.bincount(keys, length=num_bins)
    out = jnp.where(counts > 0,
                    scanned[jnp.maximum(ends - 1, 0)],
                    jnp.float32(ident))
    return out.astype(values.dtype)


def combine_from_slots(buffer: jnp.ndarray, keys: jnp.ndarray,
                       queue_pos: jnp.ndarray, keep: jnp.ndarray,
                       weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Inverse of dispatch: gather each request's result from its
    (bin, queue_pos) slot. buffer: (num_bins, capacity, D)."""
    cap = buffer.shape[1]
    qp = jnp.minimum(queue_pos, cap - 1)
    out = buffer[keys, qp]                                  # (T, D)
    out = jnp.where(keep[:, None], out, 0)
    if weights is not None:
        out = out * weights[:, None].astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# Retry-based reference (the "LRSC" baseline the paper replaces)
# ---------------------------------------------------------------------------

def lrsc_scatter_add(keys: jnp.ndarray, values: jnp.ndarray,
                     num_bins: int) -> jnp.ndarray:
    """Native scatter-add: XLA serializes duplicate keys at the destination —
    the SPMD analogue of the SC retry loop. Used as correctness oracle and
    perf baseline in benchmarks."""
    shape = (num_bins,) + values.shape[1:]
    return jnp.zeros(shape, values.dtype).at[keys].add(values)
