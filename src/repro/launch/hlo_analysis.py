"""Compiled-HLO analysis: collective-byte accounting with loop correction.

``compiled.cost_analysis()`` (and a naive text scan) counts a while-loop
body ONCE regardless of trip count (verified: see EXPERIMENTS.md §Dry-run
notes). ``collective_bytes_corrected`` recovers trip counts from the loop
condition constants and multiplies in-loop collectives accordingly —
validated against a hand-computable nested-scan module in tests.
"""
from __future__ import annotations

import re
from typing import Dict

import numpy as np

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum per-device output bytes of every collective op in the module.
    ``-done`` ops are skipped (the ``-start`` carries the shape)."""
    out = {k: 0.0 for k in COLLECTIVES}
    count = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
        count[kind] += 1
    out["total"] = float(sum(out[k] for k in COLLECTIVES))
    out["counts"] = count  # type: ignore
    return out


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    name, buf = None, []
    for line in text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            name, buf = m.group(1), []
            comps[name] = buf
            continue
        if name is not None:
            if line.strip() == "}":
                name = None
            else:
                buf.append(line)
    return comps


def collective_bytes_corrected(hlo_text: str) -> Dict[str, float]:
    """Loop-aware collective accounting.

    XLA counts a while body once in the module text; real execution runs it
    trip-count times. Trip counts are recovered from the loop-condition
    constants (scan lowers to `compare(iv, constant(N))`), and collectives
    inside a body are multiplied by the product of enclosing trip counts.
    """
    comps = _split_computations(hlo_text)
    # map body -> trip count (max constant in its condition computation)
    body_trips: Dict[str, int] = {}
    calls: Dict[str, list] = {}           # computation -> [body names called]
    for cname, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                consts = [int(c) for c in _CONST_RE.findall(
                    "\n".join(comps.get(cond, ())))]
                body_trips[body] = max(consts) if consts else 1
                calls.setdefault(cname, []).append(body)

    # multiplier per computation = product of trip counts on the call path
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if mult.get(name, 0) >= m:
            return
        mult[name] = m
        for body in calls.get(name, ()):
            visit(body, m * body_trips.get(body, 1))

    # roots = computations that are not while bodies (entry + helpers)
    for entry in list(comps):
        if entry not in body_trips:
            visit(entry, 1.0)

    out = {k: 0.0 for k in COLLECTIVES}
    raw = {k: 0.0 for k in COLLECTIVES}
    for cname, lines in comps.items():
        m = mult.get(cname, 1.0)
        for line in lines:
            om = _OP_RE.match(line)
            if not om or "-done(" in line:
                continue
            b = _shape_bytes(om.group(1))
            out[om.group(2)] += b * m
            raw[om.group(2)] += b
    out["total"] = float(sum(out[k] for k in COLLECTIVES))
    out["total_raw"] = float(sum(raw[k] for k in COLLECTIVES))
    return out


def memory_stats(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "peak_bytes": float(ma.argument_size_in_bytes
                            + ma.output_size_in_bytes
                            + ma.temp_size_in_bytes
                            - ma.alias_size_in_bytes),
    }


def cost_stats(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):           # older jax: dict per device
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
