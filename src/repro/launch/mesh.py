"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType

    def _axis_kw(n):
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # older jax: Auto is the only behaviour anyway
    def _axis_kw(n):
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips, axes (data, model).
    Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic reshape)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_kw(len(shape)))


def use_mesh(mesh):
    """Version-proof ambient-mesh context: ``jax.set_mesh`` where it
    exists, the legacy ``Mesh`` context manager otherwise."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh
