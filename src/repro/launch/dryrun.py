import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent for every
(architecture × input shape × mesh) cell without real hardware.

For each cell:
    lowered  = jax.jit(step, in_shardings=..., donate...).lower(*specs)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # per-device bytes: the fits-proof
    print(compiled.cost_analysis())     # per-device FLOPs/bytes for §Roofline

plus the collective-byte parse of ``compiled.as_text()`` and the scan-body
correction compiles (see hlo_analysis). Results land in
``reports/dryrun/<arch>__<shape>__<mesh>.json`` — EXPERIMENTS.md §Dry-run
and benchmarks/roofline.py read from there.

NOTE the XLA_FLAGS line above MUST precede any jax import (device count is
locked at first init); smoke tests and benches see 1 device because only
this module sets the flag.
"""
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import ARCH_NAMES, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed import cache_specs, make_policy, param_specs, shardings_of
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.launch.train import batch_shardings, make_train_step, opt_state_shardings
from repro.models import build, input_specs
from repro.models import transformer as TF

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def _sds_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _abstract_params(model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, opt_cfg):
    """Returns (step_fn, arg_sds tuple, in_shardings tuple, meta)."""
    policy = make_policy(mesh, cfg)
    model = build(cfg)
    params = _abstract_params(model)
    pshard = shardings_of(param_specs(params, policy), mesh)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        opt = jax.eval_shape(lambda p: optim.init(opt_cfg, p), params)
        oshard = opt_state_shardings(opt, params, policy)
        step = make_train_step(model, opt_cfg, policy,
                               cfg.parallel.accum_steps,
                               cfg.parallel.grad_accum_dtype)
        bshard = batch_shardings(specs, policy)
        return step, (params, opt, specs), (pshard, oshard, bshard), (0, 1)

    if shape.kind == "prefill":
        def step(p, batch):
            hidden, cache = model.prefill(p, batch, shape.seq_len, policy)
            return hidden[:, -1:], cache       # last-token hidden + full cache
        bshard = batch_shardings(specs, policy)
        return step, (params, specs), (pshard, bshard), ()

    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          cache_specs(cache, policy),
                          is_leaf=lambda x: isinstance(x, P))

    def step(p, c, batch):
        return model.decode_step(p, c, batch["tokens"], batch["pos"], policy)

    bshard = batch_shardings(specs, policy)
    return step, (params, cache, specs), (pshard, cshard, bshard), (1,)


def _segment_plan(cfg: ModelConfig):
    segs = TF.plan_segments(cfg)
    if cfg.encoder is not None:
        segs = segs + [((("enc", "enc"),), cfg.encoder.num_layers)]
    return segs


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             report_dir: str = REPORT_DIR, verbose: bool = True
             ) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "kind": shape.kind}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        out["status"] = "skipped"
        out["reason"] = reason
        _write(out, report_dir)
        return out

    mesh = make_production_mesh(multi_pod=multi_pod)
    opt_cfg = optim.AdamWConfig(state_dtype=cfg.parallel.opt_state_dtype)
    t0 = time.time()
    try:
        step, args, shardings, donate = build_cell(cfg, shape, mesh, opt_cfg)
        with use_mesh(mesh):
            lowered = jax.jit(step, in_shardings=shardings,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        out["memory"] = H.memory_stats(compiled)
        out["cost"] = H.cost_stats(compiled)
        text = compiled.as_text()
        out["collectives"] = dict(H.collective_bytes_corrected(text))
        out["collectives"]["counts"] = H.collective_bytes(text)["counts"]
        out["lower_s"] = round(t_lower, 1)
        out["compile_s"] = round(t_compile, 1)
        out["segments"] = [[list(map(str, u)), r]
                           for u, r in _segment_plan(cfg)]
        out["status"] = "ok"
        if verbose:
            mem = out["memory"]
            print(f"[{arch} × {shape_name} × {mesh_name}] OK "
                  f"compile={t_compile:.0f}s "
                  f"peak/device={mem.get('peak_bytes', 0)/2**30:.2f}GiB "
                  f"flops/device={out['cost']['flops']:.3e} "
                  f"coll={out['collectives']['total']/2**20:.1f}MiB")
            print("  memory_analysis:", compiled.memory_analysis())
            print("  cost_analysis: flops=%.3e bytes=%.3e" % (
                out["cost"]["flops"], out["cost"]["bytes_accessed"]))
    except Exception as e:  # noqa: BLE001 — record the failure, don't mask it
        out["status"] = "failed"
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] FAILED: "
                  f"{out['error']}")
    _write(out, report_dir)
    return out


def _write(out: Dict[str, Any], report_dir: str):
    os.makedirs(report_dir, exist_ok=True)
    name = f"{out['arch']}__{out['shape']}__{out['mesh']}.json"
    with open(os.path.join(report_dir, name), "w") as f:
        json.dump(out, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) on this mesh")
    ap.add_argument("--report-dir", default=REPORT_DIR)
    args = ap.parse_args()

    if args.all:
        failures = 0
        for arch in ARCH_NAMES:
            for shape_name in SHAPES:
                r = run_cell(arch, shape_name, args.multi_pod,
                             args.report_dir)
                failures += r["status"] == "failed"
        raise SystemExit(1 if failures else 0)
    assert args.arch and args.shape, "--arch/--shape or --all"
    r = run_cell(args.arch, args.shape, args.multi_pod, args.report_dir)
    raise SystemExit(0 if r["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
