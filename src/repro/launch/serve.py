"""Serving driver: load (or init) a checkpoint and serve batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --prompts 8 --max-new 16
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import SHAPES, get_config
from repro.models import build
from repro.serving import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--prompts", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        step = ck.latest_step()
        if step is not None:
            state = ck.restore(step, {"params": params})
            params = state["params"]
            print(f"restored checkpoint step {step}")

    engine = ServeEngine(cfg, params, batch_size=args.batch_size,
                         cache_len=args.cache_len)
    t = threading.Thread(target=engine.serve_forever, daemon=True)
    t.start()

    rng = np.random.RandomState(0)
    t0 = time.time()
    outs = []

    def client(i):
        prompt = rng.randint(0, cfg.vocab_size,
                             size=(args.prompt_len,)).astype(np.int32)
        outs.append((i, engine.generate(prompt, args.max_new)))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(args.prompts)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    engine.stop()
    dt = time.time() - t0
    total_tokens = sum(len(o) for _, o in outs)
    for i, o in sorted(outs)[:4]:
        print(f"req {i}: {o.tolist()}")
    print(f"{args.prompts} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, event-driven batching)")


if __name__ == "__main__":
    main()
