"""Training driver: step builder + fault-tolerant loop + CLI.

``make_train_step`` builds the jit'd step with explicit in/out shardings
(params/opt donated), microbatch gradient accumulation via lax.scan, and
the colibri-dispatch MoE path when the arch calls for it.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import optim
from repro.checkpoint import Checkpointer
from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeSpec
from repro.data import SyntheticPipeline
from repro.distributed import (EventCoordinator, Policy, make_policy,
                               param_specs, shardings_of)
from repro.models import build

Params = Any


def batch_shardings(batch_like, policy: Policy):
    if policy.mesh is None:
        return None
    dp = policy.dp_axes if len(policy.dp_axes) > 1 else policy.dp_axes[0]

    def leaf(x):
        spec = [None] * x.ndim
        if x.ndim >= 1 and x.shape[0] % policy.dp_size == 0 and x.shape[0] > 0:
            spec[0] = dp
        return NamedSharding(policy.mesh, P(*spec))
    return jax.tree.map(leaf, batch_like)


def make_loss_fn(model, policy: Policy):
    def loss_fn(params, batch):
        return model.loss(params, batch, policy)
    return loss_fn


def make_train_step(model, opt_cfg: optim.AdamWConfig, policy: Policy,
                    accum_steps: int = 1, grad_accum_dtype: str = "float32"):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(model, policy)
    acc_dt = jnp.dtype(grad_accum_dtype)

    def split_micro(batch):
        def leaf(x):
            b = x.shape[0]
            return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])
        return jax.tree.map(leaf, batch)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = split_micro(batch)

            def body(acc, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(acc_dt), acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            grads, (losses, ms) = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        params, opt_state, opt_metrics = optim.update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def jit_train_step(train_step, params_like, opt_like, batch_like,
                   policy: Policy):
    """jit with explicit shardings + donation (the production entry)."""
    if policy.mesh is None:
        return jax.jit(train_step, donate_argnums=(0, 1))
    pspecs = param_specs(params_like, policy)
    pshard = shardings_of(pspecs, policy.mesh)
    oshard = opt_state_shardings(opt_like, params_like, policy)
    return jax.jit(
        train_step,
        in_shardings=(pshard, oshard, batch_shardings(batch_like, policy)),
        donate_argnums=(0, 1),
    )


def opt_state_shardings(opt_like, params_like, policy: Policy):
    """Moments shard exactly like their parameter; int8 scale tensors drop
    the (quantized) last axis spec. (PartitionSpec is a tuple subclass, so
    all traversal uses explicit is_leaf / flatten_up_to.)"""
    pspecs = param_specs(params_like, policy)
    is_p = lambda x: isinstance(x, P)
    leaves, tdef = jax.tree_util.tree_flatten(pspecs, is_leaf=is_p)

    def shard_of(spec, st):
        if isinstance(st, tuple):                # (q, scale) int8 pair
            scale_spec = P(*(list(spec)[:-1] + [None])) if len(spec) else spec
            return (NamedSharding(policy.mesh, spec),
                    NamedSharding(policy.mesh, scale_spec))
        return NamedSharding(policy.mesh, spec)

    def match(state_tree):
        parts = tdef.flatten_up_to(state_tree)
        return tdef.unflatten([shard_of(s, st)
                               for s, st in zip(leaves, parts)])

    return optim.AdamWState(NamedSharding(policy.mesh, P()),
                            match(opt_like.m), match(opt_like.v))


# ---------------------------------------------------------------------------
# Fault-tolerant training loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainRun:
    cfg: ModelConfig
    shape: ShapeSpec
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    mesh: Optional[Mesh] = None
    opt: optim.AdamWConfig = dataclasses.field(default_factory=optim.AdamWConfig)
    log_every: int = 10


def run_training(run: TrainRun, resume: bool = True,
                 crash_at: Optional[int] = None) -> Dict[str, float]:
    """The end-to-end driver. ``crash_at`` simulates a mid-run failure for
    the fault-tolerance integration test."""
    cfg = run.cfg
    policy = make_policy(run.mesh, cfg)
    model = build(cfg)
    opt_cfg = dataclasses.replace(
        run.opt, state_dtype=cfg.parallel.opt_state_dtype,
        total_steps=max(run.steps, 10))
    pipeline = SyntheticPipeline(cfg, run.shape)
    coordinator = EventCoordinator()
    ckpt = Checkpointer(run.ckpt_dir, coordinator) if run.ckpt_dir else None

    params = model.init(jax.random.PRNGKey(0))
    opt_state = optim.init(opt_cfg, params)
    start_step = 0
    if ckpt is not None and resume:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
    step_fn = make_train_step(model, opt_cfg, policy,
                              cfg.parallel.accum_steps)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    metrics = {}
    t0 = time.time()
    for step in range(start_step, run.steps):
        if crash_at is not None and step == crash_at:
            if ckpt:
                ckpt.wait()
            raise RuntimeError(f"simulated failure at step {step}")
        batch = pipeline.batch(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if ckpt is not None and (step + 1) % run.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
        if (step + 1) % run.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            rate = (step + 1 - start_step) / (time.time() - t0)
            print(f"step {step+1:5d} loss={m['loss']:.4f} "
                  f"acc={m.get('acc', 0):.3f} gnorm={m['grad_norm']:.2f} "
                  f"({rate:.2f} it/s)")
    if ckpt is not None:
        ckpt.save(run.steps, {"params": params, "opt": opt_state}, wait=True)
    out = {k: float(v) for k, v in metrics.items()}
    out["params"] = params
    out["opt_state"] = opt_state
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config + tiny shape (CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    name = args.arch + ("-smoke" if args.smoke else "")
    cfg = get_config(name)
    shape = SHAPES[args.shape]
    if args.smoke:
        shape = ShapeSpec("smoke", 128, 4, "train")
    run = TrainRun(cfg=cfg, shape=shape, steps=args.steps,
                   ckpt_dir=args.ckpt_dir,
                   opt=optim.AdamWConfig(lr=args.lr))
    out = run_training(run)
    print({k: v for k, v in out.items() if isinstance(v, float)})


if __name__ == "__main__":
    main()
