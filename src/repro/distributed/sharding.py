"""Sharding policy: mesh axes, param partition rules, activation constraints.

Axis semantics
--------------
* ``data`` (+ ``pod`` when multi-pod): batch data parallelism; also the FSDP
  (ZeRO-3) weight-shard axis for ≥100B archs and the **expert-parallel (EP)**
  axis for MoE (intra-pod a2a — hierarchical EP; pods replicate experts and
  sync grads over ``pod``).
* ``model``: tensor parallelism (attention heads / FFN intermediate / vocab)
  and the per-expert FFN shard for MoE.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """Version-proof ``shard_map``: top-level ``jax.shard_map`` where it
    exists, the experimental API (with its ``check_rep`` spelling of the
    replication check) on older jax."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Policy:
    """Distribution policy threaded through model code.

    ``mesh is None`` ⇒ single-device (smoke tests); all constraints no-op and
    MoE uses the local (collective-free) path."""
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ()          # ("pod","data") or ("data",)
    ep_axis: Optional[str] = None          # intra-pod EP axis ("data")
    tp_axis: Optional[str] = None          # "model"
    fsdp: bool = False
    use_pallas: bool = False
    sequence_parallel: bool = False

    def axis_size(self, name: Optional[str]) -> int:
        if self.mesh is None or name is None:
            return 1
        return self.mesh.shape[name]

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.axis_size(a) for a in self.dp_axes])) or 1

    def constrain(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def batch_spec(self, ndim: int) -> P:
        """Batch-leading activations: (B, ...) over dp axes."""
        if self.mesh is None:
            return P()
        return P(self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0],
                 *([None] * (ndim - 1)))


def make_policy(mesh: Optional[Mesh], cfg=None, use_pallas: bool = False) -> Policy:
    if mesh is None:
        return Policy(use_pallas=use_pallas)
    names = mesh.axis_names
    dp_axes = tuple(a for a in names if a in ("pod", "data"))
    return Policy(
        mesh=mesh,
        dp_axes=dp_axes,
        ep_axis="data" if "data" in names else None,
        tp_axis="model" if "model" in names else None,
        fsdp=bool(cfg and cfg.parallel.fsdp),
        use_pallas=use_pallas,
        sequence_parallel=bool(cfg and cfg.parallel.sequence_parallel),
    )


# ---------------------------------------------------------------------------
# Parameter partition rules
# ---------------------------------------------------------------------------
# Each rule: (path regex, spec template). Template entries name a mesh axis
# role: "tp" / "fsdp" / None. Leading "L" marks the stacked-layer axis (never
# sharded). Dims whose size is not divisible by the axis size are silently
# replicated (best-effort rule, e.g. smollm's 9 heads).

_RULES = [
    # embeddings / heads
    (r"embed$",                      ("tp", "fsdp")),
    (r"pos_embed$",                  (None, "fsdp")),
    (r"lm_head$",                    ("fsdp", "tp")),
    # attention (flat (d, H*hd) layouts)
    (r"(attn|self_attn|cross_attn|enc.*attn)\.w[qkv]$", ("L", "fsdp", "tp")),
    (r"(attn|self_attn|cross_attn|enc.*attn)\.wo$",     ("L", "tp", "fsdp")),
    (r"attn\.b[qkv]$",               ("L", "tp")),
    # MLA
    (r"attn\.w_dq$",                 ("L", "fsdp", None)),
    (r"attn\.w_uq$",                 ("L", "fsdp", "tp")),
    (r"attn\.w_dkv$",                ("L", "fsdp", None)),
    (r"attn\.w_kr$",                 ("L", "fsdp", None)),
    (r"attn\.w_uk$",                 ("L", "tp", None, "fsdp")),
    (r"attn\.w_uv$",                 ("L", "tp", "fsdp", None)),
    # dense MLPs
    (r"mlp\.w_(gate|up)$",           ("L", "fsdp", "tp")),
    (r"mlp\.w_down$",                ("L", "tp", "fsdp")),
    (r"mlp\.b_up$",                  ("L", "tp")),
    # MoE: experts (E, d, f): EP over data, per-expert TP over model
    (r"moe\.w_(gate|up)$",           ("L", "ep", None, "tp")),
    (r"moe\.w_down$",                ("L", "ep", "tp", None)),
    (r"moe\.router$",                ("L", "fsdp", None)),
    (r"shared\.w_(gate|up)$",        ("L", "fsdp", "tp")),
    (r"shared\.w_down$",             ("L", "tp", "fsdp")),
    # recurrent blocks
    (r"(rglru|rwkv)\..*w_(in|gate|r|k|v|g|out|o)$", ("L", "fsdp", "tp")),
    (r"(rglru|rwkv)\..*w_(down|proj)$",             ("L", "tp", "fsdp")),
]


def _axis_for(role: Optional[str], policy: Policy) -> Optional[str]:
    if role == "tp":
        return policy.tp_axis
    if role == "ep":
        return policy.ep_axis
    if role == "fsdp":
        # FSDP shards over the innermost dp axis ("data")
        return "data" if (policy.fsdp and policy.mesh is not None
                          and "data" in policy.mesh.axis_names) else None
    return None


def spec_for(path: str, shape: Tuple[int, ...], policy: Policy,
             stacked: bool) -> P:
    """Best-effort PartitionSpec for a param at ``path`` with ``shape``."""
    if policy.mesh is None:
        return P()
    for pat, template in _RULES:
        if re.search(pat, path):
            tpl = list(template)
            if tpl and tpl[0] == "L":
                tpl = tpl[1:]
                if stacked:
                    tpl = [None] + tpl
            elif stacked:
                tpl = [None] + tpl
            tpl = (tpl + [None] * len(shape))[: len(shape)]
            out = []
            for dim, role in zip(shape, tpl):
                ax = _axis_for(role, policy)
                if ax is not None and dim % policy.axis_size(ax) == 0 \
                        and dim >= policy.axis_size(ax):
                    out.append(ax)
                else:
                    out.append(None)
            return P(*out)
    return P()  # norms, biases, small vectors: replicated


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def param_specs(params: Params, policy: Policy) -> Params:
    """PartitionSpec pytree matching ``params``. Stacked-layer arrays are
    detected by path prefix ('blocks.' / 'enc_blocks.' / 'segments.')."""
    def leaf_spec(path, leaf):
        ps = _path_str(path)
        stacked = any(s in ps for s in ("blocks.", "segments.", "enc_blocks."))
        return spec_for(ps, np.shape(leaf), policy, stacked)
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def shardings_of(tree_specs: Params, mesh: Mesh) -> Params:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def cache_specs(cache: Params, policy: Policy) -> Params:
    """KV caches / recurrent states. Leaves are stacked over layers:
    (L, B, S, heads, hd) — shard the BATCH dim (axis 1) over dp and the
    seq/heads dim (axis 2) over model when divisible. Batch < dp size
    (long_500k B=1) replicates."""
    if policy.mesh is None:
        return jax.tree.map(lambda _: P(), cache)
    dp = policy.dp_axes if len(policy.dp_axes) > 1 else policy.dp_axes[0]
    dp_size = policy.dp_size
    tp = policy.tp_axis
    tp_size = policy.axis_size(tp)

    def leaf(x):
        shape = np.shape(x)
        spec = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % dp_size == 0 and shape[1] >= dp_size:
            spec[1] = dp
        if tp and len(shape) >= 4 and shape[2] % tp_size == 0 \
                and shape[2] >= tp_size:
            spec[2] = tp
        return P(*spec)
    return jax.tree.map(leaf, cache)
