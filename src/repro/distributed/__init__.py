from repro.distributed.coordinator import ElasticController, EventCoordinator
from repro.distributed.sharding import (Policy, cache_specs, make_policy,
                                        param_specs, shardings_of)

__all__ = ["Policy", "make_policy", "param_specs", "cache_specs",
           "shardings_of", "EventCoordinator", "ElasticController"]
