"""Event-driven host coordinator — the framework-level Mwait analogue.

The paper's Mwait lets a core sleep until a memory location changes instead
of polling it. At the training-framework level the same anti-pattern is a
coordinator thread polling "is the checkpoint done? did a worker die?" in a
loop. This coordinator is condition-variable based: waiters sleep on an
event name (optionally with an *expected value* — Mwait's race-closing
check) and are woken exactly when it fires.

Used by: async checkpointing (save-complete events), the elastic controller
(membership-change events), and the serving engine's request queue.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional


class EventCoordinator:
    def __init__(self):
        self._cv = threading.Condition()
        self._values: Dict[str, Any] = {}
        self._seq: Dict[str, int] = defaultdict(int)
        self._subscribers: Dict[str, List[Callable]] = defaultdict(list)

    def notify(self, event: str, **payload):
        """Fire an event (the 'store' that wakes Mwait sleepers)."""
        with self._cv:
            self._values[event] = payload
            self._seq[event] += 1
            subs = list(self._subscribers.get(event, ()))
            self._cv.notify_all()
        for fn in subs:
            fn(**payload)

    def wait(self, event: str, *, expected: Any = None,
             timeout: Optional[float] = None) -> Any:
        """Sleep until ``event`` fires. Like Mwait's expected-value check:
        if the current value already differs from ``expected``, return
        immediately (the change we were waiting for already happened)."""
        with self._cv:
            if event in self._values and self._values[event] != expected:
                return self._values[event]
            start_seq = self._seq[event]
            ok = self._cv.wait_for(lambda: self._seq[event] > start_seq,
                                   timeout=timeout)
            if not ok:
                raise TimeoutError(f"wait({event!r}) timed out")
            return self._values[event]

    def subscribe(self, event: str, fn: Callable):
        with self._cv:
            self._subscribers[event].append(fn)

    def value(self, event: str) -> Any:
        with self._cv:
            return self._values.get(event)


class ElasticController:
    """Membership / failure bookkeeping for elastic multi-pod training.

    On a real cluster the notifications come from the job scheduler; here
    they are injected by tests and the failure-resume example. Policy:
    * a failed worker triggers restore-from-latest + mesh re-shape,
    * scale-up/down re-shards the same checkpoint onto the new mesh
      (``Checkpointer.restore`` with a new sharding_fn).
    """

    def __init__(self, coordinator: EventCoordinator, n_workers: int):
        self.coord = coordinator
        self.n_workers = n_workers
        self.alive = set(range(n_workers))
        coordinator.subscribe("worker_failed", self._on_fail)
        coordinator.subscribe("worker_joined", self._on_join)

    def _on_fail(self, worker: int, **_):
        self.alive.discard(worker)
        self.coord.notify("membership_changed", alive=len(self.alive))

    def _on_join(self, worker: int, **_):
        self.alive.add(worker)
        self.coord.notify("membership_changed", alive=len(self.alive))

    def healthy(self) -> bool:
        return len(self.alive) == self.n_workers
