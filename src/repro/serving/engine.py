"""Batched serving engine: prefill + decode with KV caches.

The request queue is event-driven (``EventCoordinator`` — the Mwait
analogue): the engine thread sleeps until requests arrive instead of
polling. Batching is continuous-lite: a fixed-width decode batch whose
finished slots are refilled from the queue at each step (slot assignment
goes through the colibri dispatch — FIFO, no slot races).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import EventCoordinator, Policy
from repro.models import build


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    id: int = 0
    result: Optional[np.ndarray] = None
    done: threading.Event = dataclasses.field(default_factory=threading.Event)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 cache_len: int = 256, policy: Policy = Policy()):
        self.cfg = cfg
        self.model = build(cfg)
        self.params = params
        self.batch = batch_size
        self.cache_len = cache_len
        self.policy = policy
        self.coord = EventCoordinator()
        self.requests: "queue.Queue[Request]" = queue.Queue()
        self._stop = False

        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len, policy))
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos, policy))

    # ------------------------------------------------------------- client
    def submit(self, req: Request):
        self.requests.put(req)
        self.coord.notify("request_arrived", qsize=self.requests.qsize())

    def generate(self, prompt: np.ndarray, max_new_tokens: int = 16
                 ) -> np.ndarray:
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens)
        self.submit(req)
        req.done.wait()
        return req.result

    # ------------------------------------------------------------- engine
    def run_once(self) -> int:
        """Drain up to ``batch`` requests, serve them, return count.
        (Greedy decoding; per-request prompt lengths are right-aligned into
        a common grid via left-padding.)"""
        batch: List[Request] = []
        while len(batch) < self.batch and not self.requests.empty():
            batch.append(self.requests.get())
        if not batch:
            return 0
        b = len(batch)
        lens = np.array([len(r.prompt) for r in batch], np.int32)
        max_prompt = int(lens.max())
        # RIGHT pad: causal attention keeps pad K/V invisible to real tokens,
        # and per-seq decode positions overwrite pad slots before attending
        # to them. (Recurrent archs need equal-length prompts — documented.)
        toks = np.zeros((b, max_prompt), np.int32)
        for i, r in enumerate(batch):
            toks[i, : len(r.prompt)] = r.prompt
        pre_batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "audio":
            pre_batch["encoder_feats"] = jnp.zeros(
                (b, self.cfg.encoder.seq_len, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype))
        if self.cfg.frontend == "vlm":
            pre_batch["patch_embeds"] = jnp.zeros(
                (b, min(self.cfg.num_patches, max_prompt), self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype))
        hidden, cache = self._prefill(self.params, pre_batch)
        last = jnp.asarray(lens - 1)
        h_last = jnp.take_along_axis(
            hidden, last[:, None, None].astype(jnp.int32).repeat(
                hidden.shape[-1], axis=-1), axis=1)      # (B,1,d) per-seq last
        logits = (h_last @ (
            self.params["embed"].T if self.cfg.tie_embeddings
            else self.params["lm_head"]).astype(hidden.dtype)
        ).astype(jnp.float32)
        outs = [[] for _ in range(b)]
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        max_new = max(r.max_new_tokens for r in batch)
        for step in range(max_new):
            for i in range(b):
                if step < batch[i].max_new_tokens:
                    outs[i].append(int(tok[i, 0]))
            pos = jnp.asarray(lens + step, jnp.int32)    # per-seq position
            logits, cache = self._decode(self.params, cache, tok, pos)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for i, r in enumerate(batch):
            r.result = np.array(outs[i][: r.max_new_tokens], np.int32)
            r.done.set()
        return b

    def serve_forever(self):
        """Event-driven loop: sleep until a request arrives (no polling)."""
        while not self._stop:
            if self.requests.empty():
                try:
                    self.coord.wait("request_arrived", timeout=0.5)
                except TimeoutError:
                    continue
            self.run_once()

    def stop(self):
        self._stop = True
