"""stablelm-3b — dense, MHA, partial rotary, LayerNorm.
[hf:stabilityai/stablelm-2-1_6b; unverified] 32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304
"""
from repro.configs.base import ModelConfig, ParallelSpec

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    head_dim=80,
    block_pattern=("attn",),
    norm="layernorm",
    partial_rotary_factor=0.25,
    rope_theta=10000.0,
    parallel=ParallelSpec(fsdp=False, opt_state_dtype="float32", remat=True,
                          sequence_parallel=True),
)
