"""recurrentgemma-2b — RG-LRU + local attention, 1:2 pattern (Griffin).
Sub-quadratic: runs long_500k.
[arXiv:2402.19427; hf] 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000
"""
from repro.configs.base import ModelConfig, ParallelSpec, RecurrentSpec

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,               # pattern rglru,rglru,local cycled (1:2 attn:rnn)
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    act="gelu",
    recurrent=RecurrentSpec(lru_width=2560, conv1d_width=4),
    rope_theta=10000.0,
    parallel=ParallelSpec(fsdp=False, opt_state_dtype="float32", remat=True,
                          sequence_parallel=True),
)
