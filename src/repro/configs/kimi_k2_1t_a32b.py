"""kimi-k2-1t-a32b — trillion-param MoE (paper-table config).
Per the assigned table: GQA kv=8 (the real model is MLA-based; we follow the
assigned table — see DESIGN.md §Arch-applicability). 384 routed experts top-8,
1 shared expert, first layer dense.
[arXiv:2501.kimi2; unverified] 61L d_model=7168 64H (GQA kv=8) d_ff(expert)=2048 vocab=163840
"""
from repro.configs.base import MoESpec, ModelConfig, ParallelSpec

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,                   # routed expert d_ff
    vocab_size=163840,
    head_dim=112,                # 7168 / 64
    block_pattern=("attn",),
    moe=MoESpec(num_experts=384, top_k=8, d_ff_expert=2048,
                num_shared_experts=1, capacity_factor=1.25,
                moe_layer_start=1, dense_d_ff=18432),
    rope_theta=50000.0,
    parallel=ParallelSpec(fsdp=True, opt_state_dtype="int8", remat=True,
                          accum_steps=8,
                          grad_accum_dtype="bfloat16"),
)
