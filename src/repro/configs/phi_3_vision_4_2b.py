"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (STUB: patch
embeddings come precomputed via input_specs()).
[hf:microsoft/Phi-3-vision-128k-instruct; hf] 32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064
"""
from repro.configs.base import ModelConfig, ParallelSpec

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    block_pattern=("attn",),
    frontend="vlm",
    num_patches=256,
    rope_theta=10000.0,
    parallel=ParallelSpec(fsdp=False, opt_state_dtype="float32", remat=True,
                          sequence_parallel=True),
)
