"""Config dataclasses for the repro framework.

Pure python — importing configs must never touch jax device state
(the dry-run sets XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts block spec (GShard-style EP with colibri dispatch)."""
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 1
    capacity_factor: float = 1.25
    # Layer index at which MoE layers begin (earlier layers are dense).
    moe_layer_start: int = 1
    # d_ff used by the dense (non-MoE) leading layers.
    dense_d_ff: int = 0
    router_noise: float = 0.0
    # Aux load-balance loss weight.
    aux_loss_weight: float = 0.001


@dataclass(frozen=True)
class MLASpec:
    """Multi-head latent attention (DeepSeek-V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class EncoderSpec:
    """Encoder stack for enc-dec models (whisper). Frontend is a stub:
    ``input_specs`` provides precomputed frame embeddings."""
    num_layers: int
    seq_len: int = 1500          # whisper: 30 s of audio -> 1500 frames


@dataclass(frozen=True)
class RecurrentSpec:
    """RG-LRU (recurrentgemma) / RWKV-6 recurrence parameters."""
    lru_width: int = 0           # rg-lru recurrent width (0 -> d_model)
    conv1d_width: int = 4        # temporal conv in the recurrent block
    head_dim: int = 64           # rwkv6 wkv head size


@dataclass(frozen=True)
class ParallelSpec:
    """Per-arch distribution policy."""
    fsdp: bool = False           # shard weights over the data axis too (ZeRO-3)
    opt_state_dtype: str = "float32"   # float32 | bfloat16 | int8
    remat: bool = True
    accum_steps: int = 1
    grad_accum_dtype: str = "float32"   # bfloat16 halves the accum buffer
    # Sequence-parallel residual path (hillclimb feature; see §Perf).
    sequence_parallel: bool = False


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    # Block pattern, cycled over layers. "attn" = full attn + mlp,
    # "local" = sliding-window attn + mlp, "rglru" = RG-LRU + mlp,
    # "rwkv" = rwkv6 time-mix + channel-mix.
    block_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 2048
    attn_kind: str = "gqa"       # gqa | mla
    qkv_bias: bool = False
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "silu"            # silu | gelu
    rope_theta: float = 10000.0
    partial_rotary_factor: float = 1.0   # fraction of head_dim rotated
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    encoder: Optional[EncoderSpec] = None
    recurrent: Optional[RecurrentSpec] = None
    frontend: Optional[str] = None       # None | "audio" | "vlm"
    num_patches: int = 256               # vlm stub patch count
    parallel: ParallelSpec = field(default_factory=ParallelSpec)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, cycling block_pattern over num_layers."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def is_attention_free(self) -> bool:
        kinds = set(self.layer_kinds())
        return not (kinds & {"attn", "local"})

    def is_subquadratic(self) -> bool:
        """True if no full-attention layer (local windows / recurrence only)."""
        kinds = set(self.layer_kinds())
        return "attn" not in kinds

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                  # lm head
        def attn_params() -> int:
            if self.attn_kind == "mla":
                m = self.mla
                p = d * m.q_lora_rank
                p += m.q_lora_rank * nq * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
                p += nq * m.v_head_dim * d
                return p
            return d * (nq + 2 * nkv) * hd + nq * hd * d
        def mlp_params(ff: int) -> int:
            mult = 3 if self.act == "silu" else 2         # gated vs plain
            return mult * d * ff
        for i, kind in enumerate(self.layer_kinds()):
            total += 2 * d                                # norms
            if kind in ("attn", "local"):
                total += attn_params()
                total += self._ff_params_for_layer(i, mlp_params)
            elif kind == "rglru":
                w = (self.recurrent.lru_width or d) if self.recurrent else d
                total += 2 * d * w + 2 * w + w * self.recurrent.conv1d_width + w * d
                total += mlp_params(self.d_ff)
            elif kind == "rwkv":
                total += 6 * d * d                        # time-mix r,k,v,g,o + decay
                total += 2 * d * self.d_ff                # channel mix
        if self.encoder is not None:
            e = self.encoder
            per = d * (nq + 2 * nq) * hd + nq * hd * d + 2 * d * self.d_ff + 4 * d
            total += e.num_layers * per
            total += e.seq_len * d                        # learned pos emb
            # cross-attention in every decoder layer
            total += self.num_layers * (d * (nq + 2 * nq) * hd + nq * hd * d + 2 * d)
        return total

    def _ff_params_for_layer(self, i: int, mlp_params) -> int:
        if self.moe is not None and i >= self.moe.moe_layer_start:
            m = self.moe
            p = self.d_model * m.num_experts                        # router
            p += m.num_experts * 3 * self.d_model * m.d_ff_expert   # routed
            p += m.num_shared_experts * 3 * self.d_model * m.d_ff_expert
            return p
        if self.moe is not None and self.moe.dense_d_ff:
            return mlp_params(self.moe.dense_d_ff)
        return mlp_params(self.d_ff)

    def num_active_params(self) -> int:
        """Parameters touched per token (MoE: only routed-to experts)."""
        if self.moe is None:
            return self.num_params()
        m = self.moe
        total = self.num_params()
        # subtract non-active routed experts
        n_moe_layers = sum(1 for i in range(self.num_layers) if i >= m.moe_layer_start)
        per_expert = 3 * self.d_model * m.d_ff_expert
        total -= n_moe_layers * (m.num_experts - m.top_k) * per_expert
        return total


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(applicable, reason). long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 2 * len(cfg.block_pattern)),
        d_model=128,
        num_heads=4,
        num_kv_heads=(1 if cfg.num_kv_heads == 1
                      else 2 if cfg.num_kv_heads < cfg.num_heads else 4),
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        param_dtype="float32",
        compute_dtype="float32",
        parallel=ParallelSpec(fsdp=False, remat=False),
    )
    if cfg.num_kv_heads == 1:
        kw["num_kv_heads"] = 1
    if cfg.moe is not None:
        kw["moe"] = MoESpec(num_experts=8, top_k=2, d_ff_expert=64,
                            num_shared_experts=cfg.moe.num_shared_experts,
                            moe_layer_start=min(cfg.moe.moe_layer_start, 1),
                            dense_d_ff=256 if cfg.moe.dense_d_ff else 0)
    if cfg.mla is not None:
        kw["mla"] = MLASpec(q_lora_rank=64, kv_lora_rank=32,
                            qk_nope_head_dim=32, qk_rope_head_dim=16,
                            v_head_dim=32)
    if cfg.encoder is not None:
        kw["encoder"] = EncoderSpec(num_layers=2, seq_len=16)
    if cfg.recurrent is not None:
        kw["recurrent"] = RecurrentSpec(
            lru_width=128 if cfg.recurrent.lru_width else 0,
            conv1d_width=cfg.recurrent.conv1d_width,
            head_dim=32)
    kw["local_window"] = min(cfg.local_window, 64)
    kw["num_patches"] = min(cfg.num_patches, 8)
    return dataclasses.replace(cfg, **kw)
