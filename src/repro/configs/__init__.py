"""Architecture registry: ``--arch <id>`` resolution.

All assigned architectures plus the paper's own simulator configs.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (
    SHAPES,
    EncoderSpec,
    MLASpec,
    MoESpec,
    ModelConfig,
    ParallelSpec,
    RecurrentSpec,
    ShapeSpec,
    reduced,
    shape_applicable,
)

_ARCH_MODULES = {
    "whisper-large-v3":   "repro.configs.whisper_large_v3",
    "qwen2-7b":           "repro.configs.qwen2_7b",
    "stablelm-3b":        "repro.configs.stablelm_3b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "smollm-135m":        "repro.configs.smollm_135m",
    "kimi-k2-1t-a32b":    "repro.configs.kimi_k2_1t_a32b",
    "deepseek-v3-671b":   "repro.configs.deepseek_v3_671b",
    "phi-3-vision-4.2b":  "repro.configs.phi_3_vision_4_2b",
    "recurrentgemma-2b":  "repro.configs.recurrentgemma_2b",
    "rwkv6-1.6b":         "repro.configs.rwkv6_1_6b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(get_config(name[: -len("-smoke")]))
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


__all__ = [
    "ARCH_NAMES", "SHAPES", "ShapeSpec", "ModelConfig", "MoESpec", "MLASpec",
    "EncoderSpec", "RecurrentSpec", "ParallelSpec", "get_config",
    "all_configs", "reduced", "shape_applicable",
]
