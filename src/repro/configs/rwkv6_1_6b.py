"""rwkv6-1.6b — "Finch": attention-free, data-dependent decay.
Sub-quadratic: runs long_500k.
[arXiv:2404.05892; unverified] 24L d_model=2048 d_ff=7168 vocab=65536
"""
from repro.configs.base import ModelConfig, ParallelSpec, RecurrentSpec

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,                # wkv heads = d_model / head_dim
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    head_dim=64,
    block_pattern=("rwkv",),
    norm="layernorm",
    recurrent=RecurrentSpec(head_dim=64),
    # NOTE §Perf: sequence_parallel cut collectives 2.9x here but tripled
    # peak HBM (gathered recurrent states); head-sharded wkv via shard_map
    # is the right fix (future work) — SP stays OFF for this arch.
    parallel=ParallelSpec(fsdp=False, opt_state_dtype="float32", remat=True),
)
