"""smollm-135m — llama-arch small dense LM.
[hf:HuggingFaceTB/SmolLM-135M; hf] 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
"""
from repro.configs.base import ModelConfig, ParallelSpec

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    head_dim=64,
    block_pattern=("attn",),
    tie_embeddings=True,
    rope_theta=10000.0,
    parallel=ParallelSpec(fsdp=False, opt_state_dtype="float32", remat=True,
                          sequence_parallel=True),
)
