"""mistral-large-123b — dense, GQA.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified] 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768
"""
from repro.configs.base import ModelConfig, ParallelSpec

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
    head_dim=128,
    block_pattern=("attn",),
    rope_theta=1_000_000.0,
    parallel=ParallelSpec(fsdp=True, opt_state_dtype="int8", remat=True, accum_steps=1,
                          sequence_parallel=True),
)
