"""deepseek-v3-671b — MoE flagship for the colibri dispatch technique.
MLA attention, 1 shared + 256 routed experts, top-8; first 3 layers dense.
MTP head omitted from step math (noted in DESIGN.md).
[arXiv:2412.19437; hf] 61L d_model=7168 128H d_ff(expert)=2048 vocab=129280
"""
from repro.configs.base import MLASpec, MoESpec, ModelConfig, ParallelSpec

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,                   # routed expert d_ff (per assigned table)
    vocab_size=129280,
    head_dim=128,
    block_pattern=("attn",),
    attn_kind="mla",
    mla=MLASpec(q_lora_rank=1536, kv_lora_rank=512,
                qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoESpec(num_experts=256, top_k=8, d_ff_expert=2048,
                num_shared_experts=1, capacity_factor=1.25,
                moe_layer_start=3, dense_d_ff=18432),
    rope_theta=10000.0,
    parallel=ParallelSpec(fsdp=True, opt_state_dtype="int8", remat=True,
                          accum_steps=8,
                          grad_accum_dtype="bfloat16"),
)
