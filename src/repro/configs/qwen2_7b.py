"""qwen2-7b — dense, GQA, QKV bias.
[arXiv:2407.10671; hf] 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064
"""
from repro.configs.base import ModelConfig, ParallelSpec

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    block_pattern=("attn",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    parallel=ParallelSpec(fsdp=False, opt_state_dtype="float32", remat=True,
                          sequence_parallel=True),
)
