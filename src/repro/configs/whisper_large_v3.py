"""whisper-large-v3 — encoder-decoder; conv frontend is a STUB
(input_specs() provides precomputed 1500-frame encoder embeddings).
[arXiv:2212.04356; unverified] 32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866
"""
from repro.configs.base import EncoderSpec, ModelConfig, ParallelSpec

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,               # decoder layers; encoder below
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    block_pattern=("attn",),
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    partial_rotary_factor=0.0,   # whisper uses learned/sinusoidal positions
    encoder=EncoderSpec(num_layers=32, seq_len=1500),
    frontend="audio",
    parallel=ParallelSpec(fsdp=False, opt_state_dtype="float32", remat=True),
)
