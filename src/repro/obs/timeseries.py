"""``Timeseries`` — the typed view of the engine's windowed telemetry.

The engine accumulates a ``(n_windows, TELE_K)`` int32 array (result key
``"tele"``) when ``telemetry_windows > 0`` — raw per-window sums (plus
one max column) with the column layout of
:data:`repro.obs.schema.TELE_CHANNELS`.  This module turns that array
into named, normalized series:

* :meth:`Timeseries.counts` — the raw per-window event counts
  (``grants``, ``fails``, ``msgs``, ...);
* :meth:`Timeseries.per_cycle` — the same divided by each window's
  cycle count, so core-count channels (``active``, ``sleeping``, ...)
  become *mean cores in that state* and event channels become rates;
* queue-depth accessors normalizing ``queue_sum`` into mean depth per
  bank (:attr:`queue_depth_mean`) alongside the windowed max
  (:attr:`queue_depth_max`).

Every accessor returns numpy arrays of length :attr:`n_used` (trailing
never-written windows are dropped), aligned with
:attr:`window_start_cycle`.  The schema is identical for all
protocols, so ``Timeseries`` from a Colibri run and an LRSC run plot
against each other directly — the queue drain vs retry storm the
paper's dynamic claims are about.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import numpy as np

from repro.obs import schema


@dataclasses.dataclass(frozen=True)
class Timeseries:
    """Windowed in-scan telemetry of one simulation point."""
    #: raw accumulator, ``(n_used, TELE_K)`` int64 (trailing all-zero
    #: windows already dropped)
    tele: np.ndarray
    #: simulated horizon the windows cover
    cycles: int
    #: telemetry_windows the run was configured with
    n_windows: int
    #: banks (addresses) of the run — normalizes ``queue_sum``
    n_addrs: int
    #: cores — normalizes nothing, but viewers want it for axes
    n_cores: int

    # ---- construction ---------------------------------------------------
    @classmethod
    def from_result(cls, result: Any) -> "Timeseries":
        """Build from a ``repro.sync.Result`` (or anything with a
        ``.stats`` mapping and ``.spec``)."""
        stats = result.stats
        if "tele" not in stats:
            raise ValueError(
                "result has no telemetry: run with telemetry_windows > 0 "
                "(e.g. Spec(..., telemetry_windows=64))")
        spec = result.spec
        return cls.from_stats(stats, cycles=spec.costs.cycles,
                              n_addrs=spec.topology.n_addrs,
                              n_cores=spec.topology.n_cores)

    @classmethod
    def from_stats(cls, stats: Dict[str, Any], *, cycles: int,
                   n_addrs: int, n_cores: int) -> "Timeseries":
        """Build from a raw engine result dict."""
        tele = np.asarray(stats["tele"], dtype=np.int64)
        if tele.ndim != 2 or tele.shape[1] != schema.TELE_K:
            raise ValueError(
                f"telemetry array must be (n_windows, {schema.TELE_K}), "
                f"got {tele.shape}")
        n_windows = tele.shape[0]
        used = schema.windows_used(cycles, n_windows)
        return cls(tele=tele[:used], cycles=int(cycles),
                   n_windows=int(n_windows), n_addrs=int(n_addrs),
                   n_cores=int(n_cores))

    # ---- geometry -------------------------------------------------------
    @property
    def n_used(self) -> int:
        """Windows that actually received samples."""
        return self.tele.shape[0]

    @property
    def window_start_cycle(self) -> np.ndarray:
        """(n_used,) first simulated cycle of each window — the x axis."""
        return schema.window_starts(self.cycles, self.n_windows)

    @property
    def window_n_cycles(self) -> np.ndarray:
        """(n_used,) cycles accumulated into each window (tail may be
        shorter)."""
        return schema.window_cycles(self.cycles, self.n_windows)

    def channels(self) -> tuple:
        """The available channel names (``schema.TELE_CHANNELS``)."""
        return schema.TELE_CHANNELS

    # ---- accessors ------------------------------------------------------
    def counts(self, channel: str) -> np.ndarray:
        """Raw per-window accumulated counts for ``channel``.  For
        ``queue_max`` this is the windowed maximum, not a sum."""
        if channel not in schema.TELE_COL:
            raise KeyError(f"unknown telemetry channel {channel!r}; "
                           f"channels: {', '.join(schema.TELE_CHANNELS)}")
        return self.tele[:, schema.TELE_COL[channel]]

    def per_cycle(self, channel: str) -> np.ndarray:
        """``counts(channel)`` divided by each window's cycle count:
        mean cores-in-state for the state channels, events per cycle
        for the outcome/traffic channels."""
        if channel == "queue_max":
            raise ValueError("queue_max is max-accumulated; use "
                             "queue_depth_max (no per-cycle form)")
        return self.counts(channel) / self.window_n_cycles

    # named conveniences (the channels figures actually plot)
    @property
    def active_cores(self) -> np.ndarray:
        """Mean non-sleeping, non-barrier atomic cores per window."""
        return self.per_cycle("active")

    @property
    def sleeping_cores(self) -> np.ndarray:
        """Mean cores asleep in a reservation queue per window — the
        paper's polling-free signature."""
        return self.per_cycle("sleeping")

    @property
    def backoff_cores(self) -> np.ndarray:
        """Mean cores in retry backoff per window — LRSC's retry storm;
        identically zero for the polling-free protocols."""
        return self.per_cycle("backoff")

    @property
    def local_msgs(self) -> np.ndarray:
        """Accepted intra-cluster (local-hop) messages per cycle per
        window.  Under the ``flat`` topology every message is local."""
        return self.per_cycle("loc_msgs")

    @property
    def cross_cluster_msgs(self) -> np.ndarray:
        """Accepted messages per cycle per window that crossed the first
        hierarchy level (``core.topologies``) — the NoC link-occupancy
        split the cluster topologies are about.  Identically zero under
        ``flat``."""
        return self.per_cycle("xcl_msgs")

    @property
    def queue_depth_mean(self) -> np.ndarray:
        """Mean reservation-queue depth per *bank* per window
        (``queue_sum`` / cycles / banks); 0 for queueless protocols."""
        return self.counts("queue_sum") / (
            self.window_n_cycles * max(self.n_addrs, 1))

    @property
    def queue_depth_max(self) -> np.ndarray:
        """Max depth of any single reservation queue in each window."""
        return self.counts("queue_max")

    # ---- serialization --------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready dict: geometry + one int list per channel."""
        out: Dict[str, Any] = {
            "cycles": self.cycles, "n_windows": self.n_windows,
            "n_used": self.n_used, "n_addrs": self.n_addrs,
            "n_cores": self.n_cores,
            "window_start_cycle": self.window_start_cycle.tolist(),
            "window_n_cycles": self.window_n_cycles.tolist(),
        }
        for name in schema.TELE_CHANNELS:
            out[name] = self.counts(name).tolist()
        return out
