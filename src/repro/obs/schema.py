"""Shared telemetry/trace schema for the observability subsystem.

One module owns the names and layouts every obs layer agrees on:

* the **windowed telemetry channel layout** — the engine
  (``core.sim``) accumulates a ``(n_windows, TELE_K)`` int32 array when
  ``telemetry_windows > 0``; :class:`repro.obs.Timeseries` reads it
  back by these column names.  The layout is protocol-agnostic: all 9
  registered protocols fill the same columns (queue columns stay 0 for
  queueless protocols), so timeseries from different protocols are
  directly comparable.
* the **core-state names** used by the event-trace layer
  (``Result.events()`` / ``obs.perfetto``) to label per-core spans —
  mirrors of the engine's state codes in ``core.protocols.base``.
* the **window geometry** helpers shared by the accumulator and the
  viewers (ceil-division window length, per-window cycle counts), so
  the view divides by exactly the cycle counts the engine accumulated
  over.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.protocols.base import (BACKOFF, BARWAIT, MOD, REQ, RESP,
                                       SLEEP, WORK)

#: telemetry channel names, in column order.  All but the last are
#: per-window **sums** (core-count channels sum one count per cycle, so
#: dividing by the window's cycle count gives a mean); the final
#: ``queue_max`` column is max-accumulated.
#:
#: ``active``/``sleeping``/``backoff``/``barwait`` — per-cycle core
#: counts by state (``active`` = non-sleeping, non-barrier, non-worker
#: cores, exactly the engine's ``active_cyc`` accounting).
#: ``grants``/``retires``/``fails``/``enqueues`` — bank-access outcome
#: counts, one per served winner, identical to the fused backend's
#: ``OUT_GRANT``/``OUT_DONE``/``OUT_FAIL``/``OUT_SLEEP`` codes.
#: ``wakes`` — cores moved out of SLEEP by a protocol wake-up this
#: window.  ``msgs``/``net_stall`` — NoC messages and rejected network
#: requests.  ``loc_msgs``/``xcl_msgs`` — NoC link-occupancy split by
#: locality: accepted requests whose (core, bank) path stays inside the
#: leaf cluster vs those crossing a cluster boundary (a topology-aware
#: split of the acceptance stream; under the ``flat`` topology every
#: accepted request is local and ``xcl_msgs`` is identically 0).
#: ``queue_sum`` — per-cycle sum of all reservation-queue depths;
#: ``queue_max`` — max depth seen in the window.
TELE_CHANNELS = ("active", "sleeping", "backoff", "barwait",
                 "grants", "retires", "fails", "enqueues", "wakes",
                 "msgs", "net_stall", "loc_msgs", "xcl_msgs",
                 "queue_sum", "queue_max")

#: number of telemetry columns; the engine's accumulator is
#: ``(n_windows, TELE_K)``
TELE_K = len(TELE_CHANNELS)

#: columns 0..TELE_NSUM-1 are add-accumulated; column TELE_NSUM
#: (``queue_max``) is max-accumulated
TELE_NSUM = TELE_K - 1

#: column index by channel name
TELE_COL: Dict[str, int] = {name: i for i, name in enumerate(TELE_CHANNELS)}

#: engine core-state code -> human/Perfetto label (codes from
#: ``core.protocols.base``)
STATE_NAMES: Dict[int, str] = {
    WORK: "WORK", REQ: "REQ", SLEEP: "SLEEP", MOD: "MOD",
    BACKOFF: "BACKOFF", RESP: "RESP", BARWAIT: "BARWAIT",
}

#: states that represent a core making progress (used by viewers to
#: style spans; SLEEP/BACKOFF/BARWAIT are the waiting states)
WAIT_STATES = frozenset((SLEEP, BACKOFF, BARWAIT))


def window_len(cycles: int, n_windows: int) -> int:
    """Cycles per telemetry window: ``ceil(cycles / n_windows)``.

    The engine maps cycle ``c`` to window ``c // window_len`` — an
    overflow-free static division (a ``c * n_windows // cycles`` rule
    would overflow int32 on long horizons).  The last *used* window may
    cover fewer cycles; trailing windows stay all-zero.
    """
    if n_windows < 1:
        raise ValueError(f"n_windows must be >= 1 (got {n_windows})")
    return -(-cycles // n_windows)


def windows_used(cycles: int, n_windows: int) -> int:
    """How many leading windows actually receive samples."""
    return -(-cycles // window_len(cycles, n_windows))


def window_starts(cycles: int, n_windows: int) -> np.ndarray:
    """(windows_used,) first cycle of each used window."""
    cw = window_len(cycles, n_windows)
    return np.arange(windows_used(cycles, n_windows), dtype=np.int64) * cw


def window_cycles(cycles: int, n_windows: int) -> np.ndarray:
    """(windows_used,) number of cycles accumulated into each used
    window (the divisor for per-cycle means; the tail window is
    usually shorter)."""
    cw = window_len(cycles, n_windows)
    starts = window_starts(cycles, n_windows)
    return np.minimum(starts + cw, cycles) - starts
