"""``EventLog`` — the typed event-trace view of a ``record_trace`` run.

``record_trace=True`` makes the engine emit four per-cycle traces:

``trace_step``/``trace_wait``
    ``(cycles, n)`` int32 — which micro-op retired on each core each
    cycle (-1 = none) and its first-issue-to-retire latency (the
    pre-existing linearizability-check arrays).
``trace_state``
    ``(cycles, n)`` int8 — each core's engine state at the END of each
    cycle (``schema.STATE_NAMES`` codes).
``trace_qlen``
    ``(cycles, a)`` int32 — each bank's reservation-queue depth at the
    end of each cycle (all-zero for queueless protocols).

This module run-length-encodes the state trace into **spans** — the
(core, state, start, length) intervals Perfetto renders as tracks — and
exposes the retirements as a flat **completions** table.  The span view
is what makes the paper's behaviour *visible*: an LRSC run shows
BACKOFF spans (retry storms) where a Colibri run of the same workload
shows single SLEEP spans per contended op and none in BACKOFF.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import schema


@dataclasses.dataclass(frozen=True)
class Span:
    """One maximal run of a core staying in one state."""
    core: int
    state: int           # engine state code (schema.STATE_NAMES)
    start: int           # first cycle of the run
    length: int          # cycles spent in the state

    @property
    def name(self) -> str:
        return schema.STATE_NAMES.get(self.state, f"state{self.state}")


@dataclasses.dataclass(frozen=True)
class EventLog:
    """Typed event traces of one ``record_trace=True`` simulation."""
    step: np.ndarray                 # (cycles, n) int32, -1 = no retire
    wait: np.ndarray                 # (cycles, n) int32, -1 = no retire
    state: Optional[np.ndarray]      # (cycles, n) int8, or None (old runs)
    qlen: Optional[np.ndarray]       # (cycles, a) int32, or None

    # ---- construction ---------------------------------------------------
    @classmethod
    def from_result(cls, result: Any) -> "EventLog":
        """Build from a ``repro.sync.Result`` (or a raw stats mapping)."""
        stats = getattr(result, "stats", result)
        if "trace_step" not in stats:
            raise ValueError(
                "result has no event trace: run with record_trace=True "
                "(e.g. Spec(..., record_trace=True))")
        get = (lambda k: np.asarray(stats[k]) if k in stats else None)
        return cls(step=np.asarray(stats["trace_step"]),
                   wait=np.asarray(stats["trace_wait"]),
                   state=get("trace_state"), qlen=get("trace_qlen"))

    @property
    def cycles(self) -> int:
        return self.step.shape[0]

    @property
    def n_cores(self) -> int:
        return self.step.shape[1]

    @property
    def n_addrs(self) -> int:
        return 0 if self.qlen is None else self.qlen.shape[1]

    # ---- completions ----------------------------------------------------
    def completions(self) -> Dict[str, np.ndarray]:
        """All retirements as a flat table: ``cycle``/``core`` of each
        retirement plus the retired micro-op index (``step``) and its
        issue-to-retire latency (``wait``), cycle-major order."""
        cyc, core = np.nonzero(self.step >= 0)
        return {"cycle": cyc.astype(np.int64),
                "core": core.astype(np.int64),
                "step": self.step[cyc, core].astype(np.int64),
                "wait": self.wait[cyc, core].astype(np.int64)}

    # ---- state spans -----------------------------------------------------
    def spans(self, core: Optional[int] = None,
              states: Optional[Tuple[int, ...]] = None) -> List[Span]:
        """Run-length-encoded state intervals, optionally restricted to
        one ``core`` and/or a tuple of state codes.  Requires the state
        trace (``trace_state``)."""
        if self.state is None:
            raise ValueError("no state trace recorded (trace_state "
                             "missing; re-run with record_trace=True on "
                             "a telemetry-era engine)")
        cores = range(self.n_cores) if core is None else (core,)
        out: List[Span] = []
        for c in cores:
            col = self.state[:, c]
            # boundaries of maximal constant runs
            brk = np.flatnonzero(col[1:] != col[:-1]) + 1
            starts = np.concatenate(([0], brk))
            ends = np.concatenate((brk, [col.shape[0]]))
            for s, e in zip(starts, ends):
                st = int(col[s])
                if states is None or st in states:
                    out.append(Span(core=int(c), state=st, start=int(s),
                                    length=int(e - s)))
        return out

    def span_counts(self, state: int) -> np.ndarray:
        """(n,) number of maximal spans each core spent in ``state`` —
        e.g. ``span_counts(BACKOFF)`` counts retry episodes per core
        (identically zero for the polling-free protocols)."""
        if self.state is None:
            raise ValueError("no state trace recorded")
        is_st = (self.state == state)
        entered = is_st & np.concatenate(
            (np.ones((1, self.n_cores), bool), ~is_st[:-1]), axis=0)
        return entered.sum(axis=0).astype(np.int64)

    def time_in_state(self, state: int) -> np.ndarray:
        """(n,) total cycles each core spent in ``state``."""
        if self.state is None:
            raise ValueError("no state trace recorded")
        return (self.state == state).sum(axis=0).astype(np.int64)
