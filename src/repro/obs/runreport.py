"""``RunReport`` — sweep-runner instrumentation (obs layer 3).

The fingerprint-grouped vmapped sweep (``repro.core.sweep``) dispatches
work in chunks; where its wall time goes — tracing+compiling a new
executable vs executing a cached one, and how the persistent
compilation cache behaves across runs — was previously invisible.  A
:class:`RunReport` records one :class:`ChunkRecord` per dispatched
chunk plus environment facts (backend, device kind, device count,
batch ceiling) and summarizes them for ``benchmarks/run.py`` output and
report JSON.

Timing model (CPU/asynchronous-dispatch reality): the jitted sweep call
traces and compiles **synchronously** on an in-process cache miss, so a
chunk's dispatch wall time is compile time when ``compiled`` is True
and sub-millisecond otherwise; execution drains at the chunk's
``jax.device_get``, so materialize wall time is execute time.  The
records name them ``compile_s`` / ``execute_s`` accordingly.

Usage — ambient (how ``benchmarks/run.py`` instruments every study a
benchmark runs, without threading a parameter through 11 modules)::

    from repro import obs
    with obs.collect() as report:
        study.run()
    print(report.summary())

or explicit: ``Study.run(report=report)`` / ``Study.stream(report=...)``
/ ``sweep_iter(..., report=report)``.

Persistent-cache hits are counted through ``jax.monitoring`` events
when that API exists (jax >= 0.4.x); otherwise the counter just stays
at 0 — the field is best-effort by design.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional

#: the active ambient report (see :func:`collect` / :func:`current`)
_current: Optional["RunReport"] = None

_listener_installed = False


def _install_cache_listener() -> None:
    """Count persistent-compilation-cache hits into the active report.

    ``jax.monitoring`` fires a cache-hit event when an executable is
    deserialized from the on-disk cache instead of compiled.  One
    process-wide listener routes the events to whichever report is
    currently collecting; on jax versions without the API this is a
    silent no-op.
    """
    global _listener_installed
    if _listener_installed:
        return
    _listener_installed = True
    try:
        from jax import monitoring

        def _on_event(event: str, **kw: Any) -> None:
            rep = _current
            if rep is not None and "cache_hit" in event:
                rep.persistent_cache_hits += 1

        monitoring.register_event_listener(_on_event)
    except Exception:               # pragma: no cover - best-effort
        pass


@dataclasses.dataclass
class ChunkRecord:
    """One dispatched sweep chunk."""
    label: str            # fingerprint summary (protocol/workload/shape)
    points: int           # real configuration points in the chunk
    batch: int            # padded batch actually dispatched
    compile_s: float      # dispatch wall: trace+compile on a miss, ~0 on hit
    execute_s: float      # materialize wall: device_get drain
    compiled: bool        # this dispatch built a new in-process executable

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RunReport:
    """Instrumentation record of one (or more) sweep executions."""
    backend: str = ""               # resolved engine backend of the run
    device: str = ""                # jax device kind (e.g. "cpu", "TPU v4")
    n_devices: int = 0
    max_batch: Optional[int] = None
    chunks: List[ChunkRecord] = dataclasses.field(default_factory=list)
    persistent_cache_hits: int = 0
    started_at: float = dataclasses.field(default_factory=time.time)

    # ---- recording (called by repro.core.sweep) -------------------------
    def note_env(self, backend: str, max_batch: int) -> None:
        """Fill environment facts once per sweep invocation."""
        self.backend = backend
        self.max_batch = max_batch
        try:
            import jax
            devs = jax.devices()
            self.device = devs[0].device_kind if devs else ""
            self.n_devices = len(devs)
        except Exception:           # pragma: no cover - env probing only
            pass

    def record_chunk(self, label: str, points: int, batch: int,
                     compile_s: float, execute_s: float,
                     compiled: bool) -> None:
        self.chunks.append(ChunkRecord(label=label, points=points,
                                       batch=batch, compile_s=compile_s,
                                       execute_s=execute_s,
                                       compiled=compiled))

    # ---- aggregates -----------------------------------------------------
    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    @property
    def n_points(self) -> int:
        return sum(c.points for c in self.chunks)

    @property
    def n_compiles(self) -> int:
        return sum(c.compiled for c in self.chunks)

    @property
    def compile_s(self) -> float:
        return sum(c.compile_s for c in self.chunks)

    @property
    def execute_s(self) -> float:
        return sum(c.execute_s for c in self.chunks)

    # ---- presentation ---------------------------------------------------
    def summary(self) -> str:
        """One human line: where the sweep wall time went."""
        return (f"{self.n_points} pts / {self.n_chunks} chunks on "
                f"{self.backend or '?'} ({self.n_devices}x"
                f"{self.device or '?'}): compile {self.compile_s:.2f}s "
                f"({self.n_compiles} new), execute {self.execute_s:.2f}s, "
                f"persistent-cache hits {self.persistent_cache_hits}")

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready dict (what ``benchmarks/run.py`` embeds per
        benchmark under ``run_report``)."""
        return {"backend": self.backend, "device": self.device,
                "n_devices": self.n_devices, "max_batch": self.max_batch,
                "n_points": self.n_points, "n_chunks": self.n_chunks,
                "n_compiles": self.n_compiles,
                "compile_s": self.compile_s, "execute_s": self.execute_s,
                "persistent_cache_hits": self.persistent_cache_hits,
                "chunks": [c.to_dict() for c in self.chunks]}


def current() -> Optional[RunReport]:
    """The ambient report sweeps record into, or None."""
    return _current


@contextlib.contextmanager
def collect(report: Optional[RunReport] = None):
    """Collect sweep instrumentation for everything run in this block.

    Yields the active :class:`RunReport`; nests (the previous ambient
    report is restored on exit).
    """
    global _current
    _install_cache_listener()
    rep = report if report is not None else RunReport()
    prev = _current
    _current = rep
    try:
        yield rep
    finally:
        _current = prev
