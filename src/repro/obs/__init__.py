"""``repro.obs`` — the observability subsystem.

Three layers over the cycle-level engine, each answering a question the
end-of-run aggregates cannot:

* **windowed telemetry** (:class:`Timeseries`, ``repro.obs.schema``) —
  what was the machine doing *over time*?  The ``telemetry_windows``
  Spec knob makes the engine accumulate a ``(n_windows, k)`` in-scan
  timeseries (sleeping/active/backoff core counts, queue depths,
  grant/fail/sleep/wake outcomes, NoC traffic) on both the XLA scan and
  the fused Pallas backends; ``Result.timeseries()`` returns the typed
  view.
* **event traces** (:class:`EventLog`, :mod:`repro.obs.perfetto`) —
  what did core 17 do at cycle 1402?  ``record_trace=True`` runs carry
  per-cycle state and queue-depth traces; ``Result.events()`` gives the
  span/completion view and :func:`perfetto.export` writes a Chrome
  trace JSON loadable at https://ui.perfetto.dev.
* **runner instrumentation** (:class:`RunReport`, :func:`collect`) —
  where did the sweep's wall time go?  Per-chunk compile vs execute
  timing, backend/device facts, persistent-cache hits; ambient
  collection via ``with obs.collect() as report:``.

Submodules import lazily (PEP 562), so the engine's dependency on
``repro.obs.schema`` stays one light leaf module.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

__all__ = ["schema", "Timeseries", "EventLog", "Span", "RunReport",
           "ChunkRecord", "collect", "current", "perfetto"]

if TYPE_CHECKING:                     # pragma: no cover - typing only
    from repro.obs import perfetto, schema
    from repro.obs.events import EventLog, Span
    from repro.obs.runreport import ChunkRecord, RunReport, collect, current
    from repro.obs.timeseries import Timeseries

#: attribute -> (submodule, member or None for the module itself)
_LAZY = {
    "schema": ("repro.obs.schema", None),
    "perfetto": ("repro.obs.perfetto", None),
    "Timeseries": ("repro.obs.timeseries", "Timeseries"),
    "EventLog": ("repro.obs.events", "EventLog"),
    "Span": ("repro.obs.events", "Span"),
    "RunReport": ("repro.obs.runreport", "RunReport"),
    "ChunkRecord": ("repro.obs.runreport", "ChunkRecord"),
    "collect": ("repro.obs.runreport", "collect"),
    "current": ("repro.obs.runreport", "current"),
}


def __getattr__(name: str):
    try:
        modname, member = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro.obs' has no attribute "
                             f"{name!r}") from None
    import importlib
    mod = importlib.import_module(modname)
    value = mod if member is None else getattr(mod, member)
    globals()[name] = value           # cache for subsequent lookups
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
