"""Chrome-trace export: watch a simulation in Perfetto.

:func:`export` turns one ``record_trace=True`` result into a Chrome
Trace Event JSON file loadable at https://ui.perfetto.dev (or
``chrome://tracing``): one track per core showing its engine-state
spans (SLEEP / BACKOFF / BARWAIT / REQ / ...), an instant marker per
atomic retirement, and one counter track per bank plotting its
reservation-queue depth.  One simulated cycle maps to one trace
microsecond, so the Perfetto timeline axis reads directly in cycles.

This is the first way to *watch* the paper's claims: load a Colibri and
an LRSC run of the same contended workload side by side and the LRSC
tracks fill with BACKOFF retry spans while the Colibri tracks show one
SLEEP span per contended op and zero retries
(``examples/trace_perfetto.py`` generates exactly that pair).

Span volume is bounded by construction — spans are maximal state runs,
so a track never holds more events than state *changes* — and WORK
spans (the between-atomics baseline) are skipped by default to keep
traces lean; pass ``include_work=True`` to render them too.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs import schema
from repro.obs.events import EventLog

#: Perfetto process ids: cores and banks render as two process groups
_PID_CORES = 1
_PID_BANKS = 2

#: engine state code -> stable Perfetto slice color (color_name is a
#: documented Chrome-trace extension; viewers without it just ignore it)
_COLORS = {"SLEEP": "thread_state_sleeping",
           "BACKOFF": "terrible",
           "BARWAIT": "thread_state_iowait",
           "REQ": "thread_state_runnable",
           "RESP": "thread_state_running",
           "MOD": "thread_state_running",
           "WORK": "grey"}


def to_trace_events(result: Any, include_work: bool = False,
                    max_cores: Optional[int] = None) -> List[Dict]:
    """The Chrome ``traceEvents`` list for ``result`` (see
    :func:`export`).  ``max_cores`` caps how many core tracks are
    emitted (all by default) — banks are always all emitted."""
    log = EventLog.from_result(result)
    if log.state is None:
        raise ValueError(
            "result predates the state trace; re-run with "
            "record_trace=True to export a Perfetto trace")
    ev: List[Dict] = []
    ncores = log.n_cores if max_cores is None else min(max_cores,
                                                       log.n_cores)
    # ---- metadata: name the process/thread tracks -----------------------
    ev.append({"ph": "M", "pid": _PID_CORES, "name": "process_name",
               "args": {"name": "cores"}})
    ev.append({"ph": "M", "pid": _PID_BANKS, "name": "process_name",
               "args": {"name": "banks"}})
    for c in range(ncores):
        ev.append({"ph": "M", "pid": _PID_CORES, "tid": c,
                   "name": "thread_name", "args": {"name": f"core {c}"}})
    # ---- per-core state spans (ph "X": complete events) -----------------
    for span in log.spans():
        if span.core >= ncores:
            continue
        name = span.name
        if name == "WORK" and not include_work:
            continue
        e = {"ph": "X", "pid": _PID_CORES, "tid": span.core,
             "name": name, "cat": "state",
             "ts": span.start, "dur": span.length}
        color = _COLORS.get(name)
        if color:
            e["cname"] = color
        ev.append(e)
    # ---- retirement instants (ph "i") -----------------------------------
    comp = log.completions()
    for cyc, core, step, wait in zip(comp["cycle"], comp["core"],
                                     comp["step"], comp["wait"]):
        if core >= ncores:
            continue
        ev.append({"ph": "i", "pid": _PID_CORES, "tid": int(core),
                   "name": "retire", "cat": "atomic", "s": "t",
                   "ts": int(cyc),
                   "args": {"step": int(step), "wait_cycles": int(wait)}})
    # ---- per-bank queue-depth counters (ph "C", emit-on-change) ---------
    if log.qlen is not None:
        q = log.qlen
        for b in range(q.shape[1]):
            col = q[:, b]
            # emit only cycles where the depth changes (plus cycle 0),
            # so an idle bank costs one event, not ``cycles``
            chg = np.concatenate(([0], np.flatnonzero(col[1:] != col[:-1])
                                  + 1))
            for cyc in chg:
                ev.append({"ph": "C", "pid": _PID_BANKS, "tid": int(b),
                           "name": f"bank {b} qlen", "ts": int(cyc),
                           "args": {"depth": int(col[cyc])}})
    return ev


def export(result: Any, path: str, include_work: bool = False,
           max_cores: Optional[int] = None) -> str:
    """Write ``result``'s event trace as Chrome-trace JSON to ``path``
    and return ``path``.  Load the file at https://ui.perfetto.dev.

    ``result`` must come from a ``record_trace=True`` run.  ``ts`` is in
    trace microseconds = simulated cycles.  ``include_work`` also
    renders the WORK (local compute) spans; ``max_cores`` limits the
    emitted core tracks for very wide machines.
    """
    doc = {"traceEvents": to_trace_events(result, include_work=include_work,
                                          max_cores=max_cores),
           "displayTimeUnit": "ms",
           "otherData": {"unit": "1 us = 1 simulated cycle"}}
    with open(path, "w") as f:
        json.dump(doc, f)
    return path
