"""Chrome-trace export: watch a simulation in Perfetto.

:func:`export` turns one ``record_trace=True`` result into a Chrome
Trace Event JSON file loadable at https://ui.perfetto.dev (or
``chrome://tracing``): one track per core showing its engine-state
spans (SLEEP / BACKOFF / BARWAIT / REQ / ...), an instant marker per
atomic retirement, and one counter track per bank plotting its
reservation-queue depth.  One simulated cycle maps to one trace
microsecond, so the Perfetto timeline axis reads directly in cycles.

This is the first way to *watch* the paper's claims: load a Colibri and
an LRSC run of the same contended workload side by side and the LRSC
tracks fill with BACKOFF retry spans while the Colibri tracks show one
SLEEP span per contended op and zero retries
(``examples/trace_perfetto.py`` generates exactly that pair).

Span volume is bounded by construction — spans are maximal state runs,
so a track never holds more events than state *changes* — and WORK
spans (the between-atomics baseline) are skipped by default to keep
traces lean; pass ``include_work=True`` to render them too.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs import schema
from repro.obs.events import EventLog

#: Perfetto process ids: cores, banks and the NoC render as three
#: process groups
_PID_CORES = 1
_PID_BANKS = 2
_PID_NOC = 3

#: engine state code -> stable Perfetto slice color (color_name is a
#: documented Chrome-trace extension; viewers without it just ignore it)
_COLORS = {"SLEEP": "thread_state_sleeping",
           "BACKOFF": "terrible",
           "BARWAIT": "thread_state_iowait",
           "REQ": "thread_state_runnable",
           "RESP": "thread_state_running",
           "MOD": "thread_state_running",
           "WORK": "grey"}


def to_trace_events(result: Any, include_work: bool = False,
                    max_cores: Optional[int] = None) -> List[Dict]:
    """The Chrome ``traceEvents`` list for ``result`` (see
    :func:`export`).  ``max_cores`` caps how many core tracks are
    emitted (all by default) — banks are always all emitted."""
    log = EventLog.from_result(result)
    if log.state is None:
        raise ValueError(
            "result predates the state trace; re-run with "
            "record_trace=True to export a Perfetto trace")
    ev: List[Dict] = []
    ncores = log.n_cores if max_cores is None else min(max_cores,
                                                       log.n_cores)
    # ---- metadata: name the process/thread tracks -----------------------
    ev.append({"ph": "M", "pid": _PID_CORES, "name": "process_name",
               "args": {"name": "cores"}})
    ev.append({"ph": "M", "pid": _PID_BANKS, "name": "process_name",
               "args": {"name": "banks"}})
    for c in range(ncores):
        ev.append({"ph": "M", "pid": _PID_CORES, "tid": c,
                   "name": "thread_name", "args": {"name": f"core {c}"}})
    # ---- per-core state spans (ph "X": complete events) -----------------
    for span in log.spans():
        if span.core >= ncores:
            continue
        name = span.name
        if name == "WORK" and not include_work:
            continue
        e = {"ph": "X", "pid": _PID_CORES, "tid": span.core,
             "name": name, "cat": "state",
             "ts": span.start, "dur": span.length}
        color = _COLORS.get(name)
        if color:
            e["cname"] = color
        ev.append(e)
    # ---- retirement instants (ph "i") -----------------------------------
    comp = log.completions()
    for cyc, core, step, wait in zip(comp["cycle"], comp["core"],
                                     comp["step"], comp["wait"]):
        if core >= ncores:
            continue
        ev.append({"ph": "i", "pid": _PID_CORES, "tid": int(core),
                   "name": "retire", "cat": "atomic", "s": "t",
                   "ts": int(cyc),
                   "args": {"step": int(step), "wait_cycles": int(wait)}})
    # ---- fault-injection overlays (repro.faults) ------------------------
    # host-synthesized from the plan's deterministic schedule plus the
    # engine's dead_mask/halt_cyc outputs: DEAD spans on killed cores,
    # STALL spans over the scheduled stall windows, BANK_STALL spans on
    # stalled bank tracks, and one global instant when the forward-
    # progress watchdog flagged a halt
    spec = getattr(result, "spec", None)
    fp = getattr(spec, "faults", None) if spec is not None else None
    if fp is not None and fp.enabled:
        horizon = int(spec.costs.cycles)
        get = result.get if hasattr(result, "get") else (lambda k, d=None: d)
        dead = np.asarray(get("dead_mask", np.zeros(0, bool)))
        kill_ts = int(fp.kill_cyc if fp.n_kill else fp.stall_cyc)
        for c in np.flatnonzero(dead):
            if c >= ncores:
                continue
            # holder kills fire at the victim's first post-kill_cyc
            # ownership handoff; kill_cyc is the earliest possible start
            ev.append({"ph": "X", "pid": _PID_CORES, "tid": int(c),
                       "name": "DEAD", "cat": "fault", "cname": "black",
                       "ts": kill_ts, "dur": max(horizon - kill_ts, 1)})
        if fp.n_stall:
            dur = min(fp.stall_cyc + fp.stall_dur, horizon) - fp.stall_cyc
            for c in np.flatnonzero(fp.stall_mask(log.n_cores)):
                if c >= ncores or dur <= 0:
                    continue
                ev.append({"ph": "X", "pid": _PID_CORES, "tid": int(c),
                           "name": "STALL", "cat": "fault",
                           "cname": "terrible",
                           "ts": int(fp.stall_cyc), "dur": int(dur)})
        if fp.n_bank_stall and log.qlen is not None:
            dur = (min(fp.bank_stall_cyc + fp.bank_stall_dur, horizon)
                   - fp.bank_stall_cyc)
            for b in np.flatnonzero(fp.bank_stall_mask(log.qlen.shape[1])):
                if dur <= 0:
                    continue
                ev.append({"ph": "X", "pid": _PID_BANKS, "tid": int(b),
                           "name": "BANK_STALL", "cat": "fault",
                           "cname": "terrible",
                           "ts": int(fp.bank_stall_cyc), "dur": int(dur)})
        halt = int(np.asarray(get("halt_cyc", -1)))
        if halt >= 0:
            ev.append({"ph": "i", "pid": _PID_CORES, "name": "HALT",
                       "cat": "fault", "s": "g", "ts": halt,
                       "args": {"detail": "forward-progress watchdog: "
                                          "no retirement for the "
                                          "progress threshold"}})
    # ---- per-bank queue-depth counters (ph "C", emit-on-change) ---------
    if log.qlen is not None:
        q = log.qlen
        for b in range(q.shape[1]):
            col = q[:, b]
            # emit only cycles where the depth changes (plus cycle 0),
            # so an idle bank costs one event, not ``cycles``
            chg = np.concatenate(([0], np.flatnonzero(col[1:] != col[:-1])
                                  + 1))
            for cyc in chg:
                ev.append({"ph": "C", "pid": _PID_BANKS, "tid": int(b),
                           "name": f"bank {b} qlen", "ts": int(cyc),
                           "args": {"depth": int(col[cyc])}})
    # ---- NoC link-occupancy counters (windowed telemetry) ---------------
    # accepted messages split into intra-cluster (local) vs cross-cluster
    # traffic, one counter sample per telemetry window; only present when
    # the run had telemetry_windows > 0, and the cross series is
    # identically zero under the flat topology
    stats = getattr(result, "stats", None)
    if stats is not None and "tele" in stats:
        from repro.obs.timeseries import Timeseries
        t = Timeseries.from_result(result)
        loc = t.counts("loc_msgs")
        xcl = t.counts("xcl_msgs")
        starts = t.window_start_cycle
        ev.append({"ph": "M", "pid": _PID_NOC, "name": "process_name",
                   "args": {"name": "noc"}})
        for i in range(t.n_used):
            ev.append({"ph": "C", "pid": _PID_NOC, "tid": 0,
                       "name": "link msgs", "ts": int(starts[i]),
                       "args": {"local": int(loc[i]),
                                "cross_cluster": int(xcl[i])}})
    return ev


def export(result: Any, path: str, include_work: bool = False,
           max_cores: Optional[int] = None) -> str:
    """Write ``result``'s event trace as Chrome-trace JSON to ``path``
    and return ``path``.  Load the file at https://ui.perfetto.dev.

    ``result`` must come from a ``record_trace=True`` run.  ``ts`` is in
    trace microseconds = simulated cycles.  ``include_work`` also
    renders the WORK (local compute) spans; ``max_cores`` limits the
    emitted core tracks for very wide machines.
    """
    doc = {"traceEvents": to_trace_events(result, include_work=include_work,
                                          max_cores=max_cores),
           "displayTimeUnit": "ms",
           "otherData": {"unit": "1 us = 1 simulated cycle"}}
    with open(path, "w") as f:
        json.dump(doc, f)
    return path
