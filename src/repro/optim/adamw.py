"""AdamW with dtype-configurable moment states (fp32 / bf16 / int8).

The int8 path is a distributed-optimization feature for the ≥100B archs:
moments are stored blockwise-quantized (per-row absmax scales), cutting
optimizer HBM by 4-8x — the difference between kimi-k2 fitting on a
16 GB/chip pod or not (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"          # float32 | bfloat16 | int8
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


# ---------------------------------------------------------------------------
# Quantized moment storage
# ---------------------------------------------------------------------------

def _quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 with per-row (last-axis) absmax scale."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _store(x: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _quant(x)
    return x.astype(jnp.dtype(dtype))


def _load(s, dtype: str) -> jnp.ndarray:
    if dtype == "int8":
        return _dequant(*s)
    return s.astype(jnp.float32)


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Params
    v: Params


def init(cfg: AdamWConfig, params: Params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: _store(jnp.zeros(p.shape, jnp.float32), cfg.state_dtype),
        params)
    z2 = jax.tree.map(
        lambda p: _store(jnp.zeros(p.shape, jnp.float32), cfg.state_dtype),
        params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros, z2)


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads: Params, state: AdamWState,
           params: Params) -> Tuple[Params, AdamWState, Dict[str, jnp.ndarray]]:
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    is_q = cfg.state_dtype == "int8"

    def leaf(g, m_s, v_s, p):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _load(m_s, cfg.state_dtype) + (1 - cfg.b1) * g
        v = cfg.b2 * _load(v_s, cfg.state_dtype) + (1 - cfg.b2) * g * g
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:      # decay matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, _store(m, cfg.state_dtype), _store(v, cfg.state_dtype)

    is_leaf_state = (lambda x: isinstance(x, tuple)) if is_q else None
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [leaf(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_params, AdamWState(step, new_m, new_v), \
        {"grad_norm": gnorm, "lr": lr}
