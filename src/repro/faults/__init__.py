"""Fault injection & recovery (``repro.faults``).

:class:`FaultPlan` is the declarative fault schedule
``Spec(faults=...)`` accepts: deterministic seed-derived core
kills/stalls, Bernoulli NoC message drops (including lost wakeups) and
bank-stall windows, paired with the recovery knobs (the per-bank
reservation ``watchdog_cyc`` driving each protocol's ``on_timeout``
eviction hook, and the ``progress_cyc`` livelock/deadlock flag).

The engine statically elides everything for the default no-fault plan
(``tests/test_faults.py`` pins bit-identity AND an unchanged scan carry
count), so fault support is free when off.
"""
from repro.faults.plan import DROP_DENOM, FaultPlan

__all__ = ["DROP_DENOM", "FaultPlan"]
