"""``FaultPlan`` — the declarative, deterministic fault schedule.

The paper's central trade is replacing polling with sleeping: every
LRwait/SCwait sleeper's forward progress *depends* on the reservation
owner, so a stalled core or a dropped wakeup silently deadlocks the
whole queue — a failure mode retry-based LRSC does not have.  A
``FaultPlan`` makes that property testable: it describes WHAT goes
wrong (cores die or stall, NoC messages drop, banks stall) and WHAT
defends against it (the per-bank reservation watchdog, the
forward-progress detector), as a frozen, hashable, JSON-round-trippable
value that ``Spec(faults=...)`` lowers into the engine.

Everything is **static and seed-derived**: victim sets are drawn
host-side from ``fault_seed`` (``numpy`` RNG, no scan carries), the
Bernoulli message-drop stream is a counter hash of (lane, cycle,
``fault_seed``), and the plan participates in the sweep runner's static
fingerprint — so the same plan always injects the same faults, across
backends, under ``vmap``, and between runs.

Injection knobs
---------------
* ``n_kill`` / ``kill_cyc`` / ``kill_holder`` — ``n_kill`` cores freeze
  permanently at/after ``kill_cyc``.  With ``kill_holder=1`` (the
  adversarial default) the victims are the first ``n_kill`` cores to be
  GRANTED a reservation/lock at or after ``kill_cyc`` — each dies while
  holding, the exact scenario that wedges sleep-based protocols.  With
  ``kill_holder=0`` victims are a uniform seed-derived core subset.
* ``n_stall`` / ``stall_cyc`` / ``stall_dur`` — ``n_stall`` uniform
  victims freeze for the window ``[stall_cyc, stall_cyc + stall_dur)``
  and then resume (transient GC-pause-style stalls).
* ``msg_drop_bp`` — Bernoulli drop, in basis points (per 10 000), on
  NoC request messages and on in-flight wakeup messages (the "lost
  wakeup").  Dropped requests retransmit (the core stays in REQ);
  dropped wakeups are only recovered by the watchdog.
* ``n_bank_stall`` / ``bank_stall_cyc`` / ``bank_stall_dur`` — that
  many banks accept no requests during the window (arbitration skips
  them; parked requests wait).

Recovery knobs
--------------
* ``watchdog_cyc`` — per-bank reservation timeout: a bank held with no
  service progress for this many cycles triggers the protocol's
  ``on_timeout`` hook (evict a dead owner, re-send a lost wakeup,
  force-free a wedged lock).  0 disables recovery — faults then
  deadlock exactly as the unprotected protocol would.
* ``progress_cyc`` — forward-progress watchdog: if NO core retires an
  op for this many cycles the run is flagged (``halt_cyc`` in stats →
  ``progress_ok=False``) instead of silently burning the horizon.
  0 picks ``max(2000, 4 * watchdog_cyc)`` automatically whenever any
  fault machinery is on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: basis-point denominator for the Bernoulli message-drop draw
DROP_DENOM = 10_000

#: RNG stream salts for the three host-drawn victim sets
_SALT_KILL, _SALT_STALL, _SALT_BANK = 1, 2, 3


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One deterministic fault schedule + recovery policy (see module
    docstring).  ``FaultPlan()`` is the no-fault plan: the engine
    statically elides every fault branch for it."""
    n_kill: int = 0           # cores killed (permanent freeze)
    kill_cyc: int = 0         # first cycle a kill may take effect
    kill_holder: int = 1      # 1: kill grant holders; 0: uniform victims
    n_stall: int = 0          # cores transiently frozen
    stall_cyc: int = 0        # stall window start
    stall_dur: int = 0        # stall window length (cycles)
    msg_drop_bp: int = 0      # request/wakeup drop rate, per 10 000
    n_bank_stall: int = 0     # banks refusing service
    bank_stall_cyc: int = 0   # bank-stall window start
    bank_stall_dur: int = 0   # bank-stall window length (cycles)
    fault_seed: int = 0       # seed of every victim draw / drop stream
    watchdog_cyc: int = 0     # reservation timeout (0 = no recovery)
    progress_cyc: int = 0     # livelock/deadlock flag (0 = auto)

    def __post_init__(self):
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if (not isinstance(v, (int, np.integer)) or isinstance(v, bool)
                    or v < 0):
                raise ValueError(
                    f"FaultPlan.{f.name} must be an int >= 0 (got {v!r})")
        if self.kill_holder not in (0, 1):
            raise ValueError(
                f"kill_holder must be 0 or 1 (got {self.kill_holder!r})")
        if self.msg_drop_bp > DROP_DENOM:
            raise ValueError(
                f"msg_drop_bp is basis points, must be <= {DROP_DENOM} "
                f"(got {self.msg_drop_bp})")
        if self.n_stall > 0 and self.stall_dur < 1:
            raise ValueError("n_stall > 0 needs stall_dur >= 1")
        if self.n_bank_stall > 0 and self.bank_stall_dur < 1:
            raise ValueError("n_bank_stall > 0 needs bank_stall_dur >= 1")

    # ---- static gates ---------------------------------------------------
    @property
    def injects(self) -> bool:
        """Does this plan inject any fault at all?"""
        return (self.n_kill > 0 or self.n_stall > 0
                or self.msg_drop_bp > 0 or self.n_bank_stall > 0)

    @property
    def enabled(self) -> bool:
        """Does the engine need ANY fault machinery (injection, recovery
        or detection) for this plan?  False ⇒ the whole subsystem is
        statically elided and the trace is bit-identical to pre-fault."""
        return (self.injects or self.watchdog_cyc > 0
                or self.progress_cyc > 0)

    def progress_threshold(self) -> int:
        """The effective forward-progress flag threshold (cycles with no
        retirement anywhere): ``progress_cyc``, or the conservative
        ``max(2000, 4 * watchdog_cyc)`` default when 0."""
        if self.progress_cyc > 0:
            return self.progress_cyc
        return max(2000, 4 * self.watchdog_cyc)

    # ---- host-side schedule derivation ----------------------------------
    def victim_mask(self, size: int, count: int, salt: int) -> np.ndarray:
        """``(size,)`` bool mask with ``min(count, size)`` True lanes,
        drawn without replacement from ``(fault_seed, salt)`` — the one
        sampler every victim set uses, so a plan's schedule is a pure
        function of the plan (numpy RNG; nothing enters the scan)."""
        mask = np.zeros((size,), bool)
        k = min(count, size)
        if k > 0:
            rng = np.random.default_rng([self.fault_seed, salt])
            mask[rng.choice(size, size=k, replace=False)] = True
        return mask

    def kill_mask(self, n: int) -> np.ndarray:
        """(n,) uniform-kill victims (``kill_holder=0`` mode)."""
        return self.victim_mask(n, self.n_kill, _SALT_KILL)

    def stall_mask(self, n: int) -> np.ndarray:
        """(n,) transient-stall victims."""
        return self.victim_mask(n, self.n_stall, _SALT_STALL)

    def bank_stall_mask(self, a: int) -> np.ndarray:
        """(a,) bank-stall victims (over the static bank allocation)."""
        return self.victim_mask(a, self.n_bank_stall, _SALT_BANK)
