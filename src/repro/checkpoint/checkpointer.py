"""Sharded checkpointing with async save and elastic restore.

Layout (one directory per step):
    <dir>/step_000042/
        manifest.json        # step, tree structure, leaf dtypes/shapes
        leaf_00000.npy ...   # one file per pytree leaf

* **Async save**: the device→host transfer happens synchronously (cheap),
  the file writes happen on a background thread; ``wait()`` joins. The
  coordinator is notified by *event*, not by polling (Mwait analogue —
  see ``distributed.coordinator``).
* **Elastic restore**: leaves are loaded on host and re-sharded with
  ``jax.device_put`` against whatever mesh/sharding the *new* job uses —
  restoring onto a different pod count is the elastic-scaling path.
* **Integrity**: the manifest is written last and fsynced; a crash mid-save
  leaves no valid manifest, so ``latest_step`` never picks up a torn save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_CUSTOM_DTYPES = {"bfloat16": ml_dtypes.bfloat16,
                  "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
                  "float8_e5m2": ml_dtypes.float8_e5m2}


def _to_savable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _CUSTOM_DTYPES:
        return arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16), name
    return arr, name


def _from_saved(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _CUSTOM_DTYPES:
        return arr.view(_CUSTOM_DTYPES[name])
    return arr

Params = Any


def _flatten_with_paths(tree: Params) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append((jax.tree_util.keystr(path), leaf))
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, coordinator=None):
        self.dir = directory
        self.coordinator = coordinator
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Params, wait: bool = False):
        """Snapshot to host memory synchronously, write files async."""
        self.wait()                                   # one save in flight
        flat, _ = _flatten_with_paths(tree)
        host = [(p, np.asarray(x)) for p, x in flat]  # device -> host now
        t = threading.Thread(target=self._write, args=(step, host),
                             daemon=True)
        self._thread = t
        t.start()
        if wait:
            self.wait()

    def _write(self, step: int, host_leaves):
        path = os.path.join(self.dir, f"step_{step:09d}")
        tmp = path + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, (p, arr) in enumerate(host_leaves):
            fname = f"leaf_{i:05d}.npy"
            savable, dtype_name = _to_savable(arr)
            np.save(os.path.join(tmp, fname), savable)
            manifest["leaves"].append(
                {"path": p, "file": fname, "dtype": dtype_name,
                 "shape": list(arr.shape)})
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(path, ignore_errors=True)
        os.rename(tmp, path)                          # atomic publish
        if self.coordinator is not None:
            self.coordinator.notify("checkpoint_saved", step=step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(full, "manifest.json")):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: int, like: Params,
                sharding_fn: Optional[Callable[[str, Any], Any]] = None
                ) -> Params:
        """Restore into the structure of ``like`` (abstract or concrete).
        ``sharding_fn(path, leaf_template) -> Sharding`` enables elastic
        re-sharding onto a different mesh."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like, treedef = _flatten_with_paths(like)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        leaves = []
        for p, tmpl in flat_like:
            entry = by_path[p]
            arr = _from_saved(np.load(os.path.join(path, entry["file"])),
                              entry["dtype"])
            if sharding_fn is not None:
                leaves.append(jax.device_put(arr, sharding_fn(p, tmpl)))
            else:
                leaves.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves)
