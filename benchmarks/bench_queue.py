"""Fig. 6 — concurrent queue ops/cycle vs. core count + fairness band.

Runs the registered ``ms_queue`` workload: each op is an enqueue RMW on
the tail word linked to a dequeue RMW on the head word (the workload's
canonical scenario supplies the two hot addresses and link-update
modify time), with the paper's fixed backoff for the retry protocols.
Claims: Colibri sustains flat throughput to 256 cores and is the
fairest (narrow min/max band); LRSC and the lock-based queue collapse
at scale.  ``colibri_hier`` tracks flat Colibri while keeping most
wake-ups inside a cluster.  Calibration residuals: our collapse onset
is 256 cores (paper: 64), and since PR 2 a queue *op* is the full
enqueue+dequeue pair of the two-atomic program rather than the former
single-RMW approximation — per-op throughput roughly halves and the
headline ratios shift (EXPERIMENTS.md §Workloads records the deltas).

One ``repro.sync.Study`` — the core-count axis changes array shapes so
each (protocol, cores) point still compiles separately, but the shared
runner keeps the API uniform and batches any same-shape points.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks._common import pick
from repro.sync import Spec, Study, scenario

CORES = (2, 8, 32, 64, 128, 256)
PROTOS = ("colibri", "colibri_hier", "lrsc", "amo_lock")
CYCLES = pick(10_000, 1_500)
KW = dict(backoff=128, backoff_exp=1, **scenario("ms_queue"))


def rows(cycles: int = CYCLES) -> List[Dict]:
    study = Study(Spec(workload="ms_queue", cycles=cycles, **KW)) \
        .grid(protocol=PROTOS, n_cores=CORES)
    return [r.to_row(figure="fig6",
                     ops_per_cycle=r.throughput,
                     atomics_per_cycle=r.atomics_per_cycle,
                     slowest_core=r.fairness_min,
                     fastest_core=r.fairness_max)
            for r in study.run()]


def headline(rs: List[Dict]) -> Dict[str, float]:
    t = {(r["protocol"], r["cores"]): r for r in rs}
    col, lrsc = t[("colibri", 8)], t[("lrsc", 8)]
    return {
        "colibri_over_lrsc_8cores":
            col["ops_per_cycle"] / lrsc["ops_per_cycle"],
        "colibri_over_lrsc_256cores":
            t[("colibri", 256)]["ops_per_cycle"]
            / t[("lrsc", 256)]["ops_per_cycle"],
        # Jain index replaces the old fastest/max(slowest, 1e-9) span,
        # which reported a meaningless ~1e9 once LRSC starved a core
        "colibri_jain_256": t[("colibri", 256)]["jain_fairness"],
        "lrsc_jain_256": t[("lrsc", 256)]["jain_fairness"],
        "hier_over_colibri_256":
            t[("colibri_hier", 256)]["ops_per_cycle"]
            / t[("colibri", 256)]["ops_per_cycle"],
    }
