"""Fig. 6 — concurrent queue ops/cycle vs. core count + fairness band.

Runs the registered ``ms_queue`` workload: each op is an enqueue RMW on
the tail word linked to a dequeue RMW on the head word (the workload's
canonical scenario supplies the two hot addresses and link-update
modify time), with the paper's fixed backoff for the retry protocols.
Claims: Colibri sustains flat throughput to 256 cores and is the
fairest (narrow min/max band); LRSC and the lock-based queue collapse
at scale.  ``colibri_hier`` tracks flat Colibri while keeping most
wake-ups inside a cluster.  Calibration residuals: our collapse onset
is 256 cores (paper: 64), and since PR 2 a queue *op* is the full
enqueue+dequeue pair of the two-atomic program rather than the former
single-RMW approximation — per-op throughput roughly halves and the
headline ratios shift (EXPERIMENTS.md §Workloads records the deltas).

Configs run through ``core.sweep`` — the core-count axis changes array
shapes so each (protocol, cores) point still compiles separately, but
the shared runner keeps the API uniform and batches any same-shape
points.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import workloads
from repro.core.sim import SimParams
from repro.core.sweep import sweep

CORES = (2, 8, 32, 64, 128, 256)
PROTOS = ("colibri", "colibri_hier", "lrsc", "amo_lock")
CYCLES = 10_000
KW = dict(backoff=128, backoff_exp=1, **workloads.get("ms_queue").scenario)


def rows(cycles: int = CYCLES) -> List[Dict]:
    configs = [SimParams(protocol=proto, workload="ms_queue", n_cores=n,
                         cycles=cycles, **KW)
               for proto in PROTOS for n in CORES]
    out = []
    for p, r in zip(configs, sweep(configs)):
        out.append({"figure": "fig6", "protocol": p.protocol,
                    "cores": p.n_cores,
                    "ops_per_cycle": r["throughput"],
                    "atomics_per_cycle": float(r["opc"].sum()) / p.cycles,
                    "slowest_core": r["fairness_min"],
                    "fastest_core": r["fairness_max"],
                    "jain_fairness": r["jain_fairness"],
                    "lat_p95": r["lat_p95"],
                    "energy_pj_per_op": r["energy_pj_per_op"]})
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    t = {(r["protocol"], r["cores"]): r for r in rs}
    col, lrsc = t[("colibri", 8)], t[("lrsc", 8)]
    return {
        "colibri_over_lrsc_8cores":
            col["ops_per_cycle"] / lrsc["ops_per_cycle"],
        "colibri_over_lrsc_256cores":
            t[("colibri", 256)]["ops_per_cycle"]
            / t[("lrsc", 256)]["ops_per_cycle"],
        # Jain index replaces the old fastest/max(slowest, 1e-9) span,
        # which reported a meaningless ~1e9 once LRSC starved a core
        "colibri_jain_256": t[("colibri", 256)]["jain_fairness"],
        "lrsc_jain_256": t[("lrsc", 256)]["jain_fairness"],
        "hier_over_colibri_256":
            t[("colibri_hier", 256)]["ops_per_cycle"]
            / t[("colibri", 256)]["ops_per_cycle"],
    }
