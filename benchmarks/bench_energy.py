"""Table II — energy per atomic op at highest contention.

Per-event energies fit once against the paper's column (calibration), then
the model is evaluated per protocol; residuals reported. Also derives the
headline efficiency ratios (7.1× vs LRSC, 8.8× vs locks) and checks the
frozen calibration (``costmodel.CALIBRATED_ENERGY`` — the fit every
simulation uses for ``energy_pj_per_op``) against the fresh fit, so
drift between the engine and the frozen constants is visible in every
benchmark run.  Stats come from ``repro.sync`` Results
(``Result.energy_stats``), so the fit sees the full required-key
contract (including ``bar_cyc``)."""
from __future__ import annotations

from typing import Dict, List

from benchmarks._common import pick
from repro.core.costmodel import (PAPER_ENERGY, default_fit, energy_per_op,
                                  fit_energy)
from repro.sync import Spec, run

CYCLES = pick(12_000, 1_500)


def _stats():
    return {proto: run(Spec(
        protocol=proto, n_addrs=1, cycles=CYCLES,
        **(dict(backoff=128, backoff_exp=1) if proto == "amo_lock"
           else {}))).energy_stats()
        for proto in ("amo", "colibri", "lrsc", "amo_lock")}


def rows() -> List[Dict]:
    stats = _stats()
    fit = fit_energy(stats)
    frozen = default_fit()
    out = []
    for proto, target in PAPER_ENERGY.items():
        model = energy_per_op(stats[proto], fit)
        out.append({"table": "energy", "protocol": proto,
                    "paper_pj_per_op": target,
                    "model_pj_per_op": round(model, 1),
                    "frozen_fit_pj_per_op":
                        round(energy_per_op(stats[proto], frozen), 1),
                    "err_pct": round(100 * (model - target) / target, 1)})
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    t = {r["protocol"]: r["model_pj_per_op"] for r in rs}
    return {"lrsc_over_colibri_energy": t["lrsc"] / t["colibri"],      # ~7.1
            "lock_over_colibri_energy": t["amo_lock"] / t["colibri"],  # ~8.8
            "max_energy_model_err_pct": max(abs(r["err_pct"]) for r in rs),
            "frozen_fit_max_drift_pct": max(
                abs(100 * (r["frozen_fit_pj_per_op"] - r["model_pj_per_op"])
                    / max(r["model_pj_per_op"], 1e-9)) for r in rs)}
