"""Summary panels — the paper's full metric triple vs. core count.

The paper's headline claims are throughput **and** fairness **and**
energy efficiency; the per-figure benchmarks each slice one of them.
This summary runs every registered protocol × every registered workload
across a core-count axis and reports the whole triple per point
(ops/cycle, Jain fairness, p50/p95/max completion latency, pJ/op), then
asserts the paper's cross-cutting trends so CI catches regressions:

  * **energy** — the polling-free protocols (colibri, lrscwait,
    mwait_lock, colibri_hier) beat LRSC's pJ/op at 256 cores on every
    workload (Table II's 7.1× generalised beyond the RMW loop);
  * **fairness** — Colibri's Jain index at 256 cores is at least
    LRSC's on every workload (Fig. 6's narrow band, now as a bounded
    index instead of a min/max span that explodes when a core starves);
  * **throughput** — Colibri ≥ LRSC at 256 cores on every workload.

The grid runs as one streaming ``repro.sync.Study`` — rows are built
from each :class:`Result` as its sweep chunk materializes (the spec on
every result identifies its point, so chunk-completion order is fine),
instead of waiting on the whole protocol × workload × cores product.

``run.py --only summary`` → ``reports/benchmarks.summary.json``.
``REPRO_BENCH_QUICK=1`` (the CI smoke row) trims to one workload, the
five headline protocols and the 64/256-core points.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks._common import pick
from repro.sync import Spec, Study, protocols, scenario, workloads

CORES = pick((8, 64, 256), (64, 256))
PROTOS = pick(tuple(sorted(protocols())),
              ("colibri", "lrscwait", "mwait_lock", "lrsc", "amo_lock"))
WORKLOADS = pick(tuple(sorted(workloads())), ("rmw_loop",))
CYCLES = pick(6_000, 2_000)

#: protocols whose contenders never busy-wait (polls == 0 everywhere —
#: the workload-grid benchmark asserts that; here we assert the paper's
#: consequence: they win the energy column at scale)
POLLING_FREE = ("colibri", "lrscwait", "mwait_lock", "colibri_hier")

#: spin/retry protocols use the paper's stated fixed 128-cycle backoff
FIXED_BACKOFF = dict(backoff=128, backoff_exp=1)


def rows(cycles: int = CYCLES) -> List[Dict]:
    study = Study.from_specs(
        Spec(protocol=proto, workload=wl, n_cores=n, cycles=cycles,
             **scenario(wl),
             **(FIXED_BACKOFF if proto.endswith("lock") else {}))
        for wl in WORKLOADS for proto in PROTOS for n in CORES)
    return [r.to_row(figure="summary", ops_per_cycle=r.throughput)
            for r in study.stream()]


def headline(rs: List[Dict]) -> Dict[str, float]:
    t = {(r["workload"], r["protocol"], r["cores"]): r for r in rs}
    protos = {r["protocol"] for r in rs}
    pf = [p for p in POLLING_FREE if p in protos]
    wls = sorted({r["workload"] for r in rs})

    # paper-trend assertions (checked in CI: run.py propagates a failure)
    for wl in wls:
        lrsc = t[(wl, "lrsc", 256)]
        for p in pf:
            e_pf = t[(wl, p, 256)]["energy_pj_per_op"]
            assert e_pf < lrsc["energy_pj_per_op"], \
                (f"polling-free {p} lost the energy column to lrsc on "
                 f"{wl}@256c: {e_pf:.1f} vs "
                 f"{lrsc['energy_pj_per_op']:.1f} pJ/op")
        col = t[(wl, "colibri", 256)]
        assert col["jain_fairness"] >= lrsc["jain_fairness"], \
            f"colibri less fair than lrsc on {wl}@256c"
        assert col["ops_per_cycle"] >= lrsc["ops_per_cycle"], \
            f"colibri slower than lrsc on {wl}@256c"

    ratio = lambda wl, k: (t[(wl, "lrsc", 256)][k]
                           / max(t[(wl, "colibri", 256)][k], 1e-9))
    head: Dict[str, float] = {
        "pollfree_energy_wins_256": 1.0,          # asserted above
        "colibri_fair_and_fast_256": 1.0,         # asserted above
        "min_lrsc_over_colibri_energy_256":
            min(ratio(wl, "energy_pj_per_op") for wl in wls),
        "max_lrsc_over_colibri_energy_256":
            max(ratio(wl, "energy_pj_per_op") for wl in wls),
    }
    wl0 = "rmw_loop" if "rmw_loop" in wls else wls[0]
    head["rmw_lrsc_over_colibri_energy_256"] = ratio(wl0, "energy_pj_per_op")
    head["rmw_colibri_jain_256"] = t[(wl0, "colibri", 256)]["jain_fairness"]
    head["rmw_lrsc_jain_256"] = t[(wl0, "lrsc", 256)]["jain_fairness"]
    head["rmw_colibri_lat_p95_256"] = t[(wl0, "colibri", 256)]["lat_p95"]
    head["rmw_lrsc_lat_p95_256"] = t[(wl0, "lrsc", 256)]["lat_p95"]
    return head
