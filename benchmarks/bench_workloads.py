"""Workload × protocol grid — every concurrent-algorithm program against
every synchronization protocol, through one ``repro.sync.Study``.

This is the scenario-diversity benchmark the paper's headline claim
("various concurrent algorithms with high and low contention") actually
needs: instead of approximating the queue and histogram with parameter
tweaks, each column runs the registered workload program (two linked
atomics for ``ms_queue``, a Zipf stream for ``zipf_histogram``, a real
arrival barrier for ``barrier_phases``, ...).  Claims validated:

  * Colibri is polling-free (``polls == 0``) on **every** workload;
  * Colibri beats LRSC on every workload, hardest where the program
    concentrates atomics (treiber_stack, barrier arrival counter);
  * the Zipf skew ladder (one traced axis, one compile) degrades LRSC
    toward its high-contention collapse while Colibri stays flat.

Each (workload, protocol) pair is one static fingerprint; the two seeds
and the skew ladder batch through ``jax.vmap`` inside it.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks._common import pick
from repro.sync import Spec, Study, scenario

WORKLOADS = ("rmw_loop", "ms_queue", "treiber_stack", "zipf_histogram",
             "barrier_phases")
PROTOS = ("colibri", "lrscwait", "mwait_lock", "lrsc", "amo_lock")
# quick horizon stays >= 2.5k: below that the 64-core colibri queue has
# not wrapped treiber_stack's push+pop program once and ratios read 0
CYCLES = pick(6_000, 2_500)
N_CORES = 64
SEEDS = (0, 1)
#: scenario knobs come from each workload's canonical ``scenario``;
#: rmw_loop gets a moderate-contention address space for the grid
OVERRIDES = {"rmw_loop": dict(n_addrs=16)}
ZIPF_LADDER = (0, 100, 200)


def _scenario(wl: str) -> dict:
    return {**scenario(wl), **OVERRIDES.get(wl, {})}


def rows(cycles: int = CYCLES) -> List[Dict]:
    labelled = [
        (wl, proto, Spec(protocol=proto, workload=wl, n_cores=N_CORES,
                         cycles=cycles, seed=seed, **_scenario(wl)))
        for wl in WORKLOADS for proto in PROTOS for seed in SEEDS
    ]
    # Zipf skew ladder rides the same colibri/lrsc static groups as the
    # grid rows — the traced zipf_skew axis adds no compiles.
    labelled += [
        (f"zipf_s{skew/100:.1f}", proto,
         Spec(protocol=proto, workload="zipf_histogram",
              n_cores=N_CORES, cycles=cycles,
              **{**_scenario("zipf_histogram"), "zipf_skew": skew}))
        for proto in ("colibri", "lrsc") for skew in ZIPF_LADDER
    ]
    study = Study.from_specs(s for _, _, s in labelled)
    out: List[Dict] = []
    acc: Dict[tuple, Dict] = {}
    for (wl, proto, s), r in zip(labelled, study.run()):
        row = acc.setdefault((wl, proto), {
            "figure": "workload_grid", "workload": wl, "protocol": proto,
            "cores": s.topology.n_cores, "ops_per_cycle": 0.0,
            "atomics_per_cycle": 0.0, "polls": 0, "msgs": 0,
            "jain_fairness": 0.0, "lat_p95": 0.0,
            "energy_pj_per_op": 0.0, "n": 0})
        row["ops_per_cycle"] += r.throughput
        row["atomics_per_cycle"] += r.atomics_per_cycle
        row["polls"] += r.polls
        row["msgs"] += r.msgs
        row["jain_fairness"] += r.jain_fairness
        row["lat_p95"] += r.lat_p95
        row["energy_pj_per_op"] += r.energy_pj_per_op
        row["n"] += 1
    for row in acc.values():                     # mean over seeds
        for k in ("ops_per_cycle", "atomics_per_cycle", "jain_fairness",
                  "lat_p95", "energy_pj_per_op"):
            row[k] /= row["n"]
        out.append(row)
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    t = {(r["workload"], r["protocol"]): r for r in rs}
    head: Dict[str, float] = {}
    for wl in WORKLOADS:
        head[f"{wl}_colibri_over_lrsc"] = (
            t[(wl, "colibri")]["ops_per_cycle"]
            / max(t[(wl, "lrsc")]["ops_per_cycle"], 1e-9))
    head["colibri_polls_all_workloads"] = float(sum(
        t[(wl, "colibri")]["polls"] for wl in WORKLOADS))
    head["pollfree_protocols_clean"] = float(all(
        t[(wl, proto)]["polls"] == 0
        for wl in WORKLOADS for proto in ("colibri", "lrscwait",
                                          "mwait_lock")))
    lad = {(r["workload"], r["protocol"]): r["ops_per_cycle"] for r in rs
           if r["workload"].startswith("zipf_s")}
    head["zipf_skew2_colibri_over_lrsc"] = (
        lad[("zipf_s2.0", "colibri")] / max(lad[("zipf_s2.0", "lrsc")], 1e-9))
    return head
