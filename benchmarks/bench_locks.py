"""Fig. 4 — lock-based histogram vs. generic-RMW atomics.

Colibri (direct LRSCwait RMW) vs spin locks (AMO test&set, LRSC pair) with
the paper's fixed 128-cycle backoff, and the Mwait MCS queue lock.
Claims: Colibri best everywhere; spin locks collapse at high contention;
waiting-based locks worst at LOW contention (management overhead)."""
from __future__ import annotations

from typing import Dict, List

from repro.core.sim import SimParams, run

BINS = (1, 4, 16, 64, 256, 1024)
LOCKS = ("colibri", "amo_lock", "lrsc_lock", "mwait_lock")
CYCLES = 12_000


def rows(cycles: int = CYCLES) -> List[Dict]:
    out = []
    for proto in LOCKS:
        for bins in BINS:
            kw = dict(backoff=128, backoff_exp=1) if proto.endswith("lock") \
                else {}
            r = run(SimParams(protocol=proto, n_addrs=bins, cycles=cycles,
                              **kw))
            out.append({"figure": "fig4", "protocol": proto, "bins": bins,
                        "updates_per_cycle": r["throughput"],
                        "polls": int(r["polls"])})
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    t = {(r["protocol"], r["bins"]): r["updates_per_cycle"] for r in rs}
    return {
        "colibri_over_amo_lock_high": t[("colibri", 1)] / t[("amo_lock", 1)],
        "colibri_over_mwait_lock_high":
            t[("colibri", 1)] / t[("mwait_lock", 1)],
        "colibri_best_everywhere": float(all(
            t[("colibri", b)] >= max(t[(p, b)] for p in LOCKS[1:]) * 0.99
            for b in BINS)),
    }
