"""Fig. 4 — lock-based histogram vs. generic-RMW atomics.

Colibri (direct LRSCwait RMW) vs spin locks (AMO test&set, LRSC pair,
FIFO ticket dispenser) with the paper's fixed 128-cycle backoff, and the
Mwait MCS queue lock.  Claims: Colibri best everywhere; spin locks
collapse at high contention; waiting-based locks worst at LOW contention
(management overhead).  ``ticket_lock`` sits between: polling like
``amo_lock`` but with FIFO fairness, paying serialized ticket handoffs.

One ``repro.sync.Study`` per figure (one compile per lock); rows come
from ``Result.to_row`` — ``jain_fairness`` is the primary fairness
metric (the former ``max/max(min, 1e-9)`` span exploded to ~1e9
whenever a spin lock starved a core), with the NaN-safe span riding
along as ``None`` once any core starves.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks._common import pick
from repro.sync import Spec, Study

BINS = (1, 4, 16, 64, 256, 1024)
LOCKS = ("colibri", "amo_lock", "lrsc_lock", "ticket_lock", "mwait_lock")
CYCLES = pick(12_000, 1_500)


def rows(cycles: int = CYCLES) -> List[Dict]:
    specs = []
    for proto in LOCKS:
        kw = dict(backoff=128, backoff_exp=1) if proto.endswith("lock") \
            else {}
        specs += [Spec(protocol=proto, n_addrs=bins, cycles=cycles, **kw)
                  for bins in BINS]
    return [r.to_row(figure="fig4", bins=r.spec.topology.n_addrs,
                     updates_per_cycle=r.throughput)
            for r in Study.from_specs(specs).run()]


def headline(rs: List[Dict]) -> Dict[str, float]:
    t = {(r["protocol"], r["bins"]): r["updates_per_cycle"] for r in rs}
    jain = {(r["protocol"], r["bins"]): r["jain_fairness"] for r in rs}
    return {
        "colibri_over_amo_lock_high": t[("colibri", 1)] / t[("amo_lock", 1)],
        "colibri_over_mwait_lock_high":
            t[("colibri", 1)] / t[("mwait_lock", 1)],
        "colibri_best_everywhere": float(all(
            t[("colibri", b)] >= max(t[(p, b)] for p in LOCKS[1:]) * 0.99
            for b in BINS)),
        "ticket_fair_vs_amo_lock_unfair": float(
            jain[("ticket_lock", 4)] >= jain[("amo_lock", 4)]),
        "ticket_jain_4bins": jain[("ticket_lock", 4)],
        "amo_lock_jain_4bins": jain[("amo_lock", 4)],
    }
