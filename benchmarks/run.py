"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus a headline summary that
EXPERIMENTS.md quotes. Roofline/dry-run analysis lives in
``benchmarks/roofline.py`` (reads reports/dryrun/*.json).

``--list`` prints the available benchmark names; ``--only <name>`` runs
one benchmark (an exact name match wins, otherwise substring match);
``--out DIR`` redirects the JSON report (default: ``reports/``)::

    PYTHONPATH=src:benchmarks/.. python benchmarks/run.py --list
    PYTHONPATH=src:benchmarks/.. python benchmarks/run.py --only engine
    PYTHONPATH=src:benchmarks/.. python benchmarks/run.py --out /tmp/r
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _run(name, mod):
    t0 = time.perf_counter()
    rs = mod.rows()
    dt_us = (time.perf_counter() - t0) * 1e6 / max(len(rs), 1)
    head = mod.headline(rs)
    derived = ";".join(f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in head.items())
    print(f"{name},{dt_us:.1f},{derived}")
    return {"rows": rs, "headline": head}


def main(argv=None) -> None:
    from repro.sync import enable_persistent_cache
    enable_persistent_cache()        # repeat runs skip XLA recompiles
    from benchmarks import (bench_area, bench_energy, bench_engine,
                            bench_histogram, bench_interference,
                            bench_locks, bench_queue, bench_scatter_kernel,
                            bench_sweep, bench_workloads, fig_summary)
    benches = {
        "summary": fig_summary,
        "fig3_histogram": bench_histogram,
        "fig4_locks": bench_locks,
        "fig5_interference": bench_interference,
        "fig6_queue": bench_queue,
        "table1_area": bench_area,
        "table2_energy": bench_energy,
        "scatter_kernel": bench_scatter_kernel,
        "sweep_speedup": bench_sweep,
        "workloads_grid": bench_workloads,
        "engine": bench_engine,
    }
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", metavar="NAME", default=None,
                    help="run a single benchmark (exact name first, then "
                         "substring match against " + ", ".join(benches)
                         + ")")
    ap.add_argument("--list", action="store_true",
                    help="print the available benchmark names and exit")
    ap.add_argument("--out", metavar="DIR", default=None,
                    help="directory for the JSON report "
                         "(default: <repo>/reports)")
    args = ap.parse_args(argv)
    if args.list:
        for name in benches:
            print(name)
        return
    if args.only:
        if args.only in benches:          # exact name wins: "--only summary"
            selected = {args.only: benches[args.only]}
        else:                             # must not also run fig_summary etc.
            selected = {k: v for k, v in benches.items() if args.only in k}
        if not selected:
            raise SystemExit(f"--only {args.only!r} matches none of: "
                             + ", ".join(benches))
    else:
        selected = benches

    results = {}
    print("name,us_per_call,derived")
    for name, mod in selected.items():
        results[name] = _run(name, mod)

    out_dir = args.out or os.path.join(os.path.dirname(__file__), "..",
                                       "reports")
    os.makedirs(out_dir, exist_ok=True)
    suffix = f".{args.only}" if args.only else ""
    out_path = os.path.join(out_dir, f"benchmarks{suffix}.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"# full rows -> {out_path}")


if __name__ == "__main__":
    main()
