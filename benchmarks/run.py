"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus a headline summary that
EXPERIMENTS.md quotes. Roofline/dry-run analysis lives in
``benchmarks/roofline.py`` (reads reports/dryrun/*.json).

``--list`` prints the available benchmark names; ``--only <name>`` runs
one benchmark (an exact name match wins, otherwise substring match);
``--out DIR`` redirects the JSON report (default: ``reports/``);
``--profile <name>`` runs one benchmark inside ``jax.profiler.trace()``
and prints the dump directory (open it with TensorBoard's profile
plugin or https://ui.perfetto.dev)::

    PYTHONPATH=src:benchmarks/.. python benchmarks/run.py --list
    PYTHONPATH=src:benchmarks/.. python benchmarks/run.py --only engine
    PYTHONPATH=src:benchmarks/.. python benchmarks/run.py --out /tmp/r
    PYTHONPATH=src:benchmarks/.. python benchmarks/run.py --profile engine

Every report carries a top-level ``provenance`` block (git sha, jax
versions, device, backend, timestamp — ``benchmarks/_common.provenance``)
and a per-benchmark ``run_report`` with the sweep runner's per-chunk
compile/execute instrumentation (``repro.obs.RunReport``).
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import time


def _run(name, mod):
    from repro import obs
    t0 = time.perf_counter()
    with obs.collect() as report:
        rs = mod.rows()
    dt_us = (time.perf_counter() - t0) * 1e6 / max(len(rs), 1)
    head = mod.headline(rs)
    derived = ";".join(f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in head.items())
    print(f"{name},{dt_us:.1f},{derived}")
    if report.n_chunks:
        print(f"#   sweep: {report.summary()}")
    return {"rows": rs, "headline": head, "run_report": report.to_dict()}


def _select(benches, name):
    """The benchmark subset a --only/--profile NAME selects."""
    if name in benches:                   # exact name wins: "summary"
        return {name: benches[name]}
    sel = {k: v for k, v in benches.items() if name in k}
    if not sel:
        raise SystemExit(f"{name!r} matches none of: " + ", ".join(benches))
    return sel


def main(argv=None) -> None:
    from repro.sync import enable_persistent_cache
    enable_persistent_cache()        # repeat runs skip XLA recompiles
    from benchmarks import (bench_area, bench_energy, bench_engine,
                            bench_faults, bench_histogram,
                            bench_interference, bench_locks, bench_queue,
                            bench_scatter_kernel, bench_sweep,
                            bench_topology, bench_workloads, fig_summary)
    benches = {
        "summary": fig_summary,
        "fig3_histogram": bench_histogram,
        "fig4_locks": bench_locks,
        "fig5_interference": bench_interference,
        "fig6_queue": bench_queue,
        "table1_area": bench_area,
        "table2_energy": bench_energy,
        "scatter_kernel": bench_scatter_kernel,
        "sweep_speedup": bench_sweep,
        "workloads_grid": bench_workloads,
        "engine": bench_engine,
        "faults": bench_faults,
        "topology": bench_topology,
    }
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", metavar="NAME", default=None,
                    help="run a single benchmark (exact name first, then "
                         "substring match against " + ", ".join(benches)
                         + ")")
    ap.add_argument("--list", action="store_true",
                    help="print the available benchmark names and exit")
    ap.add_argument("--out", metavar="DIR", default=None,
                    help="directory for the JSON report "
                         "(default: <repo>/reports)")
    ap.add_argument("--profile", metavar="NAME", default=None,
                    help="run ONE benchmark under jax.profiler.trace() "
                         "and print the dump directory (selects like "
                         "--only; must match exactly one benchmark)")
    args = ap.parse_args(argv)
    if args.list:
        for name in benches:
            print(name)
        return
    profile_dir = None
    if args.profile:
        selected = _select(benches, args.profile)
        if len(selected) != 1:
            raise SystemExit(f"--profile {args.profile!r} must match "
                             f"exactly one benchmark, got: "
                             + ", ".join(selected))
    elif args.only:
        selected = _select(benches, args.only)
    else:
        selected = benches

    out_dir = args.out or os.path.join(os.path.dirname(__file__), "..",
                                       "reports")
    os.makedirs(out_dir, exist_ok=True)

    from benchmarks._common import provenance
    results = {"provenance": provenance()}
    print("name,us_per_call,derived")
    if args.profile:
        import jax
        name = next(iter(selected))
        profile_dir = os.path.join(out_dir,
                                   f"profile_{name}_{int(time.time())}")
        prof_ctx = jax.profiler.trace(profile_dir)
    else:
        prof_ctx = contextlib.nullcontext()
    with prof_ctx:
        for name, mod in selected.items():
            results[name] = _run(name, mod)
    if profile_dir:
        print(f"# profiler dump -> {profile_dir}")
        print("#   view: tensorboard --logdir <dir>  (profile plugin), or "
              "load the .trace.json.gz at https://ui.perfetto.dev")

    picked = args.only or args.profile
    suffix = f".{picked}" if picked else ""
    out_path = os.path.join(out_dir, f"benchmarks{suffix}.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"# full rows -> {out_path}")


if __name__ == "__main__":
    main()
