"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus a headline summary that
EXPERIMENTS.md quotes. Roofline/dry-run analysis lives in
``benchmarks/roofline.py`` (reads reports/dryrun/*.json)."""
from __future__ import annotations

import json
import os
import time


def _run(name, mod):
    t0 = time.perf_counter()
    rs = mod.rows()
    dt_us = (time.perf_counter() - t0) * 1e6 / max(len(rs), 1)
    head = mod.headline(rs)
    derived = ";".join(f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in head.items())
    print(f"{name},{dt_us:.1f},{derived}")
    return {"rows": rs, "headline": head}


def main() -> None:
    from benchmarks import (bench_area, bench_energy, bench_histogram,
                            bench_interference, bench_locks, bench_queue,
                            bench_scatter_kernel)
    results = {}
    print("name,us_per_call,derived")
    results["fig3_histogram"] = _run("fig3_histogram", bench_histogram)
    results["fig4_locks"] = _run("fig4_locks", bench_locks)
    results["fig5_interference"] = _run("fig5_interference", bench_interference)
    results["fig6_queue"] = _run("fig6_queue", bench_queue)
    results["table1_area"] = _run("table1_area", bench_area)
    results["table2_energy"] = _run("table2_energy", bench_energy)
    results["scatter_kernel"] = _run("scatter_kernel", bench_scatter_kernel)

    out_dir = os.path.join(os.path.dirname(__file__), "..", "reports")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "benchmarks.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"# full rows -> {os.path.join(out_dir, 'benchmarks.json')}")


if __name__ == "__main__":
    main()
