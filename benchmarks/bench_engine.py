"""Engine hot-path throughput: simulated core-cycles/second and sweep
points/second, tracked against the pre-overhaul baseline.

Three measurements, all warm (compile excluded — the persistent
compilation cache makes repeated benchmark runs skip compiles anyway):

* **engine** — one single-point ``repro.sync.run`` at 64 / 256 / 1024
  cores, 20k cycles, reported as simulated core-cycles per wall-second.
  The 1024-core row is the run the argsort-arbitration engine made
  impractical; the headline checks it now completes under the old
  256-core wall budget.
  The full pass adds a 4096-core row — the scale target of the Pallas
  fused-step backend — checked against the same old 256-core budget.
* **unroll ablation** — the 256-core run at ``unroll`` 1 / 4 / 8
  (EXPERIMENTS.md §Engine-throughput quotes the table).
* **backend pair** — the identical 256-core Spec run on
  ``backend="xla_cpu"`` vs the Pallas path (the native ``pallas_gpu`` /
  ``pallas_tpu`` lowering when an accelerator is visible, else the
  ``pallas_interpret`` debugging path, which is expected to be slow —
  the ratio is only a perf claim on accelerator hosts; on CPU it just
  pins that the kernel path runs end-to-end).
* **grid256** — the ``workloads_grid`` study (5 workloads × 5 protocols
  × 2 seeds) at 256 cores through ``Study.run()``, reported as points
  per second.  The acceptance bar for the hot-path overhaul is ≥2×
  against ``PRE_PR`` here.
* **telemetry ablation** — the engine run with the windowed-telemetry
  knob (``repro.obs``) at windows ∈ {0, 64, 256} on both the scan and
  Pallas-interpret backends (EXPERIMENTS.md §Telemetry-cost quotes the
  table).  The acceptance bar: ``telemetry_windows=64`` costs ≤ 10%
  engine wall time at 1024 cores on both backends.

``PRE_PR`` holds the baseline measured at commit e6a3f48 (per-cycle
``jnp.argsort`` acceptance, fused int32 FIFO key, no unroll, per-key
host syncs) on the same 2-vCPU reference box that produced every other
number in EXPERIMENTS.md; ``reports/benchmarks.engine.json`` preserves
the ratio so future PRs have a perf trajectory to compare against.

``REPRO_BENCH_QUICK=1`` (the CI smoke row) trims to 64/256 cores,
2k cycles and a 2-workload grid so the row stays cheap.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks._common import QUICK, pick, time_best, time_median
from repro.core.sim import resolve_backend
from repro.sync import Spec, Study, run

#: QUICK rows gate CI through check_trend.py; median-of-N flakes far
#: less than best-of-N on the short smoke horizons (see _common)
_time = time_median if QUICK else time_best

ENGINE_CYCLES = pick(20_000, 2_000)
ENGINE_CORES = pick((64, 256, 1024, 4096), (64, 256))
UNROLLS = pick((2, 4, 8), ())              # default unroll=1 is the
GRID_CYCLES = pick(3_000, 1_000)           # engine_256c row itself
PAIR_CYCLES = pick(2_000, 500)             # backend pair: interpret-safe
GRID_WORKLOADS = pick(("rmw_loop", "ms_queue", "treiber_stack",
                       "zipf_histogram", "barrier_phases"),
                      ("rmw_loop", "ms_queue"))
GRID_PROTOS = pick(("colibri", "lrscwait", "mwait_lock", "lrsc",
                    "amo_lock"),
                   ("colibri", "lrsc"))
GRID_SEEDS = pick((0, 1), (0,))
TELE_WINDOWS = pick((0, 64, 256), (0, 64))
TELE_CORES = pick((256, 1024), (256,))
TELE_CYCLES = pick(20_000, 2_000)
TELE_INTERP_CYCLES = pick(2_000, 500)      # interpret path: shorter horizon

#: pre-overhaul baseline (commit e6a3f48), measured with this module's
#: exact protocol on the reference box.  Keys match the row labels.
PRE_PR = {
    "engine_64c": 4.235e5,      # simulated core-cycles / s, warm
    "engine_256c": 5.908e5,
    "engine_1024c": 9.050e5,
    "engine_256c_wall_s": 8.67,  # the "old 256-core budget" (20k cycles)
    "engine_1024c_wall_s": 22.63,
    "grid256_points_per_s": 0.989,  # 50-point workloads_grid sweep @256c
}


def _pallas_backend() -> str:
    """The Pallas backend this host can actually run: the native
    lowering when ``auto`` resolves to one, else the interpreter."""
    bk = resolve_backend("auto")
    return bk if bk.startswith("pallas") else "pallas_interpret"


def _grid_study() -> Study:
    from benchmarks.bench_workloads import _scenario
    return Study.from_specs(
        Spec(protocol=proto, workload=wl, n_cores=256,
             cycles=GRID_CYCLES, seed=seed, **_scenario(wl))
        for wl in GRID_WORKLOADS for proto in GRID_PROTOS
        for seed in GRID_SEEDS)


def rows() -> List[Dict]:
    bk = resolve_backend("auto")
    out: List[Dict] = []
    for n in ENGINE_CORES:
        s = Spec(protocol="colibri", n_cores=n, cycles=ENGINE_CYCLES)
        dt = _time(lambda: run(s), reps=1 if n >= 1024 else 3)
        label = f"engine_{n}c"
        out.append({"figure": "engine", "row": label, "n_cores": n,
                    "cycles": ENGINE_CYCLES, "backend": bk, "wall_s": dt,
                    "core_cycles_per_s": n * ENGINE_CYCLES / dt,
                    "pre_pr_core_cycles_per_s": PRE_PR.get(label)})
    for u in UNROLLS:
        s = Spec(protocol="colibri", n_cores=256, cycles=ENGINE_CYCLES,
                 unroll=u)
        dt = _time(lambda: run(s))
        out.append({"figure": "engine", "row": f"unroll_{u}", "n_cores": 256,
                    "cycles": ENGINE_CYCLES, "backend": bk, "wall_s": dt,
                    "core_cycles_per_s": 256 * ENGINE_CYCLES / dt})
    pb = _pallas_backend()
    s = Spec(protocol="colibri", n_cores=256, cycles=PAIR_CYCLES)
    dt_x = _time(lambda: run(s.replace(backend="xla_cpu")), reps=1)
    dt_p = _time(lambda: run(s.replace(backend=pb)), reps=1)
    out.append({"figure": "engine", "row": "backend_pair_256c",
                "n_cores": 256, "cycles": PAIR_CYCLES,
                "backend": f"xla_cpu_vs_{pb}", "wall_s": dt_x,
                "wall_s_xla": dt_x, "wall_s_pallas": dt_p,
                "pallas_over_xla": dt_p / dt_x})
    # hierarchical-topology overhead: the same engine run with the
    # cluster2 network stage on (per-level link caps + hop billing) —
    # the flat row above is the in-benchmark baseline for the cost of
    # the topology tables
    n_topo = min(256, max(ENGINE_CORES))
    s = Spec(protocol="colibri", n_cores=n_topo, cycles=ENGINE_CYCLES,
             topology="cluster2", clusters=4)
    dt = _time(lambda: run(s))
    out.append({"figure": "engine", "row": f"engine_cluster2_{n_topo}c",
                "n_cores": n_topo, "cycles": ENGINE_CYCLES, "backend": bk,
                "topology": "cluster2", "wall_s": dt,
                "core_cycles_per_s": n_topo * ENGINE_CYCLES / dt})
    study = _grid_study()
    dt = _time(lambda: study.run(), reps=1)
    out.append({"figure": "engine", "row": "grid256", "n_points": len(study),
                "cycles": GRID_CYCLES, "backend": bk, "wall_s": dt,
                "points_per_s": len(study) / dt,
                "pre_pr_points_per_s": PRE_PR["grid256_points_per_s"]})
    # telemetry-cost ablation: windows x cores x backend (w=0 is the
    # statically-elided off path, the in-row baseline for the overhead)
    for n in TELE_CORES:
        for tele_bk, cycles, tag in ((bk, TELE_CYCLES, "tele"),
                                     (pb, TELE_INTERP_CYCLES,
                                      "tele_interp")):
            base_dt = None
            for w in TELE_WINDOWS:
                s = Spec(protocol="colibri", n_cores=n, cycles=cycles,
                         backend=tele_bk, telemetry_windows=w)
                dt = _time(lambda: run(s), reps=1 if n >= 1024 else 3)
                if w == 0:
                    base_dt = dt
                out.append({"figure": "engine", "row": f"{tag}_w{w}_{n}c",
                            "n_cores": n, "cycles": cycles,
                            "backend": tele_bk, "telemetry_windows": w,
                            "wall_s": dt,
                            "core_cycles_per_s": n * cycles / dt,
                            "overhead_vs_w0": dt / base_dt - 1.0})
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    by = {r["row"]: r for r in rs}
    head: Dict[str, float] = {}
    e256 = by.get("engine_256c")
    if e256:
        head["engine_256c_Mcyc_per_s"] = e256["core_cycles_per_s"] / 1e6
        head["engine_256c_speedup_vs_pre_pr"] = (
            e256["core_cycles_per_s"] / PRE_PR["engine_256c"])
    e1024 = by.get("engine_1024c")
    if e1024:
        head["engine_1024c_Mcyc_per_s"] = e1024["core_cycles_per_s"] / 1e6
        head["engine_1024c_under_old_256c_budget"] = float(
            e1024["wall_s"] <= PRE_PR["engine_256c_wall_s"])
    e4096 = by.get("engine_4096c")
    if e4096:
        head["engine_4096c_Mcyc_per_s"] = e4096["core_cycles_per_s"] / 1e6
        head["engine_4096c_under_old_256c_budget"] = float(
            e4096["wall_s"] <= PRE_PR["engine_256c_wall_s"])
    pair = by.get("backend_pair_256c")
    if pair:
        head["backend_pair_pallas_over_xla"] = pair["pallas_over_xla"]
    ntopo = min(256, max(ENGINE_CORES))
    topo = by.get(f"engine_cluster2_{ntopo}c")
    flat = by.get(f"engine_{ntopo}c")
    if topo and flat:
        head["cluster2_overhead_vs_flat"] = (
            topo["wall_s"] / flat["wall_s"] - 1.0)
    grid = by["grid256"]
    head["grid256_points_per_s"] = grid["points_per_s"]
    if "engine_1024c" in by:                    # full (non-QUICK) pass
        head["grid256_speedup_vs_pre_pr"] = (
            grid["points_per_s"] / PRE_PR["grid256_points_per_s"])
    for u in UNROLLS:
        head[f"unroll{u}_Mcyc_per_s"] = (
            by[f"unroll_{u}"]["core_cycles_per_s"] / 1e6)
    # telemetry acceptance: w=64 overhead at the largest measured core
    # count, on both backends (bar: <= 0.10)
    ntop = max(TELE_CORES)
    for tag, label in (("tele", "scan"), ("tele_interp", "interp")):
        r = by.get(f"{tag}_w64_{ntop}c")
        if r:
            head[f"tele_w64_overhead_{label}_{ntop}c"] = r["overhead_vs_w0"]
    return head
