"""Kernel-level colibri scatter vs. the retry-style XLA scatter-add.

Wall-clock on this host (CPU, interpret-mode pallas for the kernel; the
jnp sort+segment path is the apples-to-apples framework comparison) across
the paper's contention axis (#bins). Derived column: colibri/naive speedup
of the pure-JAX ordered-commit path."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks._common import timed
from repro.core import dispatch as D

T = 1 << 18
FEAT = 8


def rows() -> List[Dict]:
    out = []
    key = jax.random.PRNGKey(0)
    vals = jax.random.normal(jax.random.PRNGKey(1), (T, FEAT))
    ordered = jax.jit(D.ordered_segment_sum, static_argnums=2)
    native = jax.jit(D.lrsc_scatter_add, static_argnums=2)
    for bins in (2, 64, 4096):
        keys = jax.random.randint(key, (T,), 0, bins)
        _, t_ord = timed(lambda: ordered(keys, vals, bins))
        _, t_nat = timed(lambda: native(keys, vals, bins))
        out.append({"bench": "scatter_kernel", "bins": bins,
                    "ordered_us": t_ord * 1e6, "native_us": t_nat * 1e6,
                    "speedup": t_nat / t_ord})
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    return {f"speedup_bins{r['bins']}": round(r["speedup"], 2) for r in rs}
