"""Table I — area model: MemPool tile kGE per synchronization design,
plus the asymptotic state-count scaling (O(n log n · m) vs O(n + 2m))."""
from __future__ import annotations

from typing import Dict, List

from repro.core.costmodel import (PAPER_AREA, fit_area, system_overhead,
                                  tile_area)


def rows() -> List[Dict]:
    fit = fit_area()
    out = []
    for name, (param, kge) in PAPER_AREA.items():
        design = name.rsplit("_", 1)[0]
        model = tile_area(design, param, fit)
        out.append({"table": "area", "design": name, "paper_kge": kge,
                    "model_kge": round(model, 1),
                    "err_pct": round(100 * (model - kge) / kge, 2)})
    for n, m in ((256, 1024), (1024, 4096), (4096, 16384)):
        out.append({"table": "area_scaling", "cores": n, "banks": m,
                    "ideal_state": system_overhead("lrscwait_ideal", n, m),
                    "colibri_state": system_overhead("colibri", n, m)})
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    errs = [abs(r["err_pct"]) for r in rs if r.get("table") == "area"]
    return {"max_area_model_error_pct": max(errs)}
