"""Topology benchmark — hierarchical NoC vs the flat crossbar.

The paper's Colibri is explicitly hierarchical (per-cluster reservation
stations, cross-cluster handoffs), but every Fig. 4/5/6 row so far ran
on the engine's flat crossbar.  This benchmark reruns the contended-
histogram shape on the ``core.topologies`` cluster trees and asks the
question the hierarchy exists to answer: **does cluster-aware waiting
keep its win once remote banks cost real hops and cross-cluster links
have finite capacity?**

Rows, per core count:

* the protocol × topology matrix — ``colibri`` / ``lrsc`` on ``flat``
  and ``cluster2``, plus the cluster-aware waiters (``colibri_hier``,
  ``hw_event``) and the FEB primitive (``nb_feb``) on ``cluster2`` —
  each row carrying the metric triple plus the per-op NoC hop count the
  energy model bills at ``e_hop``;
* a ``colibri_hier`` topology ladder (``flat`` → ``cluster2`` →
  ``cluster3``) showing the hierarchy cost curve.

Headline (at the largest measured core count): ``colibri_hier`` on
``cluster2`` vs flat ``colibri`` (the hierarchy tax on the paper's
protocol), vs ``lrsc`` *on the same cluster2 NoC* (retry storms pay the
cross-cluster latency on every poll — the polling-free win grows with
hop cost), and the hop-energy share of the per-op budget.

``REPRO_BENCH_QUICK=1`` trims to 64 cores and a short horizon — the CI
smoke row ``check_trend.py`` gates on.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks._common import pick
from repro.sync import Spec, Study

CORES = pick((256, 1024), (64,))
CYCLES = pick(12_000, 1_500)
CLUSTERS = 4
N_ADDRS = 4

#: the protocol × topology matrix (Fig. 4-style contended histogram)
MATRIX = (("colibri", "flat"), ("lrsc", "flat"),
          ("colibri", "cluster2"), ("lrsc", "cluster2"),
          ("colibri_hier", "cluster2"), ("hw_event", "cluster2"),
          ("nb_feb", "cluster2"))

#: the hierarchy cost curve for the cluster-aware waiter
LADDER = pick(("flat", "cluster2", "cluster3"), ("flat", "cluster2"))


def _row(r, **extra) -> Dict:
    ops = float(np.asarray(r.stats["ops"]).sum())
    hops = float(np.asarray(r.stats.get("hops", 0)))
    return r.to_row(figure="topology",
                    clusters=r.spec.topology.clusters,
                    hops_per_op=hops / max(ops, 1.0), **extra)


def rows(cycles: int = CYCLES) -> List[Dict]:
    specs = [Spec(protocol=proto, topology=topo, clusters=CLUSTERS,
                  n_cores=n, n_addrs=N_ADDRS, cycles=cycles)
             for n in CORES for proto, topo in MATRIX]
    out = [_row(r, row=f"{r.spec.protocol.name}_"
                       f"{r.spec.topology.name}_{r.spec.topology.n_cores}c")
           for r in Study.from_specs(specs).run()]
    ladder = [Spec(protocol="colibri_hier", topology=topo,
                   clusters=CLUSTERS, n_cores=CORES[0], n_addrs=N_ADDRS,
                   cycles=cycles)
              for topo in LADDER]
    out += [_row(r, row=f"ladder_{r.spec.topology.name}")
            for r in Study.from_specs(ladder).run()]
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    n = max(CORES)
    t = {r["row"]: r["throughput"] for r in rs}
    e = {r["row"]: r["energy_pj_per_op"] for r in rs}
    h = {r["row"]: r["hops_per_op"] for r in rs}

    def key(proto, topo):
        return f"{proto}_{topo}_{n}c"

    hier_c2 = t[key("colibri_hier", "cluster2")]
    return {
        # the hierarchy tax: cluster-aware colibri on a 2-level NoC vs
        # the paper's flat-crossbar colibri
        "hier_cluster2_over_flat_colibri":
            hier_c2 / t[key("colibri", "flat")],
        # the polling-free win ON the hierarchical NoC: every lrsc poll
        # pays cross-cluster hops, every colibri_hier wait sleeps local
        "hier_over_lrsc_cluster2": hier_c2 / t[key("lrsc", "cluster2")],
        "colibri_over_lrsc_cluster2":
            t[key("colibri", "cluster2")] / t[key("lrsc", "cluster2")],
        "hw_event_over_lrsc_cluster2":
            t[key("hw_event", "cluster2")] / t[key("lrsc", "cluster2")],
        "nb_feb_over_lrsc_cluster2":
            t[key("nb_feb", "cluster2")] / t[key("lrsc", "cluster2")],
        # hop traffic: the retry storm crosses clusters far more often
        # per completed op than the sleep-based waiters
        "lrsc_hops_per_op_cluster2": h[key("lrsc", "cluster2")],
        "hier_hops_per_op_cluster2": h[key("colibri_hier", "cluster2")],
        "lrsc_energy_over_hier_cluster2":
            e[key("lrsc", "cluster2")] / max(e[key("colibri_hier",
                                                   "cluster2")], 1e-12),
        # the ladder: deeper hierarchies cost monotone throughput
        "ladder_monotone": float(all(
            t[f"ladder_{a}"] >= t[f"ladder_{b}"] * 0.99
            for a, b in zip(LADDER, LADDER[1:]))),
    }
