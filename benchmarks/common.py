"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, Dict, List


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3):
    """(result, seconds_per_call) with block_until_ready semantics."""
    import jax
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / iters


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
