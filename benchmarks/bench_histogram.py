"""Fig. 3 — histogram throughput vs. contention for every atomic protocol.

Runs the registered ``zipf_histogram`` workload in its uniform limit
(``zipf_skew=0``) with the bin count as the contention axis — the
figure's scenario now comes from the workload registry instead of
re-stating engine parameters, and a skewed companion line
(``zipf_skew=150``) shows the contention knob real histogram kernels
experience.  The paper's claims validated here (EXPERIMENTS.md §Fig3):

  * AMO add is the roofline at all contentions;
  * Colibri ≈ LRSCwait_ideal (slight node-update penalty);
  * LRSCwait_q collapses once contention > q;
  * Colibri / LRSC ≈ 6.5× at highest contention, ~13–20% at low
    contention (since PR 2 measured over the inverse-CDF uniform
    stream; §Workloads records the small shift vs. the seed's
    hash-modulo stream).

The whole figure is one ``repro.sync.Study`` over an explicit labelled
spec list: one engine compile per protocol covers all bin counts *and*
both skew settings (the zipf skew is a traced axis).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks._common import pick
from repro.sync import Spec, Study

BINS = (1, 4, 16, 64, 256, 1024)
PROTOS = ("amo", "lrsc", "lrscwait", "colibri")
CYCLES = pick(12_000, 1_500)
WL = dict(workload="zipf_histogram", zipf_skew=0)    # uniform limit


def rows(cycles: int = CYCLES) -> List[Dict]:
    labelled = [(proto, Spec(protocol=proto, n_addrs=bins,
                             cycles=cycles, **WL))
                for proto in PROTOS for bins in BINS]
    # LRSCwait_q = 8 line (capacity collapse)
    labelled += [("lrscwait_q8", Spec(protocol={"name": "lrscwait",
                                                "q_slots": 8},
                                      n_addrs=bins, cycles=cycles, **WL))
                 for bins in BINS]
    # skewed companion lines: same compile, traced zipf_skew axis
    labelled += [(f"{proto}_zipf1.5",
                  Spec(protocol=proto, n_addrs=bins, cycles=cycles,
                       workload="zipf_histogram", zipf_skew=150))
                 for proto in ("colibri", "lrsc") for bins in BINS]
    labels = [lb for lb, _ in labelled]
    study = Study.from_specs(s for _, s in labelled)
    return [r.to_row(figure="fig3", protocol=label,
                     bins=r.spec.topology.n_addrs,
                     updates_per_cycle=r.throughput,
                     sleep_cyc=int(r["sleep_cyc"]))
            for label, r in zip(labels, study.run())]


def headline(rs: List[Dict]) -> Dict[str, float]:
    t = {(r["protocol"], r["bins"]): r["updates_per_cycle"] for r in rs}
    return {
        "high_contention_colibri_over_lrsc": t[("colibri", 1)] / t[("lrsc", 1)],
        "low_contention_colibri_over_lrsc":
            t[("colibri", 256)] / t[("lrsc", 256)],
        "colibri_over_ideal_at_1": t[("colibri", 1)] / t[("lrscwait", 1)],
        "amo_roofline_at_1": t[("amo", 1)],
        "zipf15_colibri_over_lrsc_1024bins":
            t[("colibri_zipf1.5", 1024)] / t[("lrsc_zipf1.5", 1024)],
    }
