"""Fig. 5 — matmul-worker slowdown from atomic pollers.

252:4 .. 128:128 poller:worker splits on the congested-link regime
(net_bw=13, hol_block=16, the paper's stated fixed 128-cycle backoff).
Claims: Colibri pollers leave workers unaffected (≈1.0); LRSC pollers crush
them (paper 0.26; our machine model 0.33 at 252:4).

One ``repro.sync.Study`` over contended + isolated points: per
protocol, the four 256-core contended runs share one compile
(``n_workers`` is a traced axis); only the isolated baselines compile
per core count.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks._common import pick
from repro.sync import Spec, Study

SPLITS = (4, 16, 64, 128)                 # workers; pollers = 256 - workers
PROTOS = ("amo", "lrsc", "colibri", "lrscwait")
CYCLES = pick(8_000, 1_500)
NET = dict(net_bw=13, hol_block=16, backoff=128, backoff_exp=1)


def rows(cycles: int = CYCLES) -> List[Dict]:
    contended = [Spec(protocol=proto, n_addrs=1, n_workers=w,
                      cycles=cycles, **NET)
                 for proto in PROTOS for w in SPLITS]
    isolated = [Spec(protocol=proto, n_addrs=1, n_cores=w, n_workers=w,
                     cycles=cycles, **NET)
                for proto in PROTOS for w in SPLITS]
    res = Study.from_specs(contended + isolated).run()
    out = []
    for r, base in zip(res[:len(contended)], res[len(contended):]):
        w = r.spec.workload.n_workers
        rel = r.worker_rate / max(base.worker_rate, 1e-9)
        out.append(r.to_row(figure="fig5", pollers=256 - w, workers=w,
                            relative_worker_perf=rel))
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    t = {(r["protocol"], r["workers"]): r["relative_worker_perf"]
         for r in rs}
    return {"lrsc_worker_perf_252_4": t[("lrsc", 4)],
            "colibri_worker_perf_252_4": t[("colibri", 4)]}
