"""Fig. 5 — matmul-worker slowdown from atomic pollers.

252:4 .. 128:128 poller:worker splits on the congested-link regime
(net_bw=13, hol_block=16, the paper's stated fixed 128-cycle backoff).
Claims: Colibri pollers leave workers unaffected (≈1.0); LRSC pollers crush
them (paper 0.26; our machine model 0.33 at 252:4).

The worker-split axis runs through ``core.sweep``: per protocol, the four
256-core contended runs share one compile (``n_workers`` is a traced
axis); only the isolated baselines compile per core count.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.sim import SimParams
from repro.core.sweep import sweep

SPLITS = (4, 16, 64, 128)                 # workers; pollers = 256 - workers
PROTOS = ("amo", "lrsc", "colibri", "lrscwait")
CYCLES = 8_000
NET = dict(net_bw=13, hol_block=16, backoff=128, backoff_exp=1)


def rows(cycles: int = CYCLES) -> List[Dict]:
    contended = [SimParams(protocol=proto, n_addrs=1, n_workers=w,
                           cycles=cycles, **NET)
                 for proto in PROTOS for w in SPLITS]
    isolated = [SimParams(protocol=proto, n_addrs=1, n_cores=w, n_workers=w,
                          cycles=cycles, **NET)
                for proto in PROTOS for w in SPLITS]
    res = sweep(contended + isolated)
    out = []
    for i, p in enumerate(contended):
        r, base = res[i], res[len(contended) + i]
        rel = r["worker_rate"] / max(base["worker_rate"], 1e-9)
        out.append({"figure": "fig5", "protocol": p.protocol,
                    "pollers": 256 - p.n_workers, "workers": p.n_workers,
                    "relative_worker_perf": rel,
                    "jain_fairness": r["jain_fairness"],
                    "lat_p95": r["lat_p95"],
                    "energy_pj_per_op": r["energy_pj_per_op"]})
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    t = {(r["protocol"], r["workers"]): r["relative_worker_perf"]
         for r in rs}
    return {"lrsc_worker_perf_252_4": t[("lrsc", 4)],
            "colibri_worker_perf_252_4": t[("colibri", 4)]}
