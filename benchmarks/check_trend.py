"""CI benchmark-regression gate: QUICK engine rows vs pinned baselines.

Compares the ``core_cycles_per_s`` of the engine smoke rows (a
``REPRO_BENCH_QUICK=1 run.py --only engine`` report) against the pinned
baselines in ``reports/baselines.json`` and exits non-zero when any row
regresses by more than the threshold (default 25%), so the hot-path
perf work (PR 3 scatter-free scan, PR 6 fused Pallas step) cannot rot
silently.  Improvements are reported but never fail.

Usage (what ``.github/workflows/ci.yml`` runs after the engine smoke)::

    REPRO_BENCH_QUICK=1 PYTHONPATH=src:. python benchmarks/run.py \\
        --only engine --out /tmp/ci-reports
    PYTHONPATH=src:. python benchmarks/check_trend.py \\
        --report /tmp/ci-reports/benchmarks.engine.json

Baselines are re-pinned by regenerating ``reports/baselines.json``::

    REPRO_BENCH_QUICK=1 PYTHONPATH=src:. python benchmarks/check_trend.py \\
        --pin --report <fresh engine report>

The 25% default absorbs normal CI-runner noise (shared vCPUs vary run
to run); a genuine regression from an engine change (the PR 4 carry
cliff was 3x) clears it by an order of magnitude.  The QUICK rows this
gate reads are timed **median-of-3** (``_common.time_median``) rather
than best-of-3: over the short smoke horizons, best-of-N is an order
statistic a single lucky scheduler slot can swing by tens of percent,
and the flake rate of this gate tracked that directly.  Baselines
pinned before the median switch measure the same code a few percent
faster (best <= median), which the 25% band absorbs; re-pin with
``--pin`` at the next intentional change anyway.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(REPO, "reports", "baselines.json")
DEFAULT_REPORT = os.path.join(REPO, "reports", "benchmarks.engine.json")

#: the gated metric per row kind
METRICS = ("core_cycles_per_s", "points_per_s")


def _engine_rows(report: Dict) -> Dict[str, Dict]:
    try:
        rows = report["engine"]["rows"]
    except KeyError:
        raise SystemExit("report has no 'engine' benchmark section; "
                         "generate with run.py --only engine")
    return {r["row"]: r for r in rows}


def _metric(row: Dict):
    for m in METRICS:
        if m in row and row[m] is not None:
            return m, float(row[m])
    return None, None


def pin(report: Dict, baseline_path: str) -> None:
    """Write the report's engine rows as the new pinned baselines."""
    rows = {}
    for name, row in _engine_rows(report).items():
        m, v = _metric(row)
        if m:
            rows[name] = {m: v, "wall_s": row.get("wall_s")}
    doc = {"_comment": "pinned QUICK engine baselines for "
                       "benchmarks/check_trend.py (re-pin with --pin)",
           "provenance": report.get("provenance", {}),
           "rows": rows}
    with open(baseline_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"pinned {len(rows)} baseline rows -> {baseline_path}")


def check(report: Dict, baseline: Dict, threshold: float) -> int:
    """Print a comparison table; return the number of failing rows."""
    rows = _engine_rows(report)
    failures = 0
    print(f"row                     metric             baseline"
          f"      current    ratio  verdict  (gate: >{threshold:.0%} drop)")
    for name, pinned in baseline["rows"].items():
        row = rows.get(name)
        if row is None:
            print(f"{name:<23} MISSING from report -> fail")
            failures += 1
            continue
        m, cur = _metric(row)
        base = pinned.get(m) if m else None
        if not base:
            print(f"{name:<23} no shared metric with baseline -> skip")
            continue
        ratio = cur / base
        ok = ratio >= 1.0 - threshold
        print(f"{name:<23} {m:<18} {base:>12.3e} {cur:>12.3e} "
              f"{ratio:>8.2f}  {'ok' if ok else 'REGRESSED'}")
        failures += 0 if ok else 1
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--report", default=DEFAULT_REPORT,
                    help="engine benchmark report to check "
                         f"(default: {DEFAULT_REPORT})")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="pinned baselines "
                         f"(default: {DEFAULT_BASELINE})")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional drop (default 0.25)")
    ap.add_argument("--pin", action="store_true",
                    help="write the report's rows as the new baselines "
                         "instead of checking")
    args = ap.parse_args(argv)
    with open(args.report) as f:
        report = json.load(f)
    if args.pin:
        pin(report, args.baseline)
        return
    if not os.path.exists(args.baseline):
        raise SystemExit(f"no baselines at {args.baseline}; pin them with "
                         "--pin first")
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = check(report, baseline, args.threshold)
    if failures:
        print(f"{failures} row(s) regressed past the "
              f"{args.threshold:.0%} gate", file=sys.stderr)
        sys.exit(1)
    print("benchmark trend ok")


if __name__ == "__main__":
    main()
