"""Sweep-runner speedup: one batched ``Study.run()`` vs. sequential
per-point ``repro.sync.run``.

The acceptance bar for the protocol-plugin refactor: a ≥8-point study
through the vmapped sweep runner must beat the equivalent sequential
per-point loop (the seed pattern re-jits the engine at every grid
point; the study compiles once per static fingerprint and batches the
rest through ``jax.vmap``).  Numbers land in EXPERIMENTS.md §Sweep.

Both paths are explicitly warmed (one untimed call each) before the
timed passes: the former cold-cold timing mixed one-off XLA compile
time into both walls, so the reported speedup swung run-to-run with
compile-scheduler noise and overstated variance.  What's timed now is
steady-state execution — the regime every repeated benchmark run is in
once the persistent compilation cache is warm.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks._common import pick
from repro.sync import Spec, Study, run

CYCLES = pick(6_000, 1_000)
GRID = [dict(n_addrs=a, lat=l, work=w, seed=s)
        for a, l, w, s in [(1, 5, 10, 0), (4, 5, 10, 1), (16, 5, 10, 2),
                           (64, 5, 10, 3), (1, 3, 6, 4), (16, 3, 6, 5),
                           (4, 9, 14, 6), (64, 9, 14, 7), (256, 5, 10, 8),
                           (1, 9, 6, 9), (16, 9, 10, 10), (256, 3, 14, 11)]]


def rows(cycles: int = CYCLES) -> List[Dict]:
    study = Study.from_specs(
        Spec(protocol="colibri", n_cores=128, cycles=cycles, **g)
        for g in GRID)
    specs = study.specs()
    # warm both jit caches so neither timed pass pays a compile
    study.run()
    for s in specs:
        run(s)
    t0 = time.perf_counter()
    swept = study.run()
    t_sweep = time.perf_counter() - t0
    t0 = time.perf_counter()
    seq = [run(s) for s in specs]
    t_seq = time.perf_counter() - t0
    out = []
    for g, rs, rq in zip(GRID, swept, seq):
        out.append(rs.to_row(figure="sweep", **g,
                             updates_per_cycle=rs.throughput,
                             matches_run=bool(
                                 np.array_equal(rs["ops"], rq["ops"])
                                 and rs.msgs == rq.msgs
                                 and rs.polls == rq.polls)))
    out.append({"figure": "sweep", "timing": True, "n_configs": len(specs),
                "sweep_s": t_sweep, "sequential_s": t_seq,
                "speedup": t_seq / t_sweep})
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    timing = next(r for r in rs if r.get("timing"))
    return {
        "n_configs": float(timing["n_configs"]),
        "sweep_s": timing["sweep_s"],
        "sequential_s": timing["sequential_s"],
        "sweep_speedup_over_sequential": timing["speedup"],
        "all_results_match_run": float(all(
            r["matches_run"] for r in rs if not r.get("timing"))),
    }
