"""Shared benchmark plumbing: QUICK mode, timing, provenance, rows.

Every benchmark module used to re-implement three things ad hoc: a
``QUICK = int(os.environ.get("REPRO_BENCH_QUICK", ...))`` switch, a
warm-then-best-of ``_time`` helper, and hand-built JSON-safe row dicts.
They live here once; row building itself is
``repro.sync.Result.to_row()``.  :func:`provenance` stamps every
generated report with the environment that produced it (git sha, jax
versions, device, timestamp) so numbers in ``reports/*.json`` are
attributable — ``tests/test_report_schema.py`` enforces the block's
presence and shape.

``REPRO_BENCH_QUICK=1`` (the CI smoke rows) selects each benchmark's
trimmed configuration via :func:`pick`; the full-resolution path is
byte-for-byte what it always was.
"""
from __future__ import annotations

import datetime
import os
import subprocess
import time
from typing import Callable, Dict, TypeVar

T = TypeVar("T")

#: CI smoke mode — trimmed grids/horizons so every benchmark stays cheap
QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))


def pick(full: T, quick: T) -> T:
    """``quick`` under ``REPRO_BENCH_QUICK=1``, else ``full``."""
    return quick if QUICK else full


def time_best(fn: Callable[[], object], reps: int = 3) -> float:
    """Best-of-``reps`` wall seconds for ``fn()``, after one untimed
    warm call (compile excluded — what repeated benchmark runs measure
    once the persistent compilation cache is warm)."""
    fn()
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def time_median(fn: Callable[[], object], reps: int = 3) -> float:
    """Median-of-``reps`` wall seconds for ``fn()``, after one untimed
    warm call.  The QUICK CI rows use this instead of :func:`time_best`:
    best-of-N over the short smoke horizons is an order statistic that a
    single lucky scheduler slot can swing, which made the
    ``check_trend.py`` gate flaky on shared runners — the median moves
    only when the *typical* run moves."""
    fn()
    ts = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3):
    """(result, seconds_per_call) with block_until_ready semantics."""
    import jax
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / iters


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def provenance() -> Dict[str, object]:
    """The environment block stamped into every generated report.

    Keys (all strings unless noted): ``git_sha``, ``jax`` / ``jaxlib``
    versions, ``device`` kind and ``n_devices`` (int), the resolved
    engine ``backend``, ``quick`` (bool — whether the rows are the
    trimmed CI smoke set), and an ISO-8601 UTC ``timestamp``.
    """
    import jax
    import jaxlib
    from repro.core.sim import resolve_backend
    devs = jax.devices()
    return {
        "git_sha": _git_sha(),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "device": devs[0].device_kind if devs else "none",
        "n_devices": len(devs),
        "backend": resolve_backend("auto"),
        "quick": QUICK,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
