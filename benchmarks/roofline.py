"""§Roofline — three-term roofline per (arch × shape × mesh) cell.

    compute term    = FLOPs_per_device / 197e12        (bf16 peak per chip)
    memory term     = HBM_bytes_per_device / 819e9
    collective term = collective_bytes_per_device / 50e9 (per-link ICI)

Sources & corrections (documented in EXPERIMENTS.md §Dry-run notes):
  * ``compiled.cost_analysis()`` counts while-loop bodies ONCE — for
    scan-over-layers models this undercounts by ~the layer count. The
    compute/memory terms therefore use the ANALYTIC executed-cost model
    below (validated against unrolled HLO counts in tests), while the raw
    HLO numbers are reported alongside.
  * The collective term uses the loop-corrected HLO parse
    (``hlo_analysis.collective_bytes_corrected``) — per-device bytes of
    every all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute
    multiplied by its loop trip counts.
  * MODEL_FLOPS = 6·N·D (dense train) or 6·N_active·D (MoE); the ratio
    MODEL_FLOPS / executed-FLOPs exposes remat recompute, full-square causal
    attention, and CE-chunk recompute waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import ARCH_NAMES, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeSpec

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per link
REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")


# ---------------------------------------------------------------------------
# Analytic executed-cost model
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """The 'useful' FLOPs: 6·N·D train, 2·N·D forward-only (global)."""
    n = cfg.num_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def executed_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Analytic FLOPs the compiled step actually executes (global):
    matmul factor per pass + remat recompute + full-square blocked causal
    attention + CE chunk recompute. Validated vs unrolled HLO counts."""
    n = cfg.num_active_params()
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h = cfg.num_heads
    b, s = shape.global_batch, shape.seq_len
    kinds = cfg.layer_kinds()

    if shape.kind == "train":
        # fwd(2) + bwd(4) + remat re-fwd(2 if remat)
        factor = 8.0 if cfg.parallel.remat else 6.0
        tokens = b * s
        core = factor * n * tokens
        # attention: blocked causal computes the FULL square (no triangle
        # skip): per attn layer 2 matmuls * 2 flops * B*S^2*H*hd per pass
        attn = 0.0
        for kind in kinds:
            if kind == "attn":
                eff_s = s
            elif kind == "local":
                eff_s = min(2 * cfg.local_window, s)
            else:
                continue
            attn += 2 * 2 * b * s * eff_s * h * hd
        if cfg.encoder is not None:
            e = cfg.encoder
            attn += cfg.encoder.num_layers * 2 * 2 * b * e.seq_len ** 2 * h * hd
            attn += cfg.num_layers * 2 * 2 * b * s * e.seq_len * h * hd
        attn_total = attn * (2.0 if not cfg.parallel.remat else 3.0)
        # CE loss: logits fwd + bwd + checkpoint re-fwd over all chunks
        ce = 2.0 * b * s * d * cfg.vocab_size * 4.0
        return core + attn_total + ce
    if shape.kind == "prefill":
        tokens = b * s
        core = 2.0 * n * tokens
        attn = 0.0
        for kind in kinds:
            if kind == "attn":
                attn += 2 * 2 * b * s * s * h * hd
            elif kind == "local":
                attn += 2 * 2 * b * s * min(2 * cfg.local_window, s) * h * hd
        if cfg.encoder is not None:
            e = cfg.encoder
            attn += cfg.encoder.num_layers * 2 * 2 * b * e.seq_len ** 2 * h * hd
            attn += cfg.num_layers * 2 * 2 * b * s * e.seq_len * h * hd
        return core + attn
    # decode: one token; attention reads the cache
    core = 2.0 * n * b
    attn = 0.0
    for kind in kinds:
        if kind == "attn":
            if cfg.attn_kind == "mla":
                r = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                attn += 2 * 2 * b * s * h * r        # absorbed latent scores
            else:
                attn += 2 * 2 * b * s * h * hd
        elif kind == "local":
            attn += 2 * 2 * b * min(cfg.local_window, s) * h * hd
    if cfg.encoder is not None:
        attn += cfg.num_layers * 2 * 2 * b * cfg.encoder.seq_len * h * hd
    return core + attn


def executed_bytes(cfg: ModelConfig, shape: ShapeSpec, chips: int) -> float:
    """Analytic HBM traffic per STEP (global bytes): parameter/optimizer
    streams + activation traffic + (decode) cache read/write."""
    n_total = cfg.num_params()
    p_bytes = 2.0 * n_total                      # bf16 weights
    if shape.kind == "train":
        opt_b = {"float32": 4, "bfloat16": 2, "int8": 1}[
            cfg.parallel.opt_state_dtype]
        # fwd read + remat read + bwd read + grad write (accum dtype) +
        # optimizer: read m,v + write m,v + write params
        traffic = p_bytes * (3.0 + 1.0) \
            + 2.0 * n_total * opt_b * 2.0 + p_bytes
        traffic *= 1.0
        # per-microbatch weight re-reads under accumulation
        traffic += p_bytes * 2.0 * max(cfg.parallel.accum_steps - 1, 0)
        # activations: ~14 hidden-size tensors per layer, fwd+bwd, bf16
        act = 14 * cfg.num_layers * shape.global_batch * shape.seq_len \
            * cfg.d_model * 2 * 2
        return traffic + act
    if shape.kind == "prefill":
        act = 10 * cfg.num_layers * shape.global_batch * shape.seq_len \
            * cfg.d_model * 2
        return p_bytes + act
    # decode: weights + full cache read + cache write
    cache = _cache_bytes(cfg, shape)
    return p_bytes + cache + shape.global_batch * cfg.d_model * 2 \
        * cfg.num_layers * 4


def _cache_bytes(cfg: ModelConfig, shape: ShapeSpec) -> float:
    b, s = shape.global_batch, shape.seq_len
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "attn":
            if cfg.attn_kind == "mla":
                total += b * s * (cfg.mla.kv_lora_rank
                                  + cfg.mla.qk_rope_head_dim) * 2
            else:
                total += 2 * b * s * cfg.num_kv_heads \
                    * cfg.resolved_head_dim * 2
        elif kind == "local":
            total += 2 * b * min(cfg.local_window, s) * cfg.num_kv_heads \
                * cfg.resolved_head_dim * 2
        elif kind == "rglru":
            w = cfg.recurrent.lru_width or cfg.d_model
            total += b * w * 4
        elif kind == "rwkv":
            hd = cfg.recurrent.head_dim
            total += b * (cfg.d_model // hd) * hd * hd * 4
    if cfg.encoder is not None:
        total += 2 * b * cfg.encoder.seq_len * cfg.num_heads \
            * cfg.resolved_head_dim * 2
    return total


# ---------------------------------------------------------------------------
# Roofline assembly
# ---------------------------------------------------------------------------

def cell_report(arch: str, shape_name: str, mesh: str = "pod16x16",
                report_dir: str = REPORT_DIR) -> Optional[Dict]:
    path = os.path.join(report_dir, f"{arch}__{shape_name}__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def roofline(arch: str, shape_name: str, mesh: str = "pod16x16",
             report_dir: str = REPORT_DIR) -> Optional[Dict]:
    rep = cell_report(arch, shape_name, mesh, report_dir)
    if rep is None or rep.get("status") != "ok":
        return rep
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = 512 if "2x16" in mesh else 256
    mf = model_flops(cfg, shape)
    ef = executed_flops(cfg, shape)
    eb = executed_bytes(cfg, shape, chips)
    coll = rep["collectives"]["total"]           # per device, loop-corrected
    t_compute = ef / chips / PEAK_FLOPS
    t_memory = eb / chips / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh,
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "executed_flops": ef,
        "useful_ratio": mf / ef,
        "roofline_fraction": t_compute / bound if bound else 0.0,
        "step_time_lower_bound_s": bound,
        "hlo_flops_per_device_raw": rep["cost"]["flops"],
        "hlo_bytes_per_device_raw": rep["cost"]["bytes_accessed"],
        "collective_bytes_per_device": coll,
        "collective_bytes_raw": rep["collectives"].get("total_raw", 0.0),
        "peak_hbm_gib": rep["memory"].get("peak_bytes", 0) / 2**30,
        "fits_16g": rep["memory"].get("peak_bytes", 0) <= 16 * 2**30,
    }


def full_table(mesh: str = "pod16x16", report_dir: str = REPORT_DIR
               ) -> List[Dict]:
    rows = []
    for arch in ARCH_NAMES:
        for shape_name in SHAPES:
            cfg = get_config(arch)
            ok, reason = shape_applicable(cfg, SHAPES[shape_name])
            if not ok:
                rows.append({"arch": arch, "shape": shape_name, "mesh": mesh,
                             "dominant": "skipped", "reason": reason})
                continue
            r = roofline(arch, shape_name, mesh, report_dir)
            if r is None:
                rows.append({"arch": arch, "shape": shape_name, "mesh": mesh,
                             "dominant": "missing"})
            elif r.get("status") == "failed":
                rows.append({"arch": arch, "shape": shape_name, "mesh": mesh,
                             "dominant": "FAILED",
                             "reason": r.get("error", "")[:80]})
            else:
                rows.append(r)
    return rows


def print_table(rows: List[Dict]):
    hdr = (f"{'arch':20s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collective':>10s} {'dominant':>11s} {'roofline%':>9s} "
           f"{'useful%':>8s} {'HBM GiB':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "compute_s" not in r:
            print(f"{r['arch']:20s} {r['shape']:12s} "
                  f"{'-':>9s} {'-':>9s} {'-':>10s} {r['dominant']:>11s}")
            continue
        print(f"{r['arch']:20s} {r['shape']:12s} "
              f"{r['compute_s']*1e3:8.1f}ms {r['memory_s']*1e3:8.1f}ms "
              f"{r['collective_s']*1e3:9.1f}ms "
              f"{r['dominant'].replace('_s',''):>11s} "
              f"{100*r['roofline_fraction']:8.1f}% "
              f"{100*r['useful_ratio']:7.1f}% "
              f"{r['peak_hbm_gib']:8.2f}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16",
                    choices=["pod16x16", "pod2x16x16"])
    args = ap.parse_args()
    rows = full_table(args.mesh)
    print_table(rows)
    out = os.path.join(os.path.dirname(__file__), "..", "reports",
                       f"roofline_{args.mesh}.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    print(f"\n-> {out}")
