"""Fault-tolerance benchmark: liveness and graceful degradation under
injected faults (``repro.faults``).

Three measurements, all simulation *outcomes* (deterministic — no wall
clocks, so rows are exactly reproducible):

* **liveness contrast** — every protocol under an adversarial owner
  kill (``kill_holder=1``: the cores that die are the ones holding the
  reservation/lock), with and without the reservation watchdog.  The
  headline counts protocols that sustain forward progress
  (``progress_ok``) with recovery enabled, and protocols whose
  watchdog-off run is *detected* as deadlocked (``halt_cyc >= 0`` — the
  run always completes and reports; it never hangs).  ``amo`` is the
  control: direct AMOs hold nothing, so kills never land and both
  variants trivially stay live.
* **message-drop degradation curve** — throughput of the sleep-based
  protocols as the NoC Bernoulli drop rate rises (lost requests AND
  lost wakeups), watchdog on: the curve should degrade gracefully, not
  cliff — every lost wakeup is eventually recovered by redelivery.
* **watchdog-latency ablation** — the recovery knob itself:
  ``watchdog_cyc`` from "off" (detected deadlock) through aggressive to
  conservative, under the same owner kill.

EXPERIMENTS.md §Fault-tolerance quotes the table;
``REPRO_BENCH_QUICK=1`` trims protocols/horizons for the CI smoke row.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from benchmarks._common import pick
from repro.faults import FaultPlan
from repro.sync import Spec, run

CORES = pick(64, 32)
CYCLES = pick(12_000, 6_000)
ADDRS = 4
KILLS = 2
KILL_CYC = 500
WD = 64
PROG = 600

#: liveness-contrast protocol set (full = the whole registry; QUICK
#: drops the slow-trickle spin locks whose detected halt needs a long
#: horizon)
PROTOCOLS = pick(("lrscwait", "colibri", "colibri_hier", "mwait_lock",
                  "lrsc", "lrsc_lock", "amo_lock", "ticket_lock", "amo"),
                 ("lrscwait", "colibri_hier", "mwait_lock", "lrsc"))
DROP_PROTOS = pick(("lrscwait", "colibri", "mwait_lock"),
                   ("lrscwait",))
DROPS = pick((0, 50, 100, 200, 400), (0, 200))      # basis points / 10k
WD_SET = pick((0, 32, 64, 128, 256), (0, 64))

#: owner-kill plan (watchdog_cyc varies per row)
_KILL = FaultPlan(n_kill=KILLS, kill_cyc=KILL_CYC, kill_holder=1,
                  watchdog_cyc=WD, progress_cyc=PROG)


def _spec(proto: str, fp: FaultPlan) -> Spec:
    return Spec(protocol=proto, n_cores=CORES, n_addrs=ADDRS,
                cycles=CYCLES, faults=fp)


def rows() -> List[Dict]:
    out: List[Dict] = []
    # ---- liveness contrast: owner kill, watchdog on vs off --------------
    for proto in PROTOCOLS:
        healthy = run(Spec(protocol=proto, n_cores=CORES, n_addrs=ADDRS,
                           cycles=CYCLES))
        for tag, fp in (("wd", _KILL),
                        ("nowd", dataclasses.replace(_KILL,
                                                     watchdog_cyc=0))):
            r = run(_spec(proto, fp))
            out.append(r.to_row(
                figure="faults", row=f"kill_{tag}_{proto}",
                watchdog_cyc=fp.watchdog_cyc, n_kill=KILLS,
                healthy_throughput=healthy.throughput,
                throughput_retention=(r.stats["survivor_throughput"]
                                      / healthy.throughput
                                      if healthy.throughput else 0.0)))
    # ---- message-drop degradation curve ---------------------------------
    for proto in DROP_PROTOS:
        base_tp = None
        for bp in DROPS:
            fp = FaultPlan(msg_drop_bp=bp, watchdog_cyc=WD,
                           progress_cyc=PROG)
            r = run(_spec(proto, fp))
            if bp == 0:
                base_tp = r.throughput
            out.append(r.to_row(
                figure="faults", row=f"drop_{bp}bp_{proto}",
                msg_drop_bp=bp, watchdog_cyc=WD,
                throughput_retention=(r.throughput / base_tp
                                      if base_tp else 0.0)))
    # ---- watchdog-latency ablation (lrscwait, owner kill) ---------------
    for wd in WD_SET:
        r = run(_spec("lrscwait",
                      dataclasses.replace(_KILL, watchdog_cyc=wd)))
        out.append(r.to_row(figure="faults", row=f"wd_{wd}_lrscwait",
                            watchdog_cyc=wd, n_kill=KILLS))
    return out


def headline(rs: List[Dict]) -> Dict[str, float]:
    by = {r["row"]: r for r in rs}
    head: Dict[str, float] = {}
    # liveness: how many protocols survive the owner kill with the
    # watchdog, and how many watchdog-off runs are DETECTED as halted
    # (amo is the no-holder control and is exempt from detection)
    live = sum(bool(by[f"kill_wd_{p}"]["progress_ok"]) for p in PROTOCOLS)
    detected = sum(not by[f"kill_nowd_{p}"]["progress_ok"]
                   for p in PROTOCOLS if p != "amo")
    head["protocols_live_with_watchdog"] = float(live)
    head["protocols_total"] = float(len(PROTOCOLS))
    head["deadlocks_detected_without_watchdog"] = float(detected)
    head["deadlockable_protocols"] = float(
        len([p for p in PROTOCOLS if p != "amo"]))
    for p in ("lrscwait", "colibri_hier"):
        r = by.get(f"kill_wd_{p}")
        if r:
            head[f"kill_wd_retention_{p}"] = r["throughput_retention"]
    top = max(DROPS)
    for p in DROP_PROTOS:
        head[f"drop{top}bp_retention_{p}"] = (
            by[f"drop_{top}bp_{p}"]["throughput_retention"])
    return head
