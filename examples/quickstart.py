"""Quickstart: train a reduced smollm-135m on CPU for a few steps,
reproduce the paper's headline result (Fig. 3 ratios) with the
simulator, then run one concurrent-algorithm workload from the workload
registry through every class of protocol.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core import workloads
from repro.core.sim import SimParams, run
from repro.launch.train import TrainRun, run_training


def main():
    print("=== 1. train a reduced smollm-135m (CPU) ===")
    cfg = get_config("smollm-135m-smoke")
    out = run_training(TrainRun(cfg=cfg, shape=ShapeSpec("t", 128, 4, "train"),
                                steps=20, log_every=5))
    print(f"final loss: {out['loss']:.4f}\n")

    print("=== 2. paper headline: Colibri vs LRSC (Fig. 3) ===")
    hi_c = run(SimParams(protocol="colibri", n_addrs=1))["throughput"]
    hi_l = run(SimParams(protocol="lrsc", n_addrs=1))["throughput"]
    lo_c = run(SimParams(protocol="colibri", n_addrs=256))["throughput"]
    lo_l = run(SimParams(protocol="lrsc", n_addrs=256))["throughput"]
    print(f"high contention: colibri/lrsc = {hi_c/hi_l:.2f}x (paper: 6.5x)")
    print(f"low contention:  colibri/lrsc = {lo_c/lo_l:.2f}x (paper: 1.13x)\n")

    print("=== 3. workload registry: a concurrent queue, three protocols ===")
    print(f"registered workloads: {', '.join(workloads.names())}")
    wl = workloads.get("ms_queue")
    for proto in ("colibri", "lrsc", "amo_lock"):
        p = SimParams(protocol=proto, workload="ms_queue", n_cores=64,
                      cycles=6000, record_trace=True, **wl.scenario)
        r = run(p)
        info = wl.check(p, r, r["trace_step"])   # linearizability screen
        print(f"  {proto:9s} enq+deq pairs/cycle = {r['throughput']:.4f}  "
              f"polls = {int(r['polls']):5d}  "
              f"(pushes={info['pushes']}, pops={info['pops']})")


if __name__ == "__main__":
    main()
