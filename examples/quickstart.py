"""Quickstart: train a reduced smollm-135m on CPU for a few steps, then
reproduce the paper's headline result (Fig. 3 ratios) with the simulator.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.sim import SimParams, run
from repro.launch.train import TrainRun, run_training


def main():
    print("=== 1. train a reduced smollm-135m (CPU) ===")
    cfg = get_config("smollm-135m-smoke")
    out = run_training(TrainRun(cfg=cfg, shape=ShapeSpec("t", 128, 4, "train"),
                                steps=20, log_every=5))
    print(f"final loss: {out['loss']:.4f}\n")

    print("=== 2. paper headline: Colibri vs LRSC (Fig. 3) ===")
    hi_c = run(SimParams(protocol="colibri", n_addrs=1))["throughput"]
    hi_l = run(SimParams(protocol="lrsc", n_addrs=1))["throughput"]
    lo_c = run(SimParams(protocol="colibri", n_addrs=256))["throughput"]
    lo_l = run(SimParams(protocol="lrsc", n_addrs=256))["throughput"]
    print(f"high contention: colibri/lrsc = {hi_c/hi_l:.2f}x (paper: 6.5x)")
    print(f"low contention:  colibri/lrsc = {lo_c/lo_l:.2f}x (paper: 1.13x)")


if __name__ == "__main__":
    main()
