"""Quickstart: train a reduced smollm-135m on CPU for a few steps,
reproduce the paper's headline result (Fig. 3 ratios) through the
public ``repro.sync`` API, then run one concurrent-algorithm workload
from the workload registry through every class of protocol.

    PYTHONPATH=src python examples/quickstart.py

``REPRO_BENCH_QUICK=1`` (the CI smoke) trims the simulated horizons;
the headline ratios then drift from the paper's numbers, the mechanics
don't.
"""
import os

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.launch.train import TrainRun, run_training
from repro.sync import Spec, Study, run, scenario, workloads

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))


def main():
    print("=== 1. train a reduced smollm-135m (CPU) ===")
    cfg = get_config("smollm-135m-smoke")
    out = run_training(TrainRun(cfg=cfg, shape=ShapeSpec("t", 128, 4, "train"),
                                steps=20, log_every=5))
    print(f"final loss: {out['loss']:.4f}\n")

    print("=== 2. paper headline: Colibri vs LRSC (Fig. 3) ===")
    study = Study(Spec(cycles=2_000 if QUICK else 20_000)) \
        .grid(protocol=("colibri", "lrsc"), n_addrs=(1, 256))
    t = {(r.spec.protocol.name, r.spec.topology.n_addrs): r.throughput
         for r in study.run()}
    hi = t[("colibri", 1)] / t[("lrsc", 1)]
    lo = t[("colibri", 256)] / t[("lrsc", 256)]
    print(f"high contention: colibri/lrsc = {hi:.2f}x (paper: 6.5x)")
    print(f"low contention:  colibri/lrsc = {lo:.2f}x (paper: 1.13x)\n")

    print("=== 3. workload registry: a concurrent queue, three protocols ===")
    print(f"registered workloads: {', '.join(workloads())}")
    for proto in ("colibri", "lrsc", "amo_lock"):
        r = run(Spec(protocol=proto, workload="ms_queue", n_cores=64,
                     cycles=2_000 if QUICK else 6_000, record_trace=True,
                     **scenario("ms_queue")))
        info = r.check()                         # linearizability screen
        print(f"  {proto:9s} enq+deq pairs/cycle = {r.throughput:.4f}  "
              f"polls = {r.polls:5d}  "
              f"(pushes={info['pushes']}, pops={info['pops']})")


if __name__ == "__main__":
    main()
