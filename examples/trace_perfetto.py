"""Watch the paper's dynamics: export Perfetto traces of a contended
zipf_histogram run under Colibri (polling-free) vs bare LR/SC (retry
loop), plus the windowed telemetry timeseries of the same pair.

    PYTHONPATH=src python examples/trace_perfetto.py [out_dir]

Writes ``trace_colibri.json`` and ``trace_lrsc.json`` (Chrome-trace
JSON — load them at https://ui.perfetto.dev) and prints the retry-span
contrast the traces show: the LRSC core tracks fill with BACKOFF spans
(failed SC -> backoff -> reissue), the Colibri tracks show one SLEEP
span per contended op and **zero** retries.  The same contrast shows up
numerically in ``Result.timeseries()``: Colibri's ``backoff`` channel
is identically zero while its reservation queues drain.

``REPRO_BENCH_QUICK=1`` (the CI smoke) trims the horizon.
"""
import os
import sys

from repro import obs
from repro.core.protocols.base import BACKOFF, SLEEP
from repro.sync import Spec, run, scenario

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.join(os.path.dirname(__file__), "..", "reports")
    os.makedirs(out_dir, exist_ok=True)
    base = Spec(workload="zipf_histogram", n_cores=64,
                cycles=1_000 if QUICK else 4_000,
                record_trace=True, telemetry_windows=64,
                **scenario("zipf_histogram"))

    paths = {}
    for proto in ("colibri", "lrsc"):
        r = run(base.replace(protocol=proto))
        log = r.events()
        ts = r.timeseries()
        retry_spans = int(log.span_counts(BACKOFF).sum())
        sleep_spans = int(log.span_counts(SLEEP).sum())
        paths[proto] = obs.perfetto.export(
            r, os.path.join(out_dir, f"trace_{proto}.json"))
        print(f"{proto:8s} retry(BACKOFF) spans = {retry_spans:5d}   "
              f"SLEEP spans = {sleep_spans:5d}   "
              f"polls = {r.polls:5d}   "
              f"peak queue depth = {int(ts.queue_depth_max.max())}")
        if proto == "colibri":
            assert retry_spans == 0 and r.polls == 0, \
                "colibri must be retry-free"
        else:
            assert retry_spans > 0, "lrsc must show retry spans"

    print("\nPerfetto traces (load at https://ui.perfetto.dev):")
    for proto, p in paths.items():
        print(f"  {proto}: {os.path.abspath(p)}")


if __name__ == "__main__":
    main()
