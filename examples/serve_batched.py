"""Batched serving: spin up the event-driven engine on a reduced model and
serve concurrent requests with prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_batched.py
"""
import threading
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serving import Request, ServeEngine


def main():
    cfg = get_config("qwen2-7b-smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=4, cache_len=128)
    t = threading.Thread(target=engine.serve_forever, daemon=True)
    t.start()

    rng = np.random.RandomState(0)
    t0 = time.time()
    results = []

    def client(i):
        prompt = rng.randint(0, cfg.vocab_size, size=(8 + i,)).astype(np.int32)
        out = engine.generate(prompt, max_new_tokens=8)
        results.append((i, out))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(6)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    engine.stop()
    for i, out in sorted(results):
        print(f"request {i}: generated {out.tolist()}")
    print(f"6 requests in {time.time()-t0:.1f}s (batched, event-driven)")


if __name__ == "__main__":
    main()
