"""End-to-end training driver: a ~100M-param smollm-135m variant trained for
a few hundred steps with checkpointing and automatic resume.

Full-scale invocation (unchanged code path, production mesh):
    python -m repro.launch.train --arch smollm-135m --shape train_4k

This example uses a width-reduced variant so a few hundred steps finish on
the CPU container while exercising the REAL driver (deterministic pipeline,
AdamW, async checkpoints, resume).

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.configs.base import ParallelSpec, ShapeSpec
from repro.launch.train import TrainRun, run_training
from repro import optim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    base = get_config("smollm-135m")
    cfg = dataclasses.replace(
        base, name="smollm-midi",
        num_layers=6, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=1024, vocab_size=8192, head_dim=64,
        param_dtype="float32", compute_dtype="float32",
        parallel=ParallelSpec(remat=False))
    print(f"params: {cfg.num_params()/1e6:.1f}M")
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    run = TrainRun(cfg=cfg, shape=ShapeSpec("train", 256, 16, "train"),
                   steps=args.steps, ckpt_dir=ckpt, ckpt_every=100,
                   opt=optim.AdamWConfig(lr=1e-3, warmup_steps=30,
                                         total_steps=args.steps),
                   log_every=20)
    out = run_training(run)
    print({k: round(v, 4) for k, v in out.items() if isinstance(v, float)})
    print(f"checkpoints in {ckpt} (re-run with --ckpt-dir to resume)")


if __name__ == "__main__":
    main()
