"""The paper's technique inside the framework: colibri ordered-commit MoE
dispatch on a reduced deepseek-v3 (MLA + shared/routed experts).

Shows: FIFO queue positions per expert, capacity behaviour (oldest win —
LRSCwait_q semantics), and a train step through the full dispatch path.

    PYTHONPATH=src python examples/moe_colibri_dispatch.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core import dispatch as D
from repro.distributed.sharding import Policy
from repro.models import build, make_batch


def main():
    print("=== colibri dispatch primitives ===")
    keys = jnp.array([2, 0, 2, 1, 2, 0, 2, 2])
    qp, counts = D.queue_positions(keys, 3)
    print(f"expert ids:      {keys.tolist()}")
    print(f"queue positions: {qp.tolist()}   (FIFO per expert)")
    print(f"expert loads:    {counts.tolist()}")
    d = D.dispatch(keys, 3, capacity=3)
    print(f"kept (cap=3):    {d.keep.tolist()}   <- oldest win, "
          "LRSCwait_q semantics\n")

    print("=== deepseek-v3 (reduced) train step through MoE dispatch ===")
    cfg = get_config("deepseek-v3-671b-smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, ShapeSpec("t", 64, 2, "train"),
                       jax.random.PRNGKey(1))
    loss, metrics = jax.jit(
        lambda p, b: model.loss(p, b, Policy()))(params, batch)
    print(f"loss={float(loss):.4f} aux(load-balance)={float(metrics['aux']):.4f}")
    print("experts:", cfg.moe.num_experts, "top-k:", cfg.moe.top_k,
          "| attention: MLA (latent cache)")


if __name__ == "__main__":
    main()
