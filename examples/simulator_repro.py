"""Reproduce every figure/table of the paper from the cycle-level simulator.

    PYTHONPATH=src python examples/simulator_repro.py
"""
import os
import sys

# make the repo-root `benchmarks` package importable when invoked as a
# script (only examples/ lands on sys.path then)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (bench_area, bench_energy, bench_histogram,
                        bench_interference, bench_locks, bench_queue,
                        bench_workloads)


def main():
    for name, mod, paper in [
        ("Fig.3 histogram", bench_histogram,
         "colibri/lrsc: 6.5x high contention, ~1.13x low"),
        ("Fig.4 locks", bench_locks, "colibri best at all contentions"),
        ("Fig.5 interference", bench_interference,
         "lrsc slows workers to 0.26; colibri ~1.0"),
        ("Fig.6 queue", bench_queue, "1.54x @8 cores; collapse at scale"),
        ("Table I area", bench_area, "<=2% model error"),
        ("Table II energy", bench_energy, "7.1x / 8.8x efficiency"),
        ("Workload grid", bench_workloads,
         "various concurrent algorithms: colibri polling-free on all"),
    ]:
        rows = mod.rows()
        head = mod.headline(rows)
        print(f"--- {name} (paper: {paper})")
        for k, v in head.items():
            print(f"    {k} = {v:.3f}" if isinstance(v, float)
                  else f"    {k} = {v}")


if __name__ == "__main__":
    main()
