"""Reproduce every figure/table of the paper from the cycle-level
simulator, then show the public ``repro.sync`` Study API streaming a
custom experiment.

    PYTHONPATH=src python examples/simulator_repro.py

``REPRO_BENCH_QUICK=1`` trims every figure to its CI-smoke resolution
(the benchmark modules read it via ``benchmarks._common``).
"""
import os
import sys

# make the repo-root `benchmarks` package importable when invoked as a
# script (only examples/ lands on sys.path then)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (bench_area, bench_energy, bench_histogram,
                        bench_interference, bench_locks, bench_queue,
                        bench_workloads)
from repro.sync import Spec, Study


def main():
    for name, mod, paper in [
        ("Fig.3 histogram", bench_histogram,
         "colibri/lrsc: 6.5x high contention, ~1.13x low"),
        ("Fig.4 locks", bench_locks, "colibri best at all contentions"),
        ("Fig.5 interference", bench_interference,
         "lrsc slows workers to 0.26; colibri ~1.0"),
        ("Fig.6 queue", bench_queue, "1.54x @8 cores; collapse at scale"),
        ("Table I area", bench_area, "<=2% model error"),
        ("Table II energy", bench_energy, "7.1x / 8.8x efficiency"),
        ("Workload grid", bench_workloads,
         "various concurrent algorithms: colibri polling-free on all"),
    ]:
        rows = mod.rows()
        head = mod.headline(rows)
        print(f"--- {name} (paper: {paper})")
        for k, v in head.items():
            print(f"    {k} = {v:.3f}" if isinstance(v, float)
                  else f"    {k} = {v}")

    # beyond the paper's figures: any custom study streams the same way
    print("--- custom study: contention x latency, streamed as chunks "
          "materialize")
    study = Study(Spec(protocol="colibri", n_cores=64, cycles=4000)) \
        .grid(n_addrs=(1, 16), lat=(1, 8))
    for r in study.stream():
        print(f"    n_addrs={r.spec.topology.n_addrs:2d} "
              f"lat={r.spec.costs.lat}  ops/cycle={r.throughput:.4f}  "
              f"p95={r.lat_p95:.0f}cyc  {r.energy_pj_per_op:.1f}pJ/op")


if __name__ == "__main__":
    main()
